"""Runtime tests: cost-model calibration, data streams/arrivals, serving
engine, quantized training wrapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.arrivals import build_timeline
from repro.data import streams
from repro.runtime.costmodel import EdgeCostModel, PodCostModel


def test_cost_model_matches_paper_breakdown():
    """Immediate fine-tuning on ResNet50-class work must reproduce the
    paper's Fig. 3 shares: overhead ~58% of time, ~38% of energy."""
    cm = EdgeCostModel()
    flops = 384e9  # one 16-image fine-tune round (paper §I: 24 GFLOPs/img)
    t, e, parts = cm.round_cost(flops)
    t_share = parts["t_overhead"] / t
    e_share = parts["e_overhead"] / e
    assert 0.5 < t_share < 0.65, t_share
    assert 0.3 < e_share < 0.45, e_share


def test_pod_cost_model_terms():
    pm = PodCostModel()
    terms = pm.roofline_terms(1e18, 1e15, 1e13)
    assert terms["compute_s"] == pytest.approx(1e18 / (256 * 197e12))
    assert terms["memory_s"] == pytest.approx(1e15 / (256 * 819e9))
    assert terms["collective_s"] == pytest.approx(1e13 / (256 * 50e9))


# ---------------------------------------------------------------------------
# arrivals


@pytest.mark.parametrize("dist", ["poisson", "uniform", "normal", "trace"])
def test_timeline_counts_and_determinism(dist):
    ev1 = build_timeline(num_scenarios=3, batches_per_scenario=10,
                         inferences_total=20, data_dist=dist, seed=5)
    ev2 = build_timeline(num_scenarios=3, batches_per_scenario=10,
                         inferences_total=20, data_dist=dist, seed=5)
    assert [(e.time, e.kind) for e in ev1] == [(e.time, e.kind) for e in ev2]
    assert sum(e.kind == "data" for e in ev1) == 30
    assert sum(e.kind == "inference" for e in ev1) == 20
    times = [e.time for e in ev1]
    assert times == sorted(times)
    # data events stay within their scenario's span
    for e in ev1:
        if e.kind == "data":
            assert e.scenario * 100.0 <= e.time < (e.scenario + 1) * 100.0


# ---------------------------------------------------------------------------
# streams


def test_nc_benchmark_structure():
    b = streams.nc_benchmark(num_classes=10, num_scenarios=5, batches=6,
                             batch_size=8)
    assert b.num_scenarios == 5
    for s in b.scenarios:
        assert len(s.train_batches) == 6
        assert s.train_batches[0]["images"].shape == (8, 32, 32, 3)
        assert s.val["images"].shape[0] >= 8
    # class-incremental: scenario 0 has fewer classes than the last test set
    assert set(np.unique(b.scenarios[0].test["labels"])) <= set(range(2))
    assert len(np.unique(b.scenarios[-1].test["labels"])) > 2


def test_ni_benchmark_transforms_differ():
    b = streams.ni_benchmark(num_classes=4, num_scenarios=3, batches=4,
                             batch_size=8)
    a = b.scenarios[0].train_batches[0]["images"]
    c = b.scenarios[2].train_batches[0]["images"]
    assert float(np.abs(a.mean() - c.mean())) > 1e-3  # appearance shift


def test_text_benchmark_classes_separable():
    b = streams.text_benchmark(num_classes=4, num_scenarios=2, batches=4,
                               batch_size=8, vocab=128)
    s = b.scenarios[0]
    assert s.train_batches[0]["tokens"].shape == (8, 32)
    assert s.test["tokens"].dtype == np.int32


# ---------------------------------------------------------------------------
# serving engine


def test_serve_engine_generates():
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.runtime.serve import ServeEngine

    cfg = get_reduced("qwen1.5-32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, max_len=48)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    out = eng.generate(params, toks, steps=6)
    assert out.shape == (2, 6)
    assert eng.stats.decode_steps == 6
    assert out.dtype.kind in "iu"


# ---------------------------------------------------------------------------
# quantization wrapper (paper §V-G)


def test_quantized_model_trains():
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.runtime.continual import _quantized_model

    cfg = get_reduced("mobilenetv2")
    model = _quantized_model(build_model(cfg), 8)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"images": jnp.ones((4, 32, 32, 3)),
             "labels": jnp.zeros((4,), jnp.int32)}
    (loss, _), grads = jax.value_and_grad(lambda p: model.loss(p, batch),
                                          has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gn > 0  # straight-through estimator keeps gradients alive
