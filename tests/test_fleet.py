"""DeviceFleet (DESIGN.md §13): the fleet-of-1 exactness contract, the
routing policies, federated aggregation accounting, straggler eviction,
and the three-way attribution invariant under the many-stream `fleet`
preset.

The load-bearing test is `test_fleet_of_one_matches_single_device`: the
DeviceRuntime extraction turned `ContinualRuntime` into a fleet of size
1, and that delegation must be bit-for-bit — same accuracy trace, same
ledger, same attributions — whether or not an aggregation period is set
(a fleet of one never has a merge partner)."""
import numpy as np
import pytest

from repro.data.arrivals import Event
from repro.runtime import RuntimeConfig, SlotConfig, edgeol_session
from repro.runtime.config import DeviceConfig
from repro.runtime.fleet import (FLEET_STREAM, LeastLoaded, StaticAffinity,
                                 build_routing, fleet_devices)

SCALE = dict(batches_per_scenario=3, inferences=6, num_scenarios=2)


def _run(workload="two-stream", *, scale=SCALE, **cfg_kw):
    cfg = RuntimeConfig(slots={"cv": SlotConfig()}, workload=workload,
                        workload_scale=dict(scale), seed=0,
                        pretrain_epochs=1, compiled=True, **cfg_kw)
    return edgeol_session(cfg).run()


def _assert_identical(a, b):
    assert a.rounds == b.rounds
    assert a.swaps == b.swaps
    assert a.syncs == b.syncs
    np.testing.assert_array_equal(a.inference_accs, b.inference_accs)
    np.testing.assert_array_equal(a.val_curve, b.val_curve)
    assert a.total_time_s == b.total_time_s
    assert a.total_energy_j == b.total_energy_j
    assert a.compute_tflops == b.compute_tflops
    assert a.per_stream == b.per_stream
    assert a.per_model == b.per_model


def _assert_attributions_sum(res):
    """ISSUE acceptance: per-stream, per-model and per-device each
    independently reconstruct the cell totals."""
    for dim in (res.per_stream, res.per_model, res.per_device):
        np.testing.assert_allclose(
            sum(v["time_s"] for v in dim.values()), res.total_time_s,
            rtol=1e-9)
        np.testing.assert_allclose(
            sum(v["energy_j"] for v in dim.values()), res.total_energy_j,
            rtol=1e-9)


# ---------------------------------------------------------------------------
# fleet-of-1 exactness (the refactor's regression contract)


def test_fleet_of_one_matches_single_device():
    legacy = _run()                                     # no devices axis
    one = _run(devices=(DeviceConfig("dev0"),))
    _assert_identical(legacy, one)
    assert one.syncs == 0
    assert set(one.per_device) == {"dev0"}


def test_fleet_of_one_with_aggregation_period_never_merges():
    # a merge needs >= 2 participants: setting aggregate_every on a fleet
    # of one must not perturb a bit (no sync charges, no param copies)
    legacy = _run()
    one = _run(devices=(DeviceConfig("dev0"),), aggregate_every=20.0,
               routing="least-loaded")
    _assert_identical(legacy, one)
    assert one.syncs == 0


# ---------------------------------------------------------------------------
# routing policies


def test_static_affinity_modulo_mapping():
    specs = [DeviceConfig("dev0"), DeviceConfig("dev1")]
    got = StaticAffinity().assign([3, 0, 7, 1], [], specs)
    assert got == {0: 0, 1: 1, 3: 0, 7: 1}     # sorted stream order


def test_least_loaded_respects_speed_scale():
    specs = [DeviceConfig("dev0"), DeviceConfig("fast", speed_scale=3.0)]
    events = [Event(float(i), "data", 0, i, stream=st)
              for st in range(4) for i in range(5)]   # uniform weights
    got = LeastLoaded().assign([0, 1, 2, 3], events, specs)
    counts = {0: 0, 1: 0}
    for d in got.values():
        counts[d] += 1
    assert counts[1] > counts[0]               # 3x device absorbs more


def test_least_loaded_places_heaviest_first():
    specs = [DeviceConfig("dev0"), DeviceConfig("dev1")]
    events = ([Event(0.0, "data", 0, i, stream=0) for i in range(10)]
              + [Event(0.0, "data", 0, i, stream=1) for i in range(1)]
              + [Event(0.0, "data", 0, i, stream=2) for i in range(1)])
    got = LeastLoaded().assign([0, 1, 2], events, specs)
    # the heavy stream gets a device to itself; the light two share
    assert got[1] == got[2] != got[0]


def test_build_routing_unknown_name_actionable():
    with pytest.raises(ValueError, match=r"least-loaded.*static"):
        build_routing("bogus")


def test_fleet_devices_deterministic_with_reference_dev0():
    a = fleet_devices(4, seed=3, speed_spread=0.4, energy_spread=0.2)
    b = fleet_devices(4, seed=3, speed_spread=0.4, energy_spread=0.2)
    assert a == b
    assert a[0] == DeviceConfig("dev0")        # golden reference lane
    assert all(d.speed_scale > 0 for d in a)
    assert len({d.name for d in a}) == 4
    with pytest.raises(ValueError, match="at least one"):
        fleet_devices(0)


# ---------------------------------------------------------------------------
# multi-device runs: aggregation accounting + attribution invariant


def test_multi_device_fleet_syncs_and_sums():
    devices = fleet_devices(3, seed=0, speed_spread=0.4,
                            energy_spread=0.2)
    res = _run(devices=devices, routing="least-loaded",
               aggregate_every=25.0)
    assert res.syncs > 0                        # merges actually charged
    assert set(res.per_device) == {d.name for d in devices}
    assert res.syncs == sum(v["syncs"] for v in res.per_device.values())
    # sync charges land on the fleet pseudo-stream, inside the totals
    assert str(FLEET_STREAM) in {str(k) for k in res.per_stream}
    _assert_attributions_sum(res)
    for v in res.per_device.values():
        assert 0.0 <= v["utilization"] <= 1.0 + 1e-9


def test_fleet_preset_three_way_attribution_sums():
    scale = dict(batches_per_scenario=2, inferences=4, num_scenarios=2,
                 fleet_streams=6)
    res = _run("fleet", scale=scale,
               devices=fleet_devices(3, seed=0, speed_spread=0.4),
               routing="least-loaded", aggregate_every=25.0)
    assert res.syncs > 0
    assert len(res.per_device) == 3
    # every stream is served somewhere
    assert sum(v["streams"] for v in res.per_device.values()) == 6
    _assert_attributions_sum(res)


def test_aggregation_changes_trajectory_but_not_totals_dimensionality():
    # with merges off the devices drift independently; with merges on the
    # sync charges appear — both keep the attribution invariant
    devices = fleet_devices(2, seed=0, speed_spread=0.4)
    drift = _run(devices=devices, aggregate_every=0.0)
    merged = _run(devices=devices, aggregate_every=20.0)
    assert drift.syncs == 0 and merged.syncs > 0
    assert merged.total_time_s > 0
    _assert_attributions_sum(drift)
    _assert_attributions_sum(merged)


# ---------------------------------------------------------------------------
# stragglers: flagging reroutes, eviction drains a device


def test_straggler_eviction_reroutes_streams():
    from repro.distributed.straggler import StragglerConfig

    devices = (DeviceConfig("dev0"), DeviceConfig("dev1"),
               DeviceConfig("slow", speed_scale=0.2))
    cfg = RuntimeConfig(slots={"cv": SlotConfig()}, workload="fleet",
                        workload_scale=dict(batches_per_scenario=2,
                                            inferences=4, num_scenarios=2,
                                            fleet_streams=6),
                        seed=0, pretrain_epochs=1, compiled=True,
                        devices=devices, routing="static",
                        aggregate_every=10.0)
    rt = edgeol_session(cfg)
    rt.straggler_config = StragglerConfig(min_samples=1, slow_factor=1.5,
                                          evict_after=2)
    res = rt.run()
    slow = res.per_device["slow"]
    assert slow.get("evicted")                 # 5x-slow device thrown out
    assert slow["streams"] == 0                # its streams moved away
    assert sum(v["streams"] for v in res.per_device.values()) == 6
    assert res.rounds > 0
    _assert_attributions_sum(res)
