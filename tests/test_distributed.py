"""Distributed substrate tests. Multi-device cases run in a subprocess with
XLA_FLAGS set (the main pytest process keeps the default 1 CPU device, per
the dry-run isolation rule)."""
import json
import subprocess
import sys
import textwrap

from repro.distributed.straggler import StragglerConfig, StragglerTracker


def _run_subprocess(body: str) -> dict:
    """Run `body` with 8 host devices; body must print one JSON line."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(body))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__('os').environ,
                              "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_param_specs_divisibility_fallback():
    res = _run_subprocess("""
    import jax, jax.numpy as jnp, json
    from repro.configs import get_config
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T

    mesh = make_mesh((2, 4), ("data", "model"))
    # granite: MQA kv=1 -> wk/wv must NOT be sharded on heads
    cfg = get_config("granite-20b")
    params = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(params, cfg, mesh)
    wq = specs["blocks"][0]["mix"]["wq"]
    wk = specs["blocks"][0]["mix"]["wk"]
    mlp = specs["blocks"][0]["ffn"]["wg"]
    json_out = {
        "wq": [str(s) for s in wq], "wk": [str(s) for s in wk],
        "mlp": [str(s) for s in mlp],
    }
    print(json.dumps(json_out))
    """)
    # wq [G, D, 48, 128]: heads 48 % 4 == 0 -> sharded on model
    assert "model" in " ".join(res["wq"])
    # wk [G, D, 1, 128]: kv=1 -> heads dim unsharded
    assert "model" not in res["wk"][2]
    # mlp hidden sharded on model
    assert "model" in " ".join(res["mlp"])


def test_grad_sync_shard_map_plain_and_compressed():
    res = _run_subprocess("""
    import jax, jax.numpy as jnp, json, numpy as np
    from repro.distributed import collectives
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    g = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4) / 7.0}
    synced, _ = collectives.sync_grads_shard_map(mesh, g)
    want = np.asarray(g["w"])  # psum of identical replicas / n == identity
    err_plain = float(np.abs(np.asarray(synced["w"]) - want).max())

    comp, res_t = collectives.sync_grads_shard_map(mesh, g, compress=True)
    err_comp = float(np.abs(np.asarray(comp["w"]) - want).max())
    print(json.dumps({"plain": err_plain, "comp": err_comp}))
    """)
    assert res["plain"] < 1e-6
    assert res["comp"] < 0.05  # int8 quantization error bound


def test_elastic_remesh_preserves_values():
    res = _run_subprocess("""
    import jax, jax.numpy as jnp, json, numpy as np
    from repro.distributed import elastic, sharding as sh
    from repro.launch.mesh import make_mesh
    from jax.sharding import PartitionSpec as P

    mesh_a = make_mesh((4, 2), ("data", "model"))
    mesh_b = elastic.shrink_mesh(mesh_a, "data")  # 2x2 after "failure"
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    spec = {"x": P("data", "model")}
    placed = jax.device_put(x, jax.sharding.NamedSharding(mesh_a, spec["x"]))
    moved = elastic.remesh({"x": placed}, mesh_b, spec)
    ok = bool(np.array_equal(np.asarray(moved["x"]), np.asarray(x)))
    ndev = len(set(moved["x"].devices()))
    print(json.dumps({"ok": ok, "ndev": ndev}))
    """)
    assert res["ok"] and res["ndev"] == 4


# ---------------------------------------------------------------------------
# straggler tracker (pure python)


def test_straggler_detection_and_rebalance():
    tr = StragglerTracker(4, StragglerConfig(min_samples=3, slow_factor=1.5,
                                             evict_after=2))
    for step in range(6):
        times = {0: 1.0, 1: 1.0, 2: 1.05, 3: 3.0}  # host 3 is slow
        tr.record_step(times)
    assert tr.stragglers() == [3]
    assert tr.to_evict() == [3]
    plan = tr.rebalance_plan()
    assert plan[3] < plan[0]                      # slow host gets less work
    assert abs(sum(plan.values()) - 1.0) < 1e-9
    tr.evict(3)
    assert 3 in tr.evicted
    tr.record_step({0: 1.0, 1: 1.0, 2: 1.0})
    assert tr.stragglers() == []


def test_straggler_no_flags_when_uniform():
    tr = StragglerTracker(8)
    for _ in range(20):
        tr.record_step({h: 1.0 + 0.01 * h for h in range(8)})
    assert tr.stragglers() == []


def test_straggler_trackers_do_not_share_config():
    """Regression: `StragglerTracker.__init__` used a shared
    `StragglerConfig()` default instance — mutating one tracker's config
    (as the DeviceFleet does when tightening `evict_after` for a small
    fleet) silently changed every other default-constructed tracker."""
    a = StragglerTracker(4)
    b = StragglerTracker(4)
    assert a.cfg is not b.cfg
    a.cfg.slow_factor = 99.0
    assert b.cfg.slow_factor != 99.0
    assert StragglerTracker(2).cfg is not StragglerTracker(2).cfg


def test_straggler_rebalance_excludes_evicted_hosts():
    tr = StragglerTracker(3, StragglerConfig(min_samples=2))
    for _ in range(4):
        tr.record_step({0: 1.0, 1: 1.0, 2: 2.5})
    tr.evict(2)
    plan = tr.rebalance_plan()
    assert 2 not in plan
    assert abs(sum(plan.values()) - 1.0) < 1e-9
