"""PolicyStack tests (DESIGN.md §11): the four policy axes compose into
the controller protocol, the priority-weighted trigger uses QoS priority
and staleness jointly, legacy monolithic controllers keep working through
the adapter, publish policies drive the params-visibility seam, and the
shared-mutable-default `ETunerConfig` bug stays fixed."""
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import ETunerConfig, ETunerController
from repro.core.lazytune import LazyTuneConfig
from repro.core.policies import (ImmediatePublish, ImmediateTrigger,
                                 LazyTuneTrigger, LegacyControllerAdapter,
                                 NoFreezePolicy, PolicySpec, PolicyStack,
                                 PolicyStackSpec, PriorityWeightedTrigger,
                                 RoundEndPublish, StalenessGuard,
                                 adapt_controller)
from repro.data.arrivals import Event
from repro.models import build_model
from repro.runtime import RuntimeConfig
from repro.runtime.continual import ContinualRuntime
from repro.runtime.inference import InferenceServer


@pytest.fixture(scope="module")
def model():
    return build_model(get_reduced("mobilenetv2"))


# ---------------------------------------------------------------------------
# satellite: shared-mutable-default ETunerConfig


def test_default_etuner_config_not_shared(model):
    """Regression (ISSUE satellite): `ETunerController(model)` used to
    default to one module-level ETunerConfig instance shared — and
    mutable — across every controller built with defaults; each default
    construction now gets a fresh config."""
    a = ETunerController(model)
    b = ETunerController(model)
    assert a.cfg is not b.cfg
    a.cfg.max_staleness = 5.0
    assert b.cfg.max_staleness is None
    assert ETunerController(model).cfg.max_staleness is None


# ---------------------------------------------------------------------------
# trigger policies


def test_staleness_guard_wraps_any_trigger():
    inner = LazyTuneTrigger(LazyTuneConfig())
    inner.lazytune.state.batches_needed = 4.0
    guard = StalenessGuard(inner, max_staleness=30.0)
    assert not guard.should_trigger(1, staleness=29.9)
    assert guard.should_trigger(1, staleness=30.0)
    assert not guard.should_trigger(0, staleness=99.0)  # empty buffer
    assert guard.should_trigger(4, staleness=0.0)       # inner still rules
    assert guard.lazytune is inner.lazytune             # transparent
    with pytest.raises(ValueError):
        StalenessGuard(inner, max_staleness=0.0)


def test_priority_weighted_scales_accumulation_target():
    """ISSUE tentpole: the accumulation target is jointly scaled by
    `StreamSpec.priority` — a priority-2 stream (weight 0.5 -> boost 2x)
    defers until twice the batches (keeping the shared device free for
    its latency-critical requests), a priority-0 stream behaves exactly
    like plain LazyTune."""
    trig = PriorityWeightedTrigger(LazyTuneConfig(), priority_weight=0.5)
    trig.lazytune.state.batches_needed = 4.0
    assert not trig.should_trigger(2, priority=0)
    assert not trig.should_trigger(3, priority=0)
    assert trig.should_trigger(4, priority=0)
    assert not trig.should_trigger(4, priority=2)  # 4 * (1 + 0.5*2) = 8
    assert not trig.should_trigger(7, priority=2)
    assert trig.should_trigger(8, priority=2)
    # rounds_delayed bookkeeping mirrors LazyTune's
    assert trig.lazytune.state.rounds_delayed == 4
    with pytest.raises(ValueError):
        PriorityWeightedTrigger(priority_weight=-1.0)


def test_priority_weighted_zero_weight_matches_lazytune():
    ref = LazyTuneTrigger(LazyTuneConfig())
    pw = PriorityWeightedTrigger(LazyTuneConfig(), priority_weight=0.0)
    for trig in (ref, pw):
        trig.lazytune.state.batches_needed = 3.0
    for n, p in [(1, 0), (2, 5), (3, 9), (4, 0)]:
        assert ref.should_trigger(n) == pw.should_trigger(n, priority=p)
    assert ref.lazytune.state.rounds_delayed == pw.lazytune.state.rounds_delayed


def test_priority_weighted_staleness_bounds_deferral():
    """ROADMAP: `max_staleness` and priority are used *jointly* — the
    spec builder wraps the priority-weighted trigger in the unscaled
    StalenessGuard, which caps how long priority may defer a round, so
    priority buys serving latency only up to the freshness contract."""
    from repro.core.policies import build_trigger

    trig = build_trigger(PolicySpec("priority-weighted",
                                    {"priority_weight": 0.5,
                                     "max_staleness": 30.0}))
    assert isinstance(trig, StalenessGuard)
    assert isinstance(trig.inner, PriorityWeightedTrigger)
    trig.lazytune.state.batches_needed = 10.0
    assert not trig.should_trigger(1, staleness=29.9, priority=2)
    assert trig.should_trigger(1, staleness=30.0, priority=2)
    assert trig.should_trigger(1, staleness=30.0, priority=0)
    assert not trig.should_trigger(0, staleness=99.0, priority=2)


def test_etuner_stack_spec_rejects_dead_lazytune_params():
    """`etuner_stack_spec(lazytune=False)` threads the initial target
    through to the immediate trigger's reported stats (ETunerConfig
    parity) and refuses params that would otherwise be dropped
    silently."""
    from repro.core.policies import etuner_stack_spec

    spec = etuner_stack_spec(
        lazytune=False, simfreeze=False, detect_scenario_changes=False,
        lazytune_params={"initial_batches_needed": 4.0})
    assert spec.trigger.params == {"batches_needed": 4.0}
    with pytest.raises(ValueError, match="have no effect"):
        etuner_stack_spec(lazytune=False,
                          lazytune_params={"max_batches_needed": 6.0})


def test_runtime_feeds_priority_to_trigger(model):
    """End-to-end: the runtime passes each stream's QoS priority into
    `should_trigger`, so a priority-aware stack sees it."""
    from repro.data import streams

    seen = []

    class Spy(PolicyStack):
        def should_trigger(self, n, staleness=0.0, priority=0):
            seen.append(priority)
            return False

    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=3,
                                 batch_size=8, seed=0)
    stack = Spy(model)
    rt = ContinualRuntime.from_config(
        RuntimeConfig(pretrain_epochs=1, seed=0),
        model=model, benchmark=bench, controller=stack,
        controller_factory=lambda st: stack)
    rt.run(events=[Event(1.0, "data", 1, 0, stream=0, priority=0),
                   Event(2.0, "data", 1, 0, stream=1, priority=3)])
    assert seen == [0, 3]


# ---------------------------------------------------------------------------
# legacy adapter


class _OldController:
    """Pre-QoS monolith: should_trigger(batches) only, no staleness, no
    priority, no publish_policy."""

    def __init__(self, model):
        self._plan = ETunerController(model).plan
        self.calls = []

    @property
    def plan(self):
        return self._plan

    def should_trigger(self, batches_available):
        self.calls.append(batches_available)
        return batches_available >= 1

    def round_finished(self, iters, val_acc, params):
        pass

    def inference_served(self, logits):
        return False

    def scenario_changed(self, params, probe):
        pass


def test_adapt_controller_wraps_only_legacy_signatures(model):
    new = ETunerController(model)
    assert adapt_controller(new) is new
    old = _OldController(model)
    adapted = adapt_controller(old)
    assert isinstance(adapted, LegacyControllerAdapter)
    # full-signal call reaches the one-arg monolith
    assert adapted.should_trigger(2, staleness=9.0, priority=5)
    assert old.calls == [2]
    assert adapted.plan is old.plan  # everything else forwards


def test_legacy_controller_drives_runtime(model):
    """A monolithic pre-stack controller still runs a full session
    through `controller_factory` (ISSUE tentpole: legacy adapter)."""
    from repro.data import streams

    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=3,
                                 batch_size=8, seed=0)
    ctrl = _OldController(model)
    rt = ContinualRuntime.from_config(
        RuntimeConfig(pretrain_epochs=1, seed=0),
        model=model, benchmark=bench, controller=ctrl)
    res = rt.run(inferences_total=4)
    assert res.rounds > 0 and ctrl.calls


# ---------------------------------------------------------------------------
# publish policies


class _IdModel:
    """predict() returns logits identifying the params object."""

    def predict(self, params, batch):
        return np.full((len(batch["labels"]), 2), float(params))


def test_immediate_publish_keeps_bug_compat_seam():
    srv = InferenceServer(_IdModel())
    srv.publish(0.0, 0.0)
    srv.publish(1.0, 10.0)            # round ends at t=10, default publish
    assert srv._resolve(5.0) == 1.0   # mid-round arrival sees new params
    assert srv._resolve(10.0) == 1.0


def test_round_end_publish_serves_pre_round_params_mid_round():
    """`RoundEndPublish` (delayed=True) retains the pre-round params for
    arrivals before the round's occupancy end — the genuinely-delayed
    §5 seam."""
    srv = InferenceServer(_IdModel())
    srv.publish(0.0, 0.0)
    srv.publish(1.0, 10.0, delayed=True)
    assert srv._resolve(5.0) == 0.0   # outdated model (paper §III-A)
    assert srv._resolve(10.0) == 1.0  # visible from the round's end
    srv.publish(2.0, 20.0, delayed=True)
    assert srv._resolve(15.0) == 1.0


def test_runtime_honors_publish_policy(model, monkeypatch):
    """The composition root publishes through the stream controller's
    `publish_policy`: RoundEndPublish flips the server's delayed flag,
    the default ImmediatePublish does not."""
    from repro.data import streams

    calls = []
    orig = InferenceServer.publish

    def spy(self, params, visible_at, slot="default", *, delayed=False):
        calls.append(delayed)
        return orig(self, params, visible_at, slot=slot, delayed=delayed)

    monkeypatch.setattr(InferenceServer, "publish", spy)
    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=3,
                                 batch_size=8, seed=0)

    def run(publish):
        calls.clear()
        stack = PolicyStack(model, publish=publish)
        rt = ContinualRuntime.from_config(
            RuntimeConfig(pretrain_epochs=1, seed=0),
            model=model, benchmark=bench, controller=stack)
        rt.run(events=[Event(1.0, "data", 1, 0),
                       Event(2.0, "inference", 1, 0)])
        # first publish is the t=0 bootstrap (never delayed), the rest
        # are round publishes
        return calls[0], set(calls[1:])

    boot, rounds = run(RoundEndPublish())
    assert boot is False and rounds == {True}
    boot, rounds = run(ImmediatePublish())
    assert boot is False and rounds == {False}


# ---------------------------------------------------------------------------
# stack composition and compat surface


def test_stack_spec_builds_equivalent_controller(model):
    spec = PolicyStackSpec(
        trigger=PolicySpec("lazytune", {"max_batches_needed": 6.0,
                                        "max_staleness": 30.0}),
        freeze=PolicySpec("simfreeze", {"freeze_interval": 6}),
        drift=PolicySpec("energy"))
    stack = spec.build(model)
    assert isinstance(stack.trigger, StalenessGuard)
    assert isinstance(stack.trigger.inner, LazyTuneTrigger)
    assert stack.lazytune.cfg.max_batches_needed == 6.0
    assert stack.simfreeze.cfg.freeze_interval == 6
    assert stack.detector is not None
    ctrl = ETunerController(model, ETunerConfig(
        lazytune_cfg=LazyTuneConfig(max_batches_needed=6.0),
        max_staleness=30.0))
    assert sorted(stack.stats()) == sorted(ctrl.stats())


def test_stack_compat_surface_mirrors_monolith(model):
    immed = PolicyStack(model)
    assert isinstance(immed.trigger, ImmediateTrigger)
    assert isinstance(immed.freeze, NoFreezePolicy)
    assert not hasattr(immed, "lazytune")
    assert not hasattr(immed, "simfreeze")
    assert not hasattr(immed, "detector")
    # stats keys stay exactly the monolith's across all ablations
    expected = {"rounds_triggered", "batches_needed", "frozen_fraction",
                "freezes", "unfreezes", "plan_changes", "ood_detections"}
    for lazy in (False, True):
        for freeze in (False, True):
            ctrl = ETunerController(model, ETunerConfig(
                lazytune=lazy, simfreeze=freeze,
                detect_scenario_changes=False))
            assert set(ctrl.stats()) == expected
    with pytest.raises(ValueError):
        PolicyStack()  # needs a freeze policy or a model


def test_unknown_policy_names_are_actionable(model):
    with pytest.raises(ValueError, match="known trigger policies"):
        PolicyStackSpec(trigger=PolicySpec("bogus")).build(model)
    with pytest.raises(ValueError, match="valid"):
        PolicyStackSpec(trigger=PolicySpec(
            "lazytune", {"nope": 1})).build(model)
    with pytest.raises(ValueError, match="known freeze policies"):
        PolicyStackSpec(freeze=PolicySpec("bogus")).validate()