"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas body vs
pure-jnp oracle (ref.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import ops as att_ops
from repro.kernels.attention import ref as att_ref
from repro.kernels.cka import ops as cka_ops
from repro.kernels.cka import ref as cka_ref
from repro.kernels.rwkv import ops as rwkv_ops
from repro.kernels.rwkv import ref as rwkv_ref

RNG = np.random.default_rng(42)


def _randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# CKA kernel


@pytest.mark.parametrize("n,d", [(64, 128), (200, 300), (256, 512), (100, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cka_kernel_matches_ref(n, d, dtype):
    x = _randn((n, d), dtype)
    y = jnp.asarray(0.3 * np.asarray(x, np.float32)
                    + RNG.normal(size=(n, d)), dtype)
    got = cka_ops.cka(x, y)
    xc = x.astype(jnp.float32) - x.astype(jnp.float32).mean(0)
    yc = y.astype(jnp.float32) - y.astype(jnp.float32).mean(0)
    want = cka_ref.cka_ref(xc, yc)
    np.testing.assert_allclose(float(got), float(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_cka_kernel_identical_inputs_is_one():
    x = _randn((128, 256))
    assert abs(float(cka_ops.cka(x, x)) - 1.0) < 1e-5


def test_cka_kernel_block_shape_independent():
    x = _randn((200, 700))
    y = _randn((200, 700))
    a = cka_ops.cka(x, y, bn=128, bk=512)
    b = cka_ops.cka(x, y, bn=64, bk=256)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention kernel


@pytest.mark.parametrize("S,Hq,Hkv,hd", [(128, 4, 4, 32), (256, 4, 2, 64),
                                         (192, 8, 1, 64)])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0), (0, 50.0),
                                            (48, 30.0)])
def test_flash_attention_matches_ref(S, Hq, Hkv, hd, window, softcap):
    B = 2
    q = _randn((B, S, Hq, hd))
    k = _randn((B, S, Hkv, hd))
    v = _randn((B, S, Hkv, hd))
    got = att_ops.flash_attention(q, k, v, window=window, softcap=softcap,
                                  bq=64, bk=64)
    want = att_ref.attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(dtype):
    B, S, H, hd = 1, 128, 2, 64
    q = _randn((B, S, H, hd), dtype)
    k = _randn((B, S, H, hd), dtype)
    v = _randn((B, S, H, hd), dtype)
    got = att_ops.flash_attention(q, k, v, bq=64, bk=64)
    want = att_ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_padding_path():
    # S not a multiple of the block size exercises ops.py padding
    B, S, H, hd = 1, 100, 2, 32
    q = _randn((B, S, H, hd))
    k = _randn((B, S, H, hd))
    v = _randn((B, S, H, hd))
    got = att_ops.flash_attention(q, k, v, bq=64, bk=64)
    want = att_ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_xla_matches_dense():
    """models/attention blockwise path == dense path (online softmax)."""
    from repro.configs import get_reduced
    from repro.models import attention as A

    cfg = get_reduced("qwen1.5-32b").replace(attn_q_block=32, attn_k_block=32)
    B, S = 2, 128
    q = _randn((B, S, cfg.num_heads, cfg.head_dim))
    k = _randn((B, S, cfg.num_kv_heads, cfg.head_dim))
    v = _randn((B, S, cfg.num_kv_heads, cfg.head_dim))
    pos = jnp.arange(S)
    dense = A._attend_dense(cfg, q, k, v, pos, pos, 0)
    block = A._attend_blockwise(cfg, q, k, v, pos, pos, 0)
    np.testing.assert_allclose(np.asarray(block), np.asarray(dense),
                               rtol=2e-4, atol=2e-5)
    # sliding window too
    dense_w = A._attend_dense(cfg, q, k, v, pos, pos, 48)
    block_w = A._attend_blockwise(cfg, q, k, v, pos, pos, 48)
    np.testing.assert_allclose(np.asarray(block_w), np.asarray(dense_w),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv wkv kernel


@pytest.mark.parametrize("T,H,n,bt", [(64, 2, 16, 32), (96, 1, 32, 32),
                                      (128, 4, 16, 64)])
def test_wkv_kernel_matches_ref(T, H, n, bt):
    B = 2
    r = _randn((B, T, H, n))
    k = _randn((B, T, H, n))
    v = _randn((B, T, H, n))
    logw = jnp.asarray(-np.abs(RNG.normal(size=(B, T, H, n))) * 0.5 - 0.05,
                       jnp.float32)
    u = _randn((H, n))
    got = rwkv_ops.wkv(r, k, v, logw, u, bt=bt)
    want, _ = rwkv_ref.wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_wkv_kernel_padding():
    B, T, H, n = 1, 50, 2, 16
    r = _randn((B, T, H, n))
    k = _randn((B, T, H, n))
    v = _randn((B, T, H, n))
    logw = jnp.asarray(-np.abs(RNG.normal(size=(B, T, H, n))) * 0.3 - 0.05,
                       jnp.float32)
    u = _randn((H, n))
    got = rwkv_ops.wkv(r, k, v, logw, u, bt=32)
    want, _ = rwkv_ref.wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunked_xla_matches_exact_ref():
    """The model's chunked closed form (with log-space clamping) vs the
    exact recurrence, at moderate decay strengths."""
    from repro.models.rwkv6 import wkv_chunked

    B, T, H, n = 2, 128, 2, 16
    r = _randn((B, T, H, n))
    k = _randn((B, T, H, n))
    v = _randn((B, T, H, n))
    logw = jnp.asarray(-np.clip(np.abs(RNG.normal(size=(B, T, H, n))) * 0.4,
                                0.02, 2.5), jnp.float32)
    u = _randn((H, n))
    got, s_got = wkv_chunked(r, k, v, logw, u, chunk=32)
    want, s_want = rwkv_ref.wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# runtime-facing `use_pallas` call-sites (DESIGN.md §12): the *same entry
# points the serving/probe paths dispatch* — a classifier's `predict`
# with `cfg.use_pallas` routing attention through the flash kernel, and
# the drift detector's CKA probe with `use_kernel` — must agree with
# their XLA forms on interpret-mode CPU.


def test_vit_predict_use_pallas_matches_xla():
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("deit-tiny")
    xla = build_model(cfg)
    pal = build_model(cfg.replace(use_pallas=True))
    params = xla.init(jax.random.PRNGKey(0))
    batch = {"images": _randn((4, cfg.image_size, cfg.image_size, 3))}
    np.testing.assert_allclose(np.asarray(pal.predict(params, batch)),
                               np.asarray(xla.predict(params, batch)),
                               rtol=2e-4, atol=2e-5)


def test_bert_predict_use_pallas_matches_xla():
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model

    cfg = get_reduced("bert-base")
    xla = build_model(cfg)
    pal = build_model(cfg.replace(use_pallas=True))
    params = xla.init(jax.random.PRNGKey(1))
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(4, 32)),
                         jnp.int32)
    np.testing.assert_allclose(
        np.asarray(pal.predict(params, {"tokens": tokens})),
        np.asarray(xla.predict(params, {"tokens": tokens})),
        rtol=2e-4, atol=2e-5)


def test_core_cka_use_kernel_matches_plain():
    from repro.core.cka import cka

    x = _randn((130, 64))
    y = jnp.asarray(0.5 * np.asarray(x, np.float32)
                    + RNG.normal(size=(130, 64)), jnp.float32)
    plain = float(cka(x, y))
    kernel = float(cka(x, y, use_kernel=True))
    assert abs(plain - kernel) < 1e-3
    assert abs(float(cka(x, x, use_kernel=True)) - 1.0) < 1e-3
