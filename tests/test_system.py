"""End-to-end behaviour tests for the continual-learning system (the
paper's main claims, at reduced scale):

- LazyTune cuts time/energy vs immediate fine-tuning at small accuracy cost
- SimFreeze freezes layers and reduces measured train-step FLOPs
- ETuner (both) dominates on time/energy
- scenario-change handling unfreezes and resets batches_needed
- checkpoint/restart mid-stream resumes losslessly
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (ETunerConfig, ETunerController, LazyTuneConfig,
                        SimFreezeConfig)
from repro.data import streams
from repro.models import build_model
from repro.runtime import RuntimeConfig
from repro.runtime.continual import ContinualRuntime


def _rt(model, bench, ctrl, **cfg_kw):
    return ContinualRuntime.from_config(RuntimeConfig(**cfg_kw),
                                        model=model, benchmark=bench,
                                        controller=ctrl)


@pytest.fixture(scope="module")
def bench():
    return streams.nc_benchmark(num_classes=10, num_scenarios=4, batches=16,
                                batch_size=16)


@pytest.fixture(scope="module")
def model():
    return build_model(get_reduced("mobilenetv2"))


def _run(model, bench, lazytune, simfreeze, seed=0, **kw):
    ecfg = ETunerConfig(
        lazytune=lazytune, simfreeze=simfreeze,
        detect_scenario_changes=False,
        lazytune_cfg=LazyTuneConfig(max_batches_needed=6),
        simfreeze_cfg=SimFreezeConfig(freeze_interval=10, min_history=3,
                                      cka_threshold=0.01))
    ctrl = ETunerController(model, ecfg)
    rt = _rt(model, bench, ctrl, pretrain_epochs=2, seed=seed, **kw)
    return rt.run(inferences_total=40)


@pytest.fixture(scope="module")
def results(model, bench):
    return {
        "immed": _run(model, bench, False, False),
        "lazy": _run(model, bench, True, False),
        "freeze": _run(model, bench, False, True),
        "etuner": _run(model, bench, True, True),
    }


def test_lazytune_saves_time_and_energy(results):
    assert results["lazy"].total_time_s < 0.85 * results["immed"].total_time_s
    assert results["lazy"].total_energy_j < 0.9 * results["immed"].total_energy_j
    assert results["lazy"].rounds < results["immed"].rounds


def test_simfreeze_freezes_and_cuts_flops(results):
    st = results["freeze"].controller_stats
    assert st["frozen_fraction"] > 0.2
    assert results["freeze"].compute_tflops < results["immed"].compute_tflops


def test_etuner_dominates_costs(results):
    assert results["etuner"].total_time_s < 0.85 * results["immed"].total_time_s
    assert results["etuner"].total_energy_j < 0.9 * results["immed"].total_energy_j


def test_accuracies_sane(results):
    for r in results.values():
        assert 0.05 < r.avg_inference_acc <= 1.0
        assert all(np.isfinite(a) for a in r.inference_accs)
    # lazy tuning should not collapse accuracy (paper: -0.22%; we allow a
    # loose bound at this scale)
    assert results["etuner"].avg_inference_acc > \
        results["immed"].avg_inference_acc - 0.08


def test_overhead_breakdown_recorded(results):
    bd = results["immed"].breakdown
    assert bd["t_overhead"] > 0 and bd["e_overhead"] > 0
    # immediate tuning is overhead-dominated (paper Fig. 3)
    assert bd["t_overhead"] / (bd["t_overhead"] + bd["t_compute"]) > 0.4


def test_scenario_change_resets(model, bench):
    ecfg = ETunerConfig(lazytune=True, simfreeze=True,
                        detect_scenario_changes=False,
                        simfreeze_cfg=SimFreezeConfig(freeze_interval=4))
    ctrl = ETunerController(model, ecfg)
    rt = _rt(model, bench, ctrl, pretrain_epochs=1)
    rt.run(inferences_total=16)
    assert ctrl.simfreeze.state.freezes >= 1
    assert ctrl.plan_changes >= 1


def test_detector_boundaries_mode_runs(model, bench):
    ecfg = ETunerConfig(lazytune=True, simfreeze=False,
                        detect_scenario_changes=True)
    ctrl = ETunerController(model, ecfg)
    rt = _rt(model, bench, ctrl, pretrain_epochs=1, boundaries="detector")
    res = rt.run(inferences_total=24)
    assert res.rounds > 0


def test_checkpoint_restart_resumes(tmp_path, model, bench):
    """Crash/restart fault-tolerance: params saved mid-run restore
    bit-exact on a fresh manager."""
    from repro.checkpoint import CheckpointManager

    params = model.init(jax.random.PRNGKey(3))
    mgr = CheckpointManager(str(tmp_path), use_async=True)
    mgr.save(11, params, block=True)
    mgr2 = CheckpointManager(str(tmp_path))   # "new process"
    restored, step = mgr2.restore_latest(params)
    assert step == 11
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
