"""Workload subsystem tests: spec validation, generator bit-
reproducibility and structure (ordering, stream tags, duty-cycle windows,
staggered drift), the two-stream runtime's per-stream cost attribution,
and the BENCH_workloads.json schema validator."""
import numpy as np
import pytest

from repro.workloads import (DutyCycle, StreamSpec, WorkloadSpec,
                             compile_workload, presets)

SPECS = presets(batches_per_scenario=6, inferences=16, num_scenarios=3)


# ---------------------------------------------------------------------------
# spec validation


def test_spec_validation_rejects_bad_configs():
    with pytest.raises(ValueError):
        WorkloadSpec("empty", ()).validate()
    with pytest.raises(ValueError):
        WorkloadSpec("bad-dist", (StreamSpec(data_dist="weibull"),)).validate()
    with pytest.raises(ValueError):
        WorkloadSpec("bad-drift", (StreamSpec(),), drift="chaos").validate()
    with pytest.raises(ValueError):  # modulated dists need their configs
        WorkloadSpec("no-cfg", (StreamSpec(inf_dist="mmpp"),)).validate()
    with pytest.raises(ValueError):
        WorkloadSpec("bad-duty", (StreamSpec(
            duty_cycle=DutyCycle(on_fraction=0.0)),)).validate()
    from repro.workloads import DiurnalConfig, MMPPConfig
    with pytest.raises(ValueError):  # rate would go negative (amplitude>1)
        WorkloadSpec("bad-diurnal", (StreamSpec(
            inf_dist="diurnal",
            diurnal=DiurnalConfig(amplitude=1.5)),)).validate()
    with pytest.raises(ValueError):  # non-positive multipliers
        WorkloadSpec("bad-mmpp", (StreamSpec(
            inf_dist="mmpp",
            mmpp=MMPPConfig(burst_mult=0.0)),)).validate()
    with pytest.raises(ValueError):  # QoS priority must be a non-neg int
        WorkloadSpec("bad-prio", (StreamSpec(priority=-1),)).validate()


# ---------------------------------------------------------------------------
# generators


@pytest.mark.parametrize("name", sorted(SPECS))
def test_compile_is_bit_reproducible(name):
    """The compiled timeline is a pure function of the spec — two compiles
    (and a compile of an equal copy) produce identical event lists."""
    spec = SPECS[name]
    first = compile_workload(spec)
    assert compile_workload(spec) == first
    import dataclasses
    assert compile_workload(dataclasses.replace(spec)) == first


@pytest.mark.parametrize("name", sorted(SPECS))
def test_compiled_timeline_structure(name):
    spec = SPECS[name]
    events = compile_workload(spec)
    times = [e.time for e in events]
    assert times == sorted(times)
    assert {e.stream for e in events} == set(range(len(spec.streams)))
    # scenario ids: 1..num_scenarios (0 is reserved for pretraining)
    assert {e.scenario for e in events} <= set(
        range(1, spec.num_scenarios + 1))
    for st, ss in enumerate(spec.streams):
        data = [e for e in events if e.stream == st and e.kind == "data"]
        inf = [e for e in events if e.stream == st and e.kind == "inference"]
        assert len(data) == spec.num_scenarios * ss.batches_per_scenario
        assert len(inf) == ss.inferences
        # data events stay inside their stream's scenario window
        off = spec.stream_offset(st) + ss.phase
        for e in data:
            s0 = off + (e.scenario - 1) * spec.scenario_span
            assert s0 <= e.time < s0 + spec.scenario_span


def test_seed_changes_timeline():
    a = compile_workload(SPECS["two-stream"])
    import dataclasses
    b = compile_workload(dataclasses.replace(SPECS["two-stream"], seed=7))
    assert a != b


def test_duty_cycle_windows_respected():
    """diurnal-duty only emits during the on-window of each duty period —
    for *every* event kind: data rides the duty warp, the diurnal NHPP
    composes the duty indicator into its rate (the scenario grid is a
    whole number of periods, so wall-clock modulo is well-defined)."""
    spec = SPECS["diurnal-duty"]
    dc = spec.streams[0].duty_cycle
    for e in compile_workload(spec):
        assert e.time % dc.period <= dc.period * dc.on_fraction + 1e-6, e


def test_warp_boundary_event_stays_in_on_window():
    """An arrival pinned to the very end of active time must not warp
    onto the next period's off-boundary (the rescale pins t[-1])."""
    spec = WorkloadSpec("pd", (StreamSpec(
        inf_dist="poisson", duty_cycle=DutyCycle(period=50.0,
                                                 on_fraction=0.6),
        batches_per_scenario=4, inferences=50),), num_scenarios=3,
        scenario_span=100.0).validate()
    for e in compile_workload(spec):
        assert e.time % 50.0 <= 30.0 + 1e-6, e


def test_diurnal_period_is_wall_clock_under_duty_cycle():
    """Composing diurnal with a duty cycle must not stretch the diurnal
    period: with period == 2 duty periods, arrivals concentrate in the
    sine's rising half of each wall-clock period."""
    from repro.workloads import DiurnalConfig

    spec = WorkloadSpec("dd", (StreamSpec(
        inf_dist="diurnal",
        diurnal=DiurnalConfig(period=100.0, amplitude=0.8),
        duty_cycle=DutyCycle(period=50.0, on_fraction=0.6),
        batches_per_scenario=4, inferences=200),), num_scenarios=3,
        scenario_span=100.0).validate()
    t = np.array([e.time for e in compile_workload(spec)
                  if e.kind == "inference"]) % 100.0
    # sin peaks at t%100 == 25, troughs at 75
    assert np.sum(t < 50.0) > 1.5 * np.sum(t >= 50.0)


def test_qos_preset_threads_priorities_onto_events():
    """The qos preset mixes a latency-critical stream with a bulk one;
    `compile_workload` stamps each stream's priority on every one of its
    events, and equal-time ties sort higher priority first (after kind)."""
    spec = SPECS["qos"]
    prios = [s.priority for s in spec.streams]
    assert prios[0] > prios[1] == 0
    events = compile_workload(spec)
    for e in events:
        assert e.priority == spec.streams[e.stream].priority
    assert {e.priority for e in events} == set(prios)


def test_modality_binds_streams_to_model_slots():
    """`StreamSpec.modality` is no longer metadata: compile_workload
    stamps it on every event the stream emits (the ModelPool slot
    binding), `WorkloadSpec.modalities` lists the slots a pool must
    provide, and the faithful mixed preset really names an NLP/20news
    stream."""
    spec = SPECS["mixed"]
    assert spec.modalities == ("cv", "nlp")
    assert spec.streams[1].benchmark == "20news"
    for e in compile_workload(spec):
        assert e.modality == spec.streams[e.stream].modality
    assert SPECS["single-poisson"].modalities == ("cv",)
    with pytest.raises(ValueError):
        WorkloadSpec("bad-mod", (StreamSpec(modality=""),)).validate()


def test_staggered_drift_offsets_streams():
    """two-stream is staggered: stream 1 crosses each scenario boundary
    half a span after stream 0."""
    spec = SPECS["two-stream"]
    events = compile_workload(spec)

    def first_data(stream, scenario):
        return min(e.time for e in events
                   if e.stream == stream and e.kind == "data"
                   and e.scenario == scenario)

    off = spec.stream_offset(1)
    assert off == pytest.approx(spec.scenario_span / 2)
    for sc in range(1, spec.num_scenarios + 1):
        lo = off + (sc - 1) * spec.scenario_span
        assert lo <= first_data(1, sc) < lo + spec.scenario_span


def test_mmpp_is_burstier_than_poisson():
    """Fixed-seed sanity: the MMPP stream's inter-arrival squared
    coefficient of variation exceeds the Poisson stream's (bursts =
    overdispersion)."""
    def scv(spec):
        t = np.array([e.time for e in compile_workload(spec)
                      if e.kind == "inference"])
        gaps = np.diff(np.sort(t))
        return float(np.var(gaps) / np.mean(gaps) ** 2)

    big = presets(batches_per_scenario=4, inferences=160, num_scenarios=3)
    assert scv(big["bursty-mmpp"]) > scv(big["single-poisson"]) * 1.5


# ---------------------------------------------------------------------------
# two-stream runtime: per-stream attribution


@pytest.fixture(scope="module")
def two_stream_result():
    from repro.configs import get_reduced
    from repro.core import ETunerConfig, ETunerController
    from repro.data import streams
    from repro.models import build_model
    from repro.runtime.continual import ContinualRuntime

    spec = WorkloadSpec(
        "tiny-two-stream",
        (StreamSpec(batches_per_scenario=3, inferences=5),
         StreamSpec(benchmark="ni", batches_per_scenario=3, inferences=5)),
        num_scenarios=2, drift="staggered", seed=0).validate()
    model = build_model(get_reduced("mobilenetv2"))

    def make(_st=0):
        return ETunerController(model, ETunerConfig(
            lazytune=False, simfreeze=False, detect_scenario_changes=False))

    b0 = streams.nc_benchmark(num_scenarios=3, batches=3, batch_size=8,
                              seed=0)
    b1 = streams.ni_benchmark(num_scenarios=3, batches=3, batch_size=8,
                              seed=13)
    from repro.runtime import RuntimeConfig

    rt = ContinualRuntime.from_config(
        RuntimeConfig(pretrain_epochs=1, seed=0),
        model=model, benchmark=b0, controller=make(),
        stream_benchmarks={1: b1}, controller_factory=make)
    return rt.run(events=compile_workload(spec))


def test_two_stream_ledger_attribution_sums_to_totals(two_stream_result):
    res = two_stream_result
    assert set(res.per_stream) == {0, 1}
    assert res.per_stream[0]["rounds"] > 0 and res.per_stream[1]["rounds"] > 0
    for key, total in (("time_s", res.total_time_s),
                       ("energy_j", res.total_energy_j),
                       ("rounds", float(res.rounds))):
        np.testing.assert_allclose(
            sum(v[key] for v in res.per_stream.values()), total, rtol=1e-9)
    np.testing.assert_allclose(
        sum(v["flops"] for v in res.per_stream.values()),
        res.compute_tflops * 1e12, rtol=1e-9)


def test_two_stream_per_request_accounting(two_stream_result):
    res = two_stream_result
    assert res.per_stream[0]["inferences"] == 5.0
    assert res.per_stream[1]["inferences"] == 5.0
    assert len(res.inference_accs) == 10
    # global average is the request-weighted mean of per-stream averages
    weighted = sum(v["avg_inference_acc"] * v["inferences"]
                   for v in res.per_stream.values()) / 10.0
    np.testing.assert_allclose(res.avg_inference_acc, weighted, atol=1e-9)


# ---------------------------------------------------------------------------
# BENCH schema validator


def _valid_doc():
    import benchmarks.workloads as W

    cell = {f: 1.0 for f in W.CELL_FIELDS}
    cell["devices"] = 1
    stream_cell = {f: 1.0 for f in W.STREAM_FIELDS}
    model_cell = {f: 1.0 for f in W.MODEL_FIELDS}
    device_cell = {f: 1.0 for f in W.DEVICE_FIELDS}
    cell["energy_budget_j"] = 0.0     # v7: mains-powered by default
    cells = [dict(cell, workload=w, method=m, trigger_policy="default",
                  throttle="none",
                  per_stream={"0": dict(stream_cell)},
                  per_model={"default": dict(model_cell)},
                  per_device={"dev0": dict(device_cell)})
             for w in ("a", "b", "c") for m in W.METHODS]
    return W, {
        "schema_version": W.SCHEMA_VERSION, "suite": "workloads",
        "arch": "mobilenetv2", "created_unix": 1, "quick": True,
        "workloads": {"a": {}, "b": {}, "c": {}}, "cells": cells,
    }


def test_bench_schema_validator_accepts_valid_doc():
    W, doc = _valid_doc()
    assert W.validate_bench(doc) == []


def test_bench_schema_validator_flags_violations():
    W, doc = _valid_doc()
    assert W.validate_bench({}) != []
    bad = dict(doc, schema_version=99)
    assert any("schema_version" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=doc["cells"][:4])       # one workload only
    assert any("workload" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    del bad["cells"][0]["acc"]
    assert any("'acc'" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    bad["cells"][0]["time_s"] = float("nan")
    assert any("time_s" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    del bad["cells"][0]["preemptible"]            # v2 QoS cell fields
    assert any("preemptible" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=doc["cells"][1:])       # missing one controller
    assert any("missing controllers" in e for e in W.validate_bench(bad))
    # v2: per-stream attributions must carry the serving-latency columns
    bad = dict(doc, cells=[dict(c, per_stream={"0": dict(c["per_stream"]["0"])})
                           for c in doc["cells"]])
    del bad["cells"][0]["per_stream"]["0"]["latency_p95"]
    assert any("latency_p95" in e for e in W.validate_bench(bad))
    # v3: every cell must carry a non-empty per-model attribution
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    del bad["cells"][0]["per_model"]
    assert any("per_model" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c, per_model={"default": dict(
        c["per_model"]["default"])}) for c in doc["cells"]])
    del bad["cells"][0]["per_model"]["default"]["swaps"]
    assert any("'swaps'" in e for e in W.validate_bench(bad))
    # v4: every cell names its trigger policy, and a qos preset without
    # its priority-weighted cell is a coverage regression
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    del bad["cells"][0]["trigger_policy"]
    assert any("trigger_policy" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c, workload="qos") for c in doc["cells"]])
    assert any("priority-weighted" in e for e in W.validate_bench(
        bad, min_workloads=1))
    # v6: every cell carries a per-device attribution consistent with its
    # `devices` count, and a fleet preset must include a multi-device cell
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    del bad["cells"][0]["per_device"]
    assert any("per_device" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c, devices=2) for c in doc["cells"]])
    assert any("devices" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c, per_device={"dev0": dict(
        c["per_device"]["dev0"])}) for c in doc["cells"]])
    del bad["cells"][0]["per_device"]["dev0"]["utilization"]
    assert any("utilization" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c, workload="fleet") for c in doc["cells"]])
    assert any(">= 2" in e for e in W.validate_bench(bad, min_workloads=1))
    # v7: every cell names its throttle mode; a fleet preset must carry
    # an env cell in which the environment demonstrably engaged, and an
    # env cell overdrawing its battery budget is a violation
    bad = dict(doc, cells=[dict(c) for c in doc["cells"]])
    del bad["cells"][0]["throttle"]
    assert any("'throttle'" in e for e in W.validate_bench(bad))
    bad = dict(doc, cells=[dict(c, workload="fleet", devices=1)
                           for c in doc["cells"]])
    assert any("env cell" in e for e in W.validate_bench(
        bad, min_workloads=1))
    idle = dict(doc["cells"][0]["per_device"]["dev0"],
                battery_dead=0.0, throttle_s=0.0, energy_j=49.0)
    env = dict(doc, cells=[dict(c, workload="fleet", throttle="battery",
                                energy_budget_j=50.0,
                                per_device={"dev0": dict(idle)})
                           for c in doc["cells"]])
    # per_device shows no battery_dead/throttle_s/evicted activity
    assert any("env not engaged" in e for e in W.validate_bench(
        env, min_workloads=1))
    hot = dict(idle, throttle_s=5.0)
    ok_env = dict(doc, cells=[dict(c, workload="fleet",
                                   throttle="battery",
                                   energy_budget_j=50.0,
                                   per_device={"dev0": dict(hot)})
                              for c in doc["cells"]])
    errs = W.validate_bench(ok_env, min_workloads=1)
    assert not any("env" in e for e in errs)
    over = dict(hot, energy_j=51.0)   # ledger energy > battery budget
    bad = dict(ok_env, cells=[dict(c, per_device={"dev0": dict(over)})
                              for c in ok_env["cells"]])
    assert any("exceeds" in e for e in W.validate_bench(
        bad, min_workloads=1))


# ---------------------------------------------------------------------------
# bench_diff: BENCH trajectory regression gate (CI tooling)


def _diff_docs():
    def cell():
        return {"workload": "w", "method": "immed", "preemptible": 0,
                "acc": 0.5, "time_s": 10.0, "energy_j": 100.0,
                "tflops": 1.0, "rounds": 5, "recompiles": 1,
                "preemptions": 0, "swaps": 0,
                "per_stream": {"0": {"latency_p50": 0.0,
                                     "latency_p95": 2.0}},
                "per_model": {"default": {"time_s": 10.0,
                                          "energy_j": 100.0,
                                          "flops": 1e9,
                                          "avg_inference_acc": 0.5}}}
    base = {"schema_version": 3, "cells": [cell()]}
    new = {"schema_version": 3, "cells": [cell()]}
    return base, new


def test_bench_diff_within_noise_passes():
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    new["cells"][0]["time_s"] = 10.3   # +3% < 5% threshold
    new["cells"][0]["acc"] = 0.49      # -2% < 5% threshold
    regressions, _ = BD.diff_cells(base, new, threshold=0.05)
    assert regressions == []


def test_bench_diff_flags_directional_regressions():
    """acc regresses *down*, modeled costs regress *up*; improvements in
    either direction never fail."""
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    new["cells"][0]["acc"] = 0.4       # -20%: regression at acc thr 5%
    new["cells"][0]["time_s"] = 12.0   # +20%: regression
    new["cells"][0]["energy_j"] = 80.0  # -20%: improvement, not a failure
    regressions, infos = BD.diff_cells(base, new, threshold=0.05,
                                       acc_threshold=0.05)
    assert len(regressions) == 2
    assert any("acc" in r for r in regressions)
    assert any("time_s" in r for r in regressions)
    assert any("energy_j" in i and "improvement" in i for i in infos)


def test_bench_diff_acc_has_its_own_wider_threshold():
    """A borderline-request flip (float drift across machines) moves acc
    by a few % relative — inside the default acc threshold even when the
    cost threshold is tight; a genuine accuracy collapse still fails."""
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    new["cells"][0]["acc"] = 0.45      # -10%: within default acc noise
    regressions, _ = BD.diff_cells(base, new, threshold=0.05)
    assert regressions == []
    new["cells"][0]["acc"] = 0.3       # -40%: a real collapse
    regressions, _ = BD.diff_cells(base, new, threshold=0.05)
    assert len(regressions) == 1 and "acc" in regressions[0]


def test_bench_diff_missing_cell_is_a_regression():
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    new["cells"] = []
    regressions, _ = BD.diff_cells(base, new)
    assert len(regressions) == 1 and "missing" in regressions[0]


def test_bench_diff_new_cell_and_preemptible_key():
    """`preemptible` participates in cell identity (a prioritized preset
    runs once per QoS mode); a cell present only in the new artifact is
    informational."""
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    extra = dict(new["cells"][0], preemptible=1)
    new["cells"].append(extra)
    regressions, infos = BD.diff_cells(base, new)
    assert regressions == []
    assert any("new cell" in i and "+preempt" in i for i in infos)


def test_bench_diff_gates_per_stream_latency():
    """ISSUE satellite: serving-latency columns are gated directionally —
    p95 up beyond threshold fails, improvements and sub-millisecond moves
    on a ~0 baseline never do."""
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    new["cells"][0]["per_stream"]["0"]["latency_p95"] = 3.0   # +50%
    regressions, _ = BD.diff_cells(base, new, threshold=0.05)
    assert len(regressions) == 1 and "latency_p95" in regressions[0]
    base, new = _diff_docs()
    new["cells"][0]["per_stream"]["0"]["latency_p95"] = 1.0   # improvement
    # p50 moves hugely in relative terms but only by half a millisecond
    new["cells"][0]["per_stream"]["0"]["latency_p50"] = 5e-4
    regressions, infos = BD.diff_cells(base, new, threshold=0.05)
    assert regressions == []
    assert any("latency_p95" in i and "improvement" in i for i in infos)


def test_bench_diff_gates_per_model_columns():
    """ISSUE satellite: per-model slot costs regress upward, slot
    accuracy downward (wider acc threshold), and a vanished slot entry
    fails the diff."""
    import benchmarks.bench_diff as BD

    base, new = _diff_docs()
    new["cells"][0]["per_model"]["default"]["time_s"] = 12.0   # +20%
    new["cells"][0]["per_model"]["default"]["avg_inference_acc"] = 0.3
    regressions, _ = BD.diff_cells(base, new, threshold=0.05)
    assert any("per_model[default]" in r and "time_s" in r
               for r in regressions)
    assert any("avg_inference_acc" in r for r in regressions)
    base, new = _diff_docs()
    new["cells"][0]["per_model"] = {}
    regressions, _ = BD.diff_cells(base, new)
    assert len(regressions) == 1 and "per_model[default] missing" \
        in regressions[0]


def test_bench_diff_cli_exit_codes(tmp_path):
    import json as _json
    import os
    import subprocess
    import sys

    base, new = _diff_docs()
    new["cells"][0]["time_s"] = 20.0
    p_base, p_new = tmp_path / "base.json", tmp_path / "new.json"
    p_base.write_text(_json.dumps(base))
    p_new.write_text(_json.dumps(new))
    script = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "bench_diff.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(script), "..")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    ok = subprocess.run([sys.executable, script, str(p_base), str(p_base)],
                        env=env, capture_output=True)
    assert ok.returncode == 0
    bad = subprocess.run([sys.executable, script, str(p_base), str(p_new)],
                         env=env, capture_output=True)
    assert bad.returncode == 1
    assert b"REGRESSION" in bad.stderr
    mismatched = dict(new, schema_version=1)
    p_new.write_text(_json.dumps(mismatched))
    inc = subprocess.run([sys.executable, script, str(p_base), str(p_new)],
                         env=env, capture_output=True)
    assert inc.returncode == 2
