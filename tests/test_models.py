"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs; plus
prefill/decode consistency for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, PAPER_MODELS, get_reduced
from repro.models import build_model

RNG = jax.random.PRNGKey(7)


def _lm_batch(cfg, B=2, S=32):
    tok = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, axis=1)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.random.normal(
            RNG, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _lm_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_serve(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 16
    batch = _lm_batch(cfg, B, S)
    logits, cache = model.prefill(params, {k: v for k, v in batch.items()
                                           if k != "targets"})
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch
    logits2, cache2 = model.decode(params, batch["tokens"][:, -1:], cache,
                                   jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any()), arch


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen1.5-32b", "rwkv6-3b",
                                  "musicgen-medium"])
def test_prefill_decode_consistency(arch):
    """Decoding token t from the cache must match the full-sequence forward
    at position t (validates KV caches and recurrent states)."""
    cfg = get_reduced(arch)
    if cfg.family in ("vlm",):
        pytest.skip("frontend prefix offsets positions")
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 24
    tok = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)

    # ground truth: prefill over the full sequence gives last-position logits
    full_logits, _ = model.prefill(params, {"tokens": tok})

    # serve path: prefill S-1 tokens then decode the last one
    logits_part, cache = model.prefill(params, {"tokens": tok[:, :-1]})
    # extend attention caches to S (prefill sized them S-1)
    def ext(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if names and names[-1] in ("k", "v"):
            ax = leaf.ndim - 3
            pad = [(0, 0)] * leaf.ndim
            pad[ax] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf

    cache = jax.tree_util.tree_map_with_path(ext, cache)
    dec_logits, _ = model.decode(params, tok[:, -1:], cache, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ARCHS[:4])
def test_scan_equals_unrolled(arch):
    """scan-over-layers and unrolled execution compute the same function."""
    cfg_s = get_reduced(arch)
    cfg_u = cfg_s.replace(scan_layers=False)
    m_s = build_model(cfg_s)
    m_u = build_model(cfg_u)
    params = m_s.init(RNG)
    # re-layout stacked params to per-layer lists
    import jax as _jax

    G = m_s.num_freeze_units
    blocks_u = tuple(
        [_jax.tree.map(lambda a: a[gi], off_tree) for gi in range(G)]
        for off_tree in params["blocks"])
    params_u = dict(params, blocks=blocks_u)
    batch = _lm_batch(cfg_s)
    l_s, _ = m_s.loss(params, batch)
    l_u, _ = m_u.loss(params_u, batch)
    np.testing.assert_allclose(float(l_s), float(l_u), rtol=2e-3)


@pytest.mark.parametrize("name", sorted(PAPER_MODELS))
def test_paper_model_smoke(name):
    cfg = get_reduced(name)
    model = build_model(cfg)
    params = model.init(RNG)
    B = 4
    if cfg.family == "encoder":
        batch = {"tokens": jax.random.randint(RNG, (B, 32), 0, cfg.vocab_size),
                 "labels": jnp.zeros((B,), jnp.int32)}
    else:
        batch = {"images": jax.random.normal(
            RNG, (B, cfg.image_size, cfg.image_size, 3)),
            "labels": jnp.zeros((B,), jnp.int32)}
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    feats = model.features(params, batch)
    assert len(feats) >= model.num_freeze_units - 2
    logits = model.predict(params, batch)
    assert logits.shape == (B, cfg.num_classes)


def test_mrope_matches_rope_for_text():
    """Text-only M-RoPE (equal t/h/w positions) == plain RoPE."""
    from repro.models import common

    B, S, H, hd = 2, 16, 2, 24
    x = jax.random.normal(RNG, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    plain = common.apply_rope(x, pos, 10000.0)
    m = common.apply_mrope(x, jnp.stack([pos] * 3), 10000.0, (4, 4, 4))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(m),
                               rtol=1e-5, atol=1e-5)
