"""Unit tests for the decomposed runtime pieces: EventScheduler ordering
and `busy_until` semantics, CostLedger accounting, and the
InferenceServer's arrival-time params policy + micro-batched coalescing
(with a stub model — no jit, no training)."""
import numpy as np
import pytest

from repro.data.arrivals import Event
from repro.runtime.inference import InferenceServer
from repro.runtime.ledger import BREAKDOWN_KEYS, CostLedger
from repro.runtime.scheduler import EventScheduler


# ---------------------------------------------------------------------------
# EventScheduler


def _drain(sched):
    order = []
    sched.run(on_data=lambda ev, b: order.append(("data", ev.time, b)),
              on_inference=lambda ev: order.append(("inf", ev.time)))
    return order


def test_scheduler_orders_events_by_time():
    sched = EventScheduler()
    for t in (5.0, 1.0, 3.0):
        sched.push(Event(t, "data", 0, 0))
    sched.push(Event(2.0, "inference", 0, 0))
    order = _drain(sched)
    assert [o[1] for o in order] == [1.0, 2.0, 3.0, 5.0]
    assert sched.dispatched == 4
    assert len(sched) == 0


def test_scheduler_data_before_inference_on_ties():
    """Ties dispatch data first — matching build_timeline's (time, kind)
    sort, so a pre-built timeline replays in its constructed order."""
    sched = EventScheduler([Event(1.0, "inference", 0, 0),
                            Event(1.0, "data", 0, 0)])
    order = _drain(sched)
    assert [o[0] for o in order] == ["data", "inf"]


def test_scheduler_stable_for_equal_keys():
    sched = EventScheduler([Event(1.0, "data", 0, i) for i in range(5)])
    seen = []
    sched.run(on_data=lambda ev, b: seen.append(ev.index),
              on_inference=lambda ev: None)
    assert seen == [0, 1, 2, 3, 4]


def test_scheduler_dispatches_probe_events_to_on_probe():
    """Probe events (detector-driven drift confirmation) route to the
    dedicated callback, run *after* colliding data/inference events at
    the same timestamp, and are dropped — never misrouted to
    on_inference — when no handler is wired."""
    sched = EventScheduler([Event(1.0, "probe", 1, 0, stream=2),
                            Event(1.0, "data", 1, 0),
                            Event(2.0, "inference", 1, 0)])
    order = []
    sched.run(on_data=lambda ev, b: order.append(("data", ev.time)),
              on_inference=lambda ev: order.append(("inf", ev.time)),
              on_probe=lambda ev: order.append(("probe", ev.time,
                                                ev.stream)))
    assert order == [("data", 1.0), ("probe", 1.0, 2), ("inf", 2.0)]
    sched = EventScheduler([Event(1.0, "probe", 1, 0),
                            Event(2.0, "inference", 1, 0)])
    assert _drain(sched) == [("inf", 2.0)]   # no handler: dropped


def test_scheduler_busy_until_serializes_rounds():
    sched = EventScheduler()
    start, end = sched.occupy(2.0, 3.0)
    assert (start, end) == (2.0, 5.0)
    assert not sched.idle_at(4.9) and sched.idle_at(5.0)
    # a round requested while busy starts only when the device frees up
    start, end = sched.occupy(3.0, 1.0)
    assert (start, end) == (5.0, 6.0)
    assert sched.busy_until == 6.0


def test_scheduler_per_device_occupancy_is_independent():
    """DeviceFleet (DESIGN.md §13): each fleet device owns its own
    occupancy lane — one device's in-flight round never delays another's,
    and the legacy scalar views stay aliases of the default device."""
    sched = EventScheduler()
    s0 = sched.occupy(2.0, 3.0)                       # default device
    s1 = sched.occupy(2.0, 1.0, device="jetson1")     # concurrent lane
    assert (s0.start, s0.end) == (2.0, 5.0)
    assert (s1.start, s1.end) == (2.0, 3.0)           # not serialized
    assert sched.busy_until_of() == 5.0
    assert sched.busy_until_of("jetson1") == 3.0
    assert sched.idle_at(3.0, device="jetson1") and not sched.idle_at(3.0)
    # queued work serializes only within its own device
    s2 = sched.occupy(2.5, 1.0, device="jetson1")
    assert (s2.start, s2.end) == (3.0, 4.0)
    assert sched.busy_until_of() == 5.0               # untouched
    # legacy scalar views alias the default device
    assert sched.busy_until == 5.0
    assert sched.reservation is sched.reservation_of()
    assert sched.reservation_of("jetson1") is s2
    sched.busy_until = 7.0
    assert sched.busy_until_of() == 7.0
    assert set(sched.devices) >= {"jetson1"}


def test_scheduler_scenario_boundary_bookkeeping():
    events = [Event(0.5, "data", 0, 0), Event(1.0, "data", 1, 0),
              Event(1.5, "inference", 1, 0), Event(2.0, "data", 2, 0)]
    sched = EventScheduler(events)
    changes = []
    flags = []
    sched.run(on_data=lambda ev, b: flags.append(b),
              on_inference=lambda ev: None,
              on_scenario_change=lambda prev, ev: changes.append(
                  (prev, ev.scenario)))
    assert changes == [(0, 1), (1, 2)]
    assert flags == [False, True, True]
    assert sched.current_scenario == 2


def test_scheduler_accepts_mid_run_pushes():
    sched = EventScheduler([Event(1.0, "data", 0, 0)])
    seen = []

    def on_data(ev, boundary):
        seen.append(ev.time)
        if ev.time == 1.0:  # inject follow-up work while draining
            sched.push(Event(4.0, "data", 0, 1))

    sched.run(on_data=on_data, on_inference=lambda ev: None)
    assert seen == [1.0, 4.0]


def test_scheduler_per_stream_scenario_counters():
    """Streams drift independently: each stream's boundary fires on *its*
    scenario progression, not the interleaved global one."""
    events = [Event(1.0, "data", 1, 0, stream=0),
              Event(2.0, "data", 1, 0, stream=1),   # same scenario, new stream
              Event(3.0, "data", 2, 1, stream=0),   # stream 0 drifts first
              Event(4.0, "data", 1, 1, stream=1),   # stream 1 still in 1
              Event(5.0, "data", 2, 2, stream=1)]   # now stream 1 drifts
    sched = EventScheduler(events)
    boundaries = []
    changes = []
    sched.run(on_data=lambda ev, b: boundaries.append((ev.stream, ev.scenario, b)),
              on_inference=lambda ev: None,
              on_scenario_change=lambda prev, ev: changes.append(
                  (ev.stream, prev, ev.scenario)))
    assert boundaries == [(0, 1, True), (1, 1, True), (0, 2, True),
                          (1, 1, False), (1, 2, True)]
    assert changes == [(0, 0, 1), (1, 0, 1), (0, 1, 2), (1, 1, 2)]
    assert sched.scenario_of(0) == 2 and sched.scenario_of(1) == 2
    assert sched.streams == [0, 1]


def test_scheduler_multi_stream_dispatch_deterministic():
    """Dispatch over interleaved streams is time-ordered and identical
    across replays (ties: data before inference, then insertion order)."""
    events = [Event(3.0, "inference", 1, 0, stream=1),
              Event(3.0, "data", 1, 0, stream=0),
              Event(1.0, "data", 1, 0, stream=1),
              Event(2.0, "data", 1, 1, stream=1),
              Event(2.0, "inference", 1, 0, stream=0)]
    orders = []
    for _ in range(2):
        sched = EventScheduler(events)
        seen = []
        sched.run(on_data=lambda ev, b: seen.append(("d", ev.time, ev.stream)),
                  on_inference=lambda ev: seen.append(("i", ev.time, ev.stream)))
        orders.append(seen)
    assert orders[0] == orders[1]
    assert orders[0] == [("d", 1.0, 1), ("d", 2.0, 1), ("i", 2.0, 0),
                         ("d", 3.0, 0), ("i", 3.0, 1)]
    assert [t for _, t, _ in orders[0]] == sorted(t for _, t, _ in orders[0])


def test_scheduler_priority_orders_equal_time_events():
    """QoS tie-break: at equal timestamps, kind still wins (data before
    inference), then higher `Event.priority` dispatches first; priority-0
    timelines keep the exact legacy (time, kind, insertion) order."""
    sched = EventScheduler([Event(1.0, "inference", 0, 0, stream=0),
                            Event(1.0, "inference", 0, 1, stream=1, priority=5),
                            Event(1.0, "data", 0, 0, stream=2),
                            Event(1.0, "data", 0, 1, stream=3, priority=1)])
    seen = []
    sched.run(on_data=lambda ev, b: seen.append(("d", ev.stream)),
              on_inference=lambda ev: seen.append(("i", ev.stream)))
    assert seen == [("d", 3), ("d", 2), ("i", 1), ("i", 0)]


def test_reservation_unpacks_and_preempts():
    """`occupy` returns a Reservation that legacy callers tuple-unpack; a
    preemptible one can be split by a strictly-higher-priority arrival,
    rewinding `busy_until` so the remainder can be re-reserved."""
    sched = EventScheduler()
    res = sched.occupy(1.0, 4.0, stream=1, priority=1, preemptible=True)
    start, end = res
    assert (start, end) == (1.0, 5.0)
    assert res.duration == pytest.approx(4.0)
    assert sched.can_preempt(2.0, 2)
    assert not sched.can_preempt(2.0, 1)    # equal priority never preempts
    assert not sched.can_preempt(5.0, 9)    # past the reservation's end
    remaining = sched.preempt(2.0)
    assert remaining == pytest.approx(3.0)
    assert sched.busy_until == 2.0 and res.end == 2.0
    res2 = sched.occupy(2.0, remaining, stream=1, priority=1,
                        preemptible=True)
    assert (res2.start, res2.end) == (2.0, 5.0)  # round end unchanged


def test_non_preemptible_reservation_cannot_be_split():
    sched = EventScheduler()
    sched.occupy(0.0, 2.0)  # legacy call: not preemptible
    assert not sched.can_preempt(1.0, 99)
    with pytest.raises(ValueError):
        sched.preempt(1.0)  # inside the interval, but not preemptible
    assert sched.busy_until == 2.0  # occupancy untouched
    with pytest.raises(ValueError):
        sched.preempt(3.0)  # outside any reservation


def test_scheduler_single_stream_current_scenario_legacy():
    """`current_scenario` keeps its pre-multi-stream meaning for stream-0
    timelines (the golden regression path)."""
    sched = EventScheduler([Event(1.0, "data", 1, 0), Event(2.0, "data", 2, 0)])
    sched.run(on_data=lambda ev, b: None, on_inference=lambda ev: None)
    assert sched.current_scenario == 2 == sched.scenario_of(0)


# ---------------------------------------------------------------------------
# CostLedger


def test_ledger_accumulates_rounds_and_probes():
    led = CostLedger()
    assert set(led.breakdown) == set(BREAKDOWN_KEYS)
    parts = {"t_compute": 1.0, "t_overhead": 2.0,
             "e_compute": 10.0, "e_overhead": 5.0}
    led.charge_round(flops=3e12, time_s=3.0, energy_j=15.0, parts=parts)
    led.charge_round(flops=1e12, time_s=3.0, energy_j=15.0, parts=parts)
    led.charge_probe("cka", 0.5, 2.5)
    assert led.rounds == 2
    assert led.total_time_s == pytest.approx(6.5)
    assert led.total_energy_j == pytest.approx(32.5)
    assert led.compute_tflops == pytest.approx(4.0)
    assert led.breakdown["t_compute"] == pytest.approx(2.0)
    assert led.breakdown["t_cka"] == pytest.approx(0.5)
    assert led.breakdown["e_cka"] == pytest.approx(2.5)
    # totals always reconcile with the breakdown
    assert sum(led.breakdown[k] for k in
               ("t_compute", "t_overhead", "t_cka")) == pytest.approx(
                   led.total_time_s)


def test_ledger_per_stream_attribution_sums_to_totals():
    led = CostLedger()
    parts = {"t_compute": 1.0, "t_overhead": 2.0,
             "e_compute": 10.0, "e_overhead": 5.0}
    led.charge_round(flops=2e12, time_s=3.0, energy_j=15.0, parts=parts,
                     stream=0)
    led.charge_round(flops=1e12, time_s=3.0, energy_j=15.0, parts=parts,
                     stream=1)
    led.charge_round(flops=1e12, time_s=3.0, energy_j=15.0, parts=parts,
                     stream=1)
    led.charge_probe("cka", 0.5, 2.5, stream=1)
    assert set(led.per_stream) == {0, 1}
    assert led.per_stream[0]["rounds"] == 1 and led.per_stream[1]["rounds"] == 2
    for total, key in ((led.total_time_s, "time_s"),
                       (led.total_energy_j, "energy_j"),
                       (led.total_flops, "flops"),
                       (led.rounds, "rounds")):
        assert sum(v[key] for v in led.per_stream.values()) == \
            pytest.approx(total)


def test_ledger_segment_charges_sum_to_unpreempted_round():
    """A preempted round charged in proportional segments (final = exact
    remainder) reconciles with the one-shot charge: same totals, same
    breakdown, one round counted only at the final segment."""
    parts = {"t_compute": 1.0, "t_overhead": 2.0,
             "e_compute": 10.0, "e_overhead": 5.0}
    whole = CostLedger()
    whole.charge_round(flops=3e12, time_s=3.0, energy_j=15.0, parts=parts,
                       stream=1)
    split = CostLedger()
    f = 0.3  # first segment: 30% of the round
    split.charge_round_segment(
        flops=3e12 * f, time_s=3.0 * f, energy_j=15.0 * f,
        parts={k: v * f for k, v in parts.items()}, stream=1, final=False)
    split.note_preemption(stream=1)
    assert split.rounds == 0  # not a round until the final segment
    split.charge_round_segment(
        flops=3e12 - 3e12 * f, time_s=3.0 - 3.0 * f,
        energy_j=15.0 - 15.0 * f,
        parts={k: v - v * f for k, v in parts.items()}, stream=1,
        final=True)
    assert split.rounds == whole.rounds == 1
    assert split.total_time_s == pytest.approx(whole.total_time_s)
    assert split.total_energy_j == pytest.approx(whole.total_energy_j)
    assert split.total_flops == pytest.approx(whole.total_flops)
    for k in ("t_compute", "t_overhead", "e_compute", "e_overhead"):
        assert split.breakdown[k] == pytest.approx(whole.breakdown[k])
    for k in ("time_s", "energy_j", "flops", "rounds"):
        assert split.per_stream[1][k] == pytest.approx(whole.per_stream[1][k])
    assert split.per_stream[1]["preemptions"] == 1
    assert split.preemptions == 1 and whole.preemptions == 0


# ---------------------------------------------------------------------------
# InferenceServer (stub model: logits are right iff served by "good" params)


class _StubModel:
    def __init__(self):
        self.calls = 0

    def predict(self, params, batch):
        self.calls += 1
        labels = np.asarray(batch["labels"])
        logits = np.zeros((len(labels), 4), np.float32)
        if params == "good":
            logits[np.arange(len(labels)), labels] = 1.0
        else:  # always answer class 3
            logits[:, 3] = 1.0
        return logits


def _req(labels):
    return {"labels": np.asarray(labels, np.int32)}


def test_server_per_request_path():
    model = _StubModel()
    srv = InferenceServer(model)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0, 1]))
    srv.submit(2.0, _req([2, 3]))
    assert srv.accs == [1.0, 1.0]
    assert srv.eval_calls == 2 and model.calls == 2


def test_server_coalesces_within_window():
    model = _StubModel()
    srv = InferenceServer(model, batch_window=1.0)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0, 1]))
    srv.submit(1.5, _req([2, 2]))   # within window -> same group
    srv.submit(5.0, _req([1, 0]))   # beyond window -> flushes first group
    srv.flush()
    assert srv.accs == [1.0, 1.0, 1.0]
    assert srv.served == 3
    assert srv.eval_calls == 2      # 3 requests, 2 forward passes
    assert model.calls == 2


def test_server_publish_flushes_with_arrival_time_params():
    """Requests resolve params at arrival: a publish mid-window serves the
    queued group with the old params before switching."""
    model = _StubModel()
    srv = InferenceServer(model, batch_window=10.0)
    srv.publish("bad", 0.0)
    srv.submit(1.0, _req([0, 1]))          # resolves to "bad"
    srv.publish("good", 2.0)               # flushes the queued request
    srv.submit(3.0, _req([0, 1]))          # resolves to "good"
    srv.flush()
    assert srv.accs == [0.0, 1.0]


def test_server_expire_flushes_elapsed_window():
    """A queued group must not be deferred past its window just because no
    further request arrives — the timeline advancing (expire) flushes it,
    so detector-mode change signals surface promptly."""
    model = _StubModel()
    srv = InferenceServer(model, batch_window=1.0,
                          on_served=lambda logits, stream: True)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0]))
    srv.expire(1.5)                    # still inside the window
    assert srv.served == 0 and not srv.poll_change()
    srv.expire(2.5)                    # window elapsed -> group served
    assert srv.served == 1 and srv.accs == [1.0]
    assert srv.poll_change() is True


def test_server_per_stream_accuracy_and_signal_routing():
    """Requests carry their arrival stream: per-stream accuracy views are
    recorded, and `on_served` receives the stream id (so a multi-stream
    composition root can route controller signals)."""
    model = _StubModel()
    routed = []

    def on_served(logits, stream):
        routed.append(stream)
        return False

    srv = InferenceServer(model, batch_window=10.0, on_served=on_served)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0]), stream=0)
    srv.submit(1.5, _req([1, 2]), stream=1)  # same group, different stream
    srv.flush()
    assert routed == [0, 1]
    assert srv.eval_calls == 1               # still one coalesced pass
    assert srv.accs_by_stream == {0: [1.0], 1: [1.0]}
    assert srv.accs == [1.0, 1.0]


def test_server_window_boundary_is_closed():
    """Pinned semantics (submit/expire docstrings): the coalescing window
    is *closed* — a request landing at exactly ``first.time +
    batch_window`` joins the open group; only a strictly later arrival
    starts a new one. `expire` agrees: the group is still open at exactly
    the boundary instant."""
    model = _StubModel()
    srv = InferenceServer(model, batch_window=1.0)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0]))
    srv.expire(2.0)                 # exactly first + window: still open
    assert srv.eval_calls == 0
    srv.submit(2.0, _req([1]))      # boundary arrival coalesces
    assert srv.eval_calls == 0
    srv.submit(2.0 + 1e-9, _req([2]))  # strictly past: new group
    assert srv.eval_calls == 1 and srv.served == 2
    srv.expire(3.5)                 # strictly past the new group's window
    assert srv.eval_calls == 2 and srv.served == 3
    assert srv.accs == [1.0, 1.0, 1.0]


def test_server_records_per_stream_latency():
    model = _StubModel()
    srv = InferenceServer(model)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0]), stream=0, latency=0.0)
    srv.submit(2.0, _req([1]), stream=1, latency=1.5)
    srv.submit(3.0, _req([2]), stream=1, latency=0.5)
    assert srv.latencies_by_stream == {0: [0.0], 1: [1.5, 0.5]}


def test_server_on_served_latches_change_detection():
    model = _StubModel()
    hits = []

    def on_served(logits, stream):
        hits.append(logits.shape[0])
        return len(hits) == 2  # "detect" on the second request only

    srv = InferenceServer(model, batch_window=5.0, on_served=on_served)
    srv.publish("good", 0.0)
    srv.submit(1.0, _req([0]))
    srv.submit(1.5, _req([1, 2]))
    srv.flush()
    assert hits == [1, 2]               # per-request logits, arrival order
    assert srv.poll_change() is True
    assert srv.poll_change() is False   # consumed


def test_server_never_coalesces_across_model_slots():
    """ModelPool serving (DESIGN.md §9): two slots whose lanes happen to
    hold the *same* params object must still serve separately — each
    request's logits come from its own slot's model, and accuracies land
    under the right slot."""
    cv, nlp = _StubModel(), _StubModel()
    srv = InferenceServer(cv, batch_window=10.0)
    srv.register("cv", cv)
    srv.register("nlp", nlp)
    srv.publish("good", 0.0, slot="cv")
    srv.publish("good", 0.0, slot="nlp")   # identical params object
    srv.submit(1.0, _req([0, 1]), slot="cv")
    srv.submit(2.0, _req([2, 3]), slot="nlp")  # same window, other slot
    srv.flush()
    assert srv.eval_calls == 2             # split despite shared params
    assert cv.calls == 1 and nlp.calls == 1
    assert srv.accs_by_slot == {"cv": [1.0], "nlp": [1.0]}
    # same slot + same params still coalesces as before
    srv2 = InferenceServer(cv, batch_window=10.0)
    srv2.publish("good", 0.0)
    srv2.submit(1.0, _req([0]))
    srv2.submit(2.0, _req([1]))
    srv2.flush()
    assert srv2.eval_calls == 1
