"""QoS layer tests: preemptible fine-tuning rounds (segment-charged cost
conservation, checkpointed batch iterator), serving-latency accounting on
the qos preset, and the multi-stream runtime bugfix regressions (unseen
stream pushed mid-run; per-stream `start_scenario` latch with a shared
controller)."""
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import ETunerConfig, ETunerController
from repro.data import streams
from repro.data.arrivals import Event
from repro.models import build_model
from repro.runtime import RuntimeConfig
from repro.runtime.continual import ContinualRuntime
from repro.runtime.costmodel import EdgeCostModel
from repro.runtime.executor import FineTuneExecutor, ReplayBuffer
from repro.runtime.ledger import CostLedger
from repro.runtime.scheduler import EventScheduler
from repro.workloads import compile_workload, presets


# ---------------------------------------------------------------------------
# executor-level property: preempted segments conserve the round's cost


class _FakeSteps:
    """TrainStepCache stand-in: params count applied batches, fixed
    per-batch FLOPs — no jit, no model."""
    recompiles = 0

    def get(self, plan):
        return lambda p, o, b: (p + 1, o, 0.0)

    def flops(self, plan, jb):
        return 1e9


def _mk_executor(**kw):
    ledger = CostLedger()
    ex = FineTuneExecutor(_FakeSteps(), EdgeCostModel(), ledger,
                          ReplayBuffer(), rng=np.random.default_rng(0),
                          calibrate_cost=False, **kw)
    ex.load(0, None)
    return ex, ledger


def _run_round(split_fracs, resume=0.0, preemptor=None):
    """One 5-batch round, preempted at each fraction of its duration (empty
    tuple = the synchronous unpreempted path). Returns (ledger, report,
    params)."""
    ex, ledger = _mk_executor(preempt_resume_cost_s=resume)
    for _ in range(5):
        ex.enqueue({"x": np.zeros(2, np.float32)}, stream=1)
    sched = EventScheduler()
    if not split_fracs:
        report = ex.execute_round("plan", 10.0, sched, stream=1)
        return ledger, report, ex.params
    assert ex.execute_round("plan", 10.0, sched, stream=1, priority=0,
                            preemptible=True) is None
    total = ex.active_round.time_s
    for f in split_fracs:
        t = 10.0 + f * total
        assert sched.can_preempt(t, priority=9)
        ex.preempt(t, sched, preempting_stream=preemptor)
    report = ex.finalize_round()
    return ledger, report, ex.params


@pytest.mark.parametrize("splits", [(0.5,), (0.2, 0.4, 0.9), (0.01, 0.99)])
def test_preempted_round_segments_conserve_cost(splits):
    """Property (ISSUE satellite): however a round is split, its segment
    charges sum to the unpreempted round's time/energy/FLOPs/breakdown,
    the round end is unchanged, and every batch still trains once."""
    base_ledger, base_report, base_params = _run_round(())
    led, rep, params = _run_round(splits)
    assert params == base_params                  # all 5 batches trained
    assert rep.end == pytest.approx(base_report.end)
    assert rep.segments == len(splits) + 1
    assert rep.preemptions == len(splits)
    assert led.rounds == base_ledger.rounds == 1
    assert led.total_time_s == pytest.approx(base_ledger.total_time_s,
                                             rel=1e-12)
    assert led.total_energy_j == pytest.approx(base_ledger.total_energy_j,
                                               rel=1e-12)
    assert led.total_flops == pytest.approx(base_ledger.total_flops,
                                            rel=1e-12)
    for k, v in base_ledger.breakdown.items():
        assert led.breakdown[k] == pytest.approx(v, rel=1e-12, abs=1e-15)
    for k in ("time_s", "energy_j", "flops", "rounds"):
        assert led.per_stream[1][k] == pytest.approx(
            base_ledger.per_stream[1][k], rel=1e-12)
    assert led.per_stream[1]["preemptions"] == len(splits)


def test_same_instant_arrivals_count_one_preemption():
    """Several high-priority requests clamped to one timestamp (the
    generators pin overflow arrivals to the horizon) ride a single split:
    re-preempting at the existing segment start is a no-op — no
    zero-duration segment, no inflated preemption count."""
    ex, ledger = _mk_executor()
    for _ in range(4):
        ex.enqueue({"x": np.zeros(2, np.float32)}, stream=1)
    sched = EventScheduler()
    ex.execute_round("plan", 0.0, sched, stream=1, preemptible=True)
    t = 0.5 * ex.active_round.time_s
    ex.preempt(t, sched)
    ex.preempt(t, sched)     # same-instant re-preempt: no-op
    ex.preempt(t, sched)     # and again — still one physical split
    report = ex.finalize_round()
    assert report.preemptions == 1 and report.segments == 2
    assert ledger.per_stream[1]["preemptions"] == 1


def test_preemption_checkpoints_batch_iterator():
    """Mid-round preemption trains exactly the batches the device had
    completed by the split instant — the rest stay checkpointed."""
    ex, _ = _mk_executor()
    for _ in range(4):
        ex.enqueue({"x": np.zeros(2, np.float32)})
    sched = EventScheduler()
    ex.execute_round("plan", 0.0, sched, preemptible=True)
    ar = ex.active_round
    ex.preempt(0.5 * ar.time_s, sched)     # half the round -> 2 of 4 batches
    assert ar.trained == 2 and ex.params == 2
    ex.finalize_round()
    assert ex.params == 4 and ex.active_round is None


@pytest.mark.parametrize("splits", [(0.5,), (0.2, 0.6)])
def test_preempt_resume_cost_charged_to_preemptor(splits):
    """Segment-conservation extension (ISSUE satellite): with
    `preempt_resume_cost_s` set, each split still conserves the round's
    own charges (stream 1 unchanged), but the modeled checkpoint-resume
    fee lands on the *preempting* stream under t_resume/e_resume, and the
    round's end shifts by one fee per split."""
    resume = 0.05
    base_ledger, base_report, base_params = _run_round(())
    led, rep, params = _run_round(splits, resume=resume, preemptor=7)
    n = len(splits)
    assert params == base_params                  # all 5 batches trained
    assert rep.end == pytest.approx(base_report.end + n * resume)
    assert rep.preemptions == n
    # the round's own cost is conserved: the fee is a separate charge
    for k in ("time_s", "energy_j", "flops", "rounds"):
        assert led.per_stream[1][k] == pytest.approx(
            base_ledger.per_stream[1][k], rel=1e-12)
    power = EdgeCostModel().overhead_power_w
    assert led.per_stream[7]["time_s"] == pytest.approx(n * resume)
    assert led.per_stream[7]["energy_j"] == pytest.approx(
        n * resume * power)
    assert led.breakdown["t_resume"] == pytest.approx(n * resume)
    assert led.breakdown["e_resume"] == pytest.approx(n * resume * power)
    assert led.total_time_s == pytest.approx(
        base_ledger.total_time_s + n * resume)
    assert led.total_energy_j == pytest.approx(
        base_ledger.total_energy_j + n * resume * power)
    # a zero knob stays byte-identical to the legacy free split
    led0, rep0, _ = _run_round(splits)
    assert rep0.end == pytest.approx(base_report.end)
    assert "t_resume" not in led0.breakdown


# ---------------------------------------------------------------------------
# runtime-level: the qos preset with preemption off/on


def _immed(model):
    return ETunerController(model, ETunerConfig(
        lazytune=False, simfreeze=False, detect_scenario_changes=False))


@pytest.fixture(scope="module")
def qos_runs():
    spec = presets(batches_per_scenario=4, inferences=10,
                   num_scenarios=2)["qos"]
    events = compile_workload(spec)

    def run(preemptible, resume=0.0):
        model = build_model(get_reduced("mobilenetv2"))
        b0 = streams.nc_benchmark(num_scenarios=3, batches=4, batch_size=8,
                                  seed=0)
        b1 = streams.ni_benchmark(num_scenarios=3, batches=8, batch_size=8,
                                  seed=13)
        rt = ContinualRuntime.from_config(
            RuntimeConfig(pretrain_epochs=1, seed=0,
                          preemptible=preemptible,
                          preempt_resume_cost_s=resume),
            model=model, benchmark=b0, controller=_immed(model),
            stream_benchmarks={1: b1},
            controller_factory=lambda st: _immed(model))
        return rt.run(events=events)

    return run(False), run(True), run(True, resume=2.0)


def test_qos_preemption_cuts_high_priority_latency(qos_runs):
    """Acceptance criterion: the high-priority stream's p95 serving
    latency is strictly lower with preemption on, and preemptions are
    attributed to the bulk stream whose rounds were split."""
    off, on = qos_runs[:2]
    assert off.preemptions == 0
    assert on.preemptions > 0
    assert on.per_stream[1]["preemptions"] == on.preemptions  # bulk stream
    assert on.per_stream[0]["preemptions"] == 0
    assert on.per_stream[0]["latency_p95"] < off.per_stream[0]["latency_p95"]


def test_max_staleness_starvation_guard():
    """`ETunerConfig.max_staleness` forces a round for a stream that has
    gone that long without one, overriding LazyTune's accumulation target
    — but never fires with an empty buffer."""
    model = build_model(get_reduced("mobilenetv2"))
    ctrl = ETunerController(model, ETunerConfig(
        lazytune=True, simfreeze=False, detect_scenario_changes=False,
        max_staleness=30.0))
    ctrl.lazytune.state.batches_needed = 4.0  # LazyTune wants to wait
    assert not ctrl.should_trigger(1, staleness=0.0)
    assert not ctrl.should_trigger(1, staleness=29.9)
    assert ctrl.should_trigger(1, staleness=30.0)   # starved: force it
    assert not ctrl.should_trigger(0, staleness=99.0)  # nothing buffered
    fresh = ETunerController(model, ETunerConfig(
        lazytune=True, simfreeze=False, detect_scenario_changes=False))
    fresh.lazytune.state.batches_needed = 4.0
    assert not fresh.should_trigger(1, staleness=1e9)  # default: disabled


def test_qos_preemption_conserves_totals(qos_runs):
    """Splitting rounds must not change what the run costs: segment
    charges reconcile to the same totals as the unpreempted run."""
    off, on = qos_runs[:2]
    assert on.rounds == off.rounds
    # val_curve parity additionally pins that a lazily-finalized round
    # validates against the scenario current at its *launch* (not
    # whatever the stream drifted to by finalize time)
    np.testing.assert_allclose(on.val_curve, off.val_curve, atol=1e-6)
    np.testing.assert_allclose(on.total_time_s, off.total_time_s,
                               rtol=1e-9)
    np.testing.assert_allclose(on.total_energy_j, off.total_energy_j,
                               rtol=1e-9)
    np.testing.assert_allclose(on.compute_tflops, off.compute_tflops,
                               rtol=1e-9)
    for st in (0, 1):
        for key in ("time_s", "energy_j", "flops", "rounds"):
            np.testing.assert_allclose(on.per_stream[st][key],
                                       off.per_stream[st][key], rtol=1e-9)


def test_preempt_resume_cost_runtime_wiring(qos_runs):
    """End-to-end knob: `ContinualRuntime(preempt_resume_cost_s=2.0)`
    charges exactly one modeled resume fee per split, visible in the
    t_resume/e_resume breakdown, with both attribution views still
    reconstructing the totals."""
    _, _, onr = qos_runs
    assert onr.preemptions > 0
    assert onr.breakdown["t_resume"] == pytest.approx(
        onr.preemptions * 2.0)
    assert onr.breakdown["e_resume"] == pytest.approx(
        onr.preemptions * 2.0 * EdgeCostModel().overhead_power_w)
    for view in (onr.per_stream, onr.per_model):
        np.testing.assert_allclose(
            sum(v["time_s"] for v in view.values()), onr.total_time_s,
            rtol=1e-9)
        np.testing.assert_allclose(
            sum(v["energy_j"] for v in view.values()),
            onr.total_energy_j, rtol=1e-9)


# ---------------------------------------------------------------------------
# detector-driven probes (ISSUE satellite; ROADMAP open item)


def test_detector_probe_fires_and_resolves_on_right_stream():
    """A detection in boundaries='detector' mode pushes a probe Event
    onto the live scheduler; the probe's dedicated forward pass resolves
    against the *detecting stream's* controller, whose confirmation
    latches the scenario change for that stream's next data event."""
    model = build_model(get_reduced("mobilenetv2"))
    b0 = streams.nc_benchmark(num_scenarios=3, batches=3, batch_size=8,
                              seed=0)
    b1 = streams.ni_benchmark(num_scenarios=3, batches=3, batch_size=8,
                              seed=13)

    class Spy(ETunerController):
        def __init__(self, model, fire=False):
            super().__init__(model, ETunerConfig(
                lazytune=False, simfreeze=False,
                detect_scenario_changes=False))
            self.fire = fire
            self.probes = 0
            self.changes = 0

        def inference_served(self, logits):
            hit = super().inference_served(logits)
            if self.fire:
                self.fire = False
                return True
            return hit

        def probe_served(self, logits):
            self.probes += 1
            return True

        def scenario_changed(self, params, batch):
            self.changes += 1
            super().scenario_changed(params, batch)

    c0 = Spy(model)
    c1 = Spy(model, fire=True)   # stream 1's controller flags a change
    rt = ContinualRuntime.from_config(
        RuntimeConfig(pretrain_epochs=1, seed=0, boundaries="detector"),
        model=model, benchmark=b0, controller=c0,
        stream_benchmarks={1: b1}, controller_factory=lambda st: c1)
    events = [Event(1.0, "data", 1, 0, stream=0),
              Event(2.0, "data", 1, 0, stream=1),
              Event(3.0, "inference", 1, 0, stream=1),
              Event(4.0, "data", 1, 1, stream=1),
              Event(5.0, "data", 1, 1, stream=0)]
    res = rt.run(events=events)
    assert res.probes == 1
    assert c1.probes == 1 and c0.probes == 0       # right controller
    assert c1.changes == 1 and c0.changes == 0     # right stream latched
    assert res.breakdown["t_probe"] > 0            # the pass is charged


def test_probe_confirmation_can_reject():
    """A probe whose forward pass does *not* confirm drift leaves the
    stream's pending-change latch unset — no scenario_changed fires."""
    model = build_model(get_reduced("mobilenetv2"))
    bench = streams.nc_benchmark(num_scenarios=3, batches=3, batch_size=8,
                                 seed=0)

    class Reject(ETunerController):
        def __init__(self, model):
            super().__init__(model, ETunerConfig(
                lazytune=False, simfreeze=False,
                detect_scenario_changes=False))
            self.fire = True
            self.changes = 0

        def inference_served(self, logits):
            super().inference_served(logits)
            if self.fire:
                self.fire = False
                return True
            return False

        def probe_served(self, logits):
            return False

        def scenario_changed(self, params, batch):
            self.changes += 1

    ctrl = Reject(model)
    rt = ContinualRuntime.from_config(
        RuntimeConfig(pretrain_epochs=1, seed=0, boundaries="detector"),
        model=model, benchmark=bench, controller=ctrl)
    res = rt.run(events=[Event(1.0, "data", 1, 0),
                         Event(2.0, "inference", 1, 0),
                         Event(3.0, "data", 1, 1)])
    assert res.probes == 1
    assert ctrl.changes == 0


# ---------------------------------------------------------------------------
# bugfix regressions (ISSUE satellites)


def _tiny_runtime(ctrl_cls=ETunerController, **kw):
    model = build_model(get_reduced("mobilenetv2"))
    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=3,
                                 batch_size=8, seed=0)
    ctrl = ctrl_cls(model, ETunerConfig(
        lazytune=False, simfreeze=False, detect_scenario_changes=False))
    return ContinualRuntime.from_config(
        RuntimeConfig(pretrain_epochs=1, seed=0, **kw),
        model=model, benchmark=bench, controller=ctrl), ctrl


def test_unseen_stream_pushed_mid_run_does_not_crash():
    """Regression (ISSUE satellite): an Event carrying a stream id the
    start-of-run list never saw — pushed onto the live scheduler from
    inside a callback, the detector-driven-probe pattern — used to
    KeyError in on_data/served; it now defaults to the primary
    controller/benchmark and is fully accounted."""
    rt, ctrl = _tiny_runtime()
    pushed = []

    orig_served = ctrl.inference_served

    def served_and_push(logits):
        if not pushed:
            pushed.append(True)
            now = rt.scheduler.now
            rt.scheduler.push(Event(now + 1.0, "data", 1, 0, stream=7))
            rt.scheduler.push(Event(now + 1.5, "inference", 1, 0, stream=7))
        return orig_served(logits)

    ctrl.inference_served = served_and_push
    events = [Event(1.0, "data", 1, 0), Event(2.0, "inference", 1, 0),
              Event(10.0, "data", 1, 1), Event(30.0, "data", 2, 0)]
    res = rt.run(events=events)
    assert pushed
    assert 7 in res.per_stream
    assert res.per_stream[7]["inferences"] == 1.0
    assert res.per_stream[7]["rounds"] >= 1  # its data batch fine-tuned


def test_lazy_finalize_validates_against_launch_scenario(monkeypatch):
    """A preemptible round finalized *after* its stream drifted must
    validate against the scenario whose batches it trained (snapshotted
    at launch) — the scheduler's scenario bookkeeping advances before
    on_data's settle, so reading it at finalize time would grade round 1
    on scenario 2's val split. Spies on the val batches actually
    evaluated."""
    import repro.runtime.device as D

    val_labels = []
    real_eval = D.evaluate

    def spy(model, params, batch):
        val_labels.append(np.asarray(batch["labels"]))
        return real_eval(model, params, batch)

    monkeypatch.setattr(D, "evaluate", spy)
    rt, _ = _tiny_runtime(preemptible=True)
    events = [Event(1.0, "data", 1, 0),
              Event(50.0, "data", 2, 0),   # boundary event finalizes it
              Event(60.0, "data", 2, 1)]
    res = rt.run(events=events)
    assert res.rounds == 3 and len(val_labels) == 3
    np.testing.assert_array_equal(
        val_labels[0], np.asarray(rt.bench.scenarios[1].val["labels"]))
    np.testing.assert_array_equal(
        val_labels[1], np.asarray(rt.bench.scenarios[2].val["labels"]))


class _StartCountingController(ETunerController):
    def __init__(self, model, cfg):
        super().__init__(model, cfg)
        self.starts = 0

    def start_scenario(self, reference_params, probe_batch):
        self.starts += 1
        super().start_scenario(reference_params, probe_batch)


def test_shared_controller_start_scenario_not_suppressed_across_streams():
    """Regression (ISSUE satellite): the `_scenario_started` latch used to
    live on the controller object, so streams sharing one controller (no
    controller_factory) leaked start state into each other; it now lives
    in a per-stream dict in the runtime, and no attribute is written onto
    the user-owned controller."""
    rt, ctrl = _tiny_runtime(ctrl_cls=_StartCountingController)
    events = [Event(1.0, "data", 1, 0, stream=0),
              Event(2.0, "data", 1, 0, stream=1),
              Event(3.0, "data", 1, 1, stream=1)]
    rt.run(events=events)
    # one start per stream's first scenario; the third event (same stream,
    # same scenario) must not re-start
    assert ctrl.starts == 2
    assert not hasattr(ctrl, "_scenario_started")
