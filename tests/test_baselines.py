"""Baseline controllers (Egeria/SlimFit/RigL/Ekya/static) integrate with
the runtime and exhibit their defining behaviours."""
import jax
import numpy as np
import pytest

from repro.baselines import (EgeriaController, EkyaController, RigLController,
                             SlimFitController, StaticController)
from repro.configs import get_reduced
from repro.data import streams
from repro.models import build_model
from repro.runtime import RuntimeConfig
from repro.runtime.continual import ContinualRuntime


def _rt(model, bench, ctrl):
    return ContinualRuntime.from_config(RuntimeConfig(pretrain_epochs=1),
                                        model=model, benchmark=bench,
                                        controller=ctrl)


@pytest.fixture(scope="module")
def setup():
    model = build_model(get_reduced("mobilenetv2"))
    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=8,
                                 batch_size=16)
    return model, bench


def test_static_controller_interval(setup):
    model, bench = setup
    ctrl = StaticController(model, interval=4)
    rt = _rt(model, bench, ctrl)
    res = rt.run(inferences_total=10)
    ctrl_immed = StaticController(model, interval=1)
    rt2 = _rt(model, bench, ctrl_immed)
    res2 = rt2.run(inferences_total=10)
    assert res.rounds < res2.rounds
    assert res.total_energy_j < res2.total_energy_j


def test_egeria_freezes_front_to_back(setup):
    model, bench = setup
    ctrl = EgeriaController(model, with_lazytune=False, interval=2)
    rt = _rt(model, bench, ctrl)
    rt.run(inferences_total=8)
    flags = list(ctrl.plan.layers)
    # frozen set (if any) must be a prefix — Egeria's defining rigidity
    if any(flags):
        first_active = flags.index(False) if False in flags else len(flags)
        assert all(flags[:first_active])
        assert not any(flags[first_active:])


def test_slimfit_freezes_by_update_magnitude(setup):
    model, bench = setup
    ctrl = SlimFitController(model, with_lazytune=False, interval=2,
                             threshold=0.5)  # generous: freezes something
    rt = _rt(model, bench, ctrl)
    rt.run(inferences_total=8)
    assert sum(ctrl.plan.layers) >= 1
    assert sum(ctrl.plan.layers) <= int(0.9 * ctrl.n_units)  # budget capped


def test_rigl_masks_and_flops_scale(setup):
    model, bench = setup
    ctrl = RigLController(model, with_lazytune=False, sparsity=0.5)
    wrapped = ctrl.wrap_model()
    rt = _rt(wrapped, bench, ctrl)
    rt.run(inferences_total=8)
    assert ctrl.masks is not None
    dens = [float(np.mean(np.asarray(m))) for m in jax.tree.leaves(ctrl.masks)
            if np.asarray(m).ndim >= 2]
    assert 0.35 < float(np.mean(dens)) < 0.65  # ~50% sparsity on matrices
    assert ctrl.flops_scale < 1.0


def test_ekya_profiles_and_schedules(setup):
    model, bench = setup
    ctrl = EkyaController(model, with_lazytune=False, window_batches=4)
    rt = _rt(model, bench, ctrl)
    rt.run(inferences_total=8)
    assert ctrl.profile_rounds >= 1
