"""Compiled hot path (DESIGN.md §12): equivalence, donation, and the
compile-ledger contract.

The headline property: executing a compiled preset timeline as fused
segments — `lax.scan` over stacked train batches, vmapped stacks of
serving groups — yields the *identical* `RunResult` to dispatching the
same timeline one event at a time, and to the pure-Python fallback
(`compiled=False`). Identical means exact: a scan's while-loop HLO is
trip-count-independent and the validity mask leaves padded steps' carry
untouched, so fusion is purely a dispatch optimization; any drift is a
bug, not noise. The same must hold under QoS preemption, where
segment-batched rounds fall back to segment-split execution.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.optim import AdamWConfig
from repro.runtime import RuntimeConfig, SlotConfig, edgeol_session
from repro.runtime.train_loop import (TrainStepCache, as_jnp,
                                      batch_signature, make_optimizer_state,
                                      same_shape_runs)

SCALE = dict(batches_per_scenario=3, inferences=6, num_scenarios=2)


def _run(workload="single-poisson", *, compiled=True, segment=True,
         preemptible=False, scale=SCALE, **cfg_kw):
    cfg = RuntimeConfig(slots={"cv": SlotConfig()}, workload=workload,
                        workload_scale=dict(scale), seed=0,
                        pretrain_epochs=1, preemptible=preemptible,
                        compiled=compiled, **cfg_kw)
    rt = edgeol_session(cfg)
    rt.segment = segment
    return rt.run()


def _assert_identical(a, b):
    """Exact RunResult equality — accuracy trace, ledger totals, and the
    per-stream / per-model attribution down to the last bit."""
    assert a.rounds == b.rounds
    assert a.recompiles == b.recompiles
    assert a.preemptions == b.preemptions
    np.testing.assert_array_equal(a.inference_accs, b.inference_accs)
    np.testing.assert_array_equal(a.val_curve, b.val_curve)
    assert a.total_time_s == b.total_time_s
    assert a.total_energy_j == b.total_energy_j
    assert a.compute_tflops == b.compute_tflops
    assert a.per_stream == b.per_stream
    assert a.per_model == b.per_model


def test_segment_batched_matches_per_event():
    seg = _run(segment=True)
    per_event = _run(segment=False)
    _assert_identical(seg, per_event)


def test_compiled_matches_fallback():
    compiled = _run(segment=True)
    fallback = _run(compiled=False)
    _assert_identical(compiled, fallback)


def test_segment_batched_matches_per_event_preemptible():
    # QoS preemption splits rounds mid-flight; preempted rounds leave the
    # fused path and advance batch-by-batch, which must not perturb a bit.
    # The CI quick-sweep scale is the smallest one that actually preempts.
    scale = dict(batches_per_scenario=4, inferences=10, num_scenarios=2)
    seg = _run("qos", segment=True, preemptible=True, scale=scale)
    per_event = _run("qos", segment=False, preemptible=True, scale=scale)
    assert seg.preemptions > 0
    _assert_identical(seg, per_event)


def test_compiled_matches_fallback_multi_stream():
    compiled = _run("two-stream")
    fallback = _run("two-stream", compiled=False)
    _assert_identical(compiled, fallback)


# ---------------------------------------------------------------------------
# TrainStepCache: fused scan + donation semantics on a micro model


def _micro_cache(donate):
    def loss(params, batch, plan=None):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"loss": l}

    model = Model(cfg=None, loss=loss, features=None, num_freeze_units=1,
                  init=lambda rng: {"w": jax.random.normal(rng, (4, 2))})
    opt = AdamWConfig(lr=1e-2)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = make_optimizer_state(model, opt, params)
    return TrainStepCache(model, opt, donate=donate), params, opt_state


def _micro_batches(n, seed=1):
    rng = np.random.default_rng(seed)
    return [{"x": rng.standard_normal((5, 4)).astype(np.float32),
             "y": rng.standard_normal((5, 2)).astype(np.float32)}
            for _ in range(n)]


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree.leaves(tree)]


def test_donated_step_bitwise_matches_undonated():
    batches = _micro_batches(3)
    results = []
    for donate in (False, True):
        cache, params, opt_state = _micro_cache(donate)
        step = cache.get(None)
        for b in batches:
            # exclusive copies: the donated variant consumes its inputs
            params, opt_state, _ = step(
                jax.tree.map(jnp.copy, params),
                jax.tree.map(jnp.copy, opt_state), as_jnp(b))
        results.append(_leaves(params) + _leaves(opt_state))
    for a, b in zip(*results):
        np.testing.assert_array_equal(a, b)


def test_fused_scan_bitwise_matches_single_steps():
    batches = _micro_batches(5)
    cache, params, opt_state = _micro_cache(False)
    step = cache.get(None)
    p_seq, o_seq = params, opt_state
    for b in batches:
        p_seq, o_seq, _ = step(p_seq, o_seq, as_jnp(b))
    # one fused dispatch (bucket 8, 3 masked padding steps)
    p_fused, o_fused, _ = cache.fused_call(None, params, opt_state, batches)
    for a, b in zip(_leaves(p_seq) + _leaves(o_seq),
                    _leaves(p_fused) + _leaves(o_fused)):
        np.testing.assert_array_equal(a, b)


def test_recompile_ledger_counts_plan_shape_triples():
    cache, _, _ = _micro_cache(False)
    b_small, b_large = _micro_batches(1)[0], {
        "x": np.zeros((9, 4), np.float32), "y": np.zeros((9, 2), np.float32)}
    assert cache.recompiles == 0
    cache.get("planA")
    assert cache.recompiles == 1
    cache.get("planA", b_small)          # first shape rides the plan compile
    cache.get("planA", b_small)
    assert cache.recompiles == 1
    cache.get("planA", b_large)          # second shape = second program
    assert cache.recompiles == 2
    cache.get("planB", b_large)          # new plan (its first shape rides)
    assert cache.recompiles == 3
    cache.get("planB", b_small)
    assert cache.recompiles == 4
    # steady state: re-requesting any known (plan, shape) is free
    for plan, b in (("planA", b_small), ("planA", b_large),
                    ("planB", b_small), ("planB", b_large)):
        cache.get(plan, b)
    assert cache.recompiles == 4


def test_same_shape_runs_slices_maximal_runs():
    a = {"x": np.zeros((2, 4), np.float32)}
    b = {"x": np.zeros((3, 4), np.float32)}
    runs = list(same_shape_runs([a, a, b, a]))
    assert [len(r) for r in runs] == [2, 1, 1]
    assert batch_signature(runs[0][0]) == batch_signature(a)
    assert batch_signature(runs[1][0]) == batch_signature(b)


# ---------------------------------------------------------------------------
# scheduler segmentation + config surface


def test_scheduler_slices_inference_segments():
    from repro.data.arrivals import Event
    from repro.runtime.scheduler import EventScheduler

    events = [Event(0.0, "data", 0, 0), Event(1.0, "inference", 0, 0),
              Event(2.0, "inference", 0, 1), Event(3.0, "inference", 0, 2),
              Event(4.0, "data", 0, 1), Event(5.0, "inference", 0, 3)]
    sched = EventScheduler(events)
    segments, singles, datas = [], [], []
    sched.run(on_data=lambda ev, b: datas.append(ev.time),
              on_inference=lambda ev: singles.append(ev.time),
              on_inference_segment=lambda seg:
                  segments.append([e.time for e in seg]))
    assert segments == [[1.0, 2.0, 3.0], [5.0]]
    assert singles == []            # the segment handler owns every one
    assert datas == [0.0, 4.0]
    assert sched.dispatched == len(events)
    assert sched.now == 5.0


def test_scheduler_per_event_without_segment_handler():
    from repro.data.arrivals import Event
    from repro.runtime.scheduler import EventScheduler

    events = [Event(1.0, "inference", 0, 0), Event(2.0, "inference", 0, 1)]
    sched = EventScheduler(events)
    singles = []
    sched.run(on_data=lambda ev, b: None,
              on_inference=lambda ev: singles.append(ev.time))
    assert singles == [1.0, 2.0]


def test_config_roundtrip_compiled_flags():
    cfg = RuntimeConfig(slots={"cv": SlotConfig()},
                        workload="single-poisson",
                        compiled=True, use_pallas=True)
    assert cfg.to_dict()["compiled"] is True
    assert cfg.to_dict()["use_pallas"] is True
    assert RuntimeConfig.from_dict(cfg.to_dict()) == cfg
    # defaults stay off: the golden regression path is the eager one
    assert RuntimeConfig().compiled is False
    assert RuntimeConfig().use_pallas is False
