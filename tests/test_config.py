"""RuntimeConfig tests (DESIGN.md §11): dict/JSON round-trip identity
across every workload preset, strict validation with actionable
messages, and the declarative session front door (`edgeol_session`)."""
import json

import pytest

from benchmarks.workloads import workload_config
from repro.core.policies import PolicySpec, PolicyStackSpec
from repro.runtime import (HookSpec, RuntimeConfig, SlotConfig, build_hook,
                           edgeol_session)
from repro.workloads import presets


# ---------------------------------------------------------------------------
# round-trip identity


@pytest.mark.parametrize("name", sorted(presets()))
def test_config_round_trips_across_presets(name):
    """ISSUE satellite: `RuntimeConfig.from_dict(cfg.to_dict())` is the
    identity for every workload preset's sweep config — through real
    JSON, so the artifact a manifest records reconstructs the session."""
    cfg = workload_config("mobilenetv2", name, "etuner",
                          workload_scale=dict(batches_per_scenario=4,
                                              inferences=10,
                                              num_scenarios=2))
    rebuilt = RuntimeConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert rebuilt == cfg


def test_config_round_trips_with_hooks_and_qos():
    cfg = RuntimeConfig(
        slots={
            "cv": SlotConfig(arch="mobilenetv2", benchmark="nc",
                             benchmark_kw={"num_scenarios": 3},
                             hooks=(HookSpec("fake-quant", {"bits": 8}),
                                    HookSpec("simsiam", {"fraction": 0.5})),
                             policies=PolicyStackSpec(
                                 trigger=PolicySpec("priority-weighted",
                                                    {"priority_weight": 1.0,
                                                     "max_staleness": 40.0}),
                                 publish=PolicySpec("round-end")),
                             memory_mb=4.5),
            "nlp": SlotConfig(arch="bert-base", benchmark="20news"),
        },
        workload="mixed", workload_scale={"batch_size": 4},
        seed=3, boundaries="detector", replay_batches=1, pretrain_epochs=2,
        inference_batch=4, calibrate_cost=False, inference_window=1.5,
        preemptible=True, preempt_resume_cost_s=0.25, memory_budget_mb=6.0)
    assert RuntimeConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg


# ---------------------------------------------------------------------------
# validation: unknown keys / names raise with the alternatives listed


def test_unknown_top_level_key_actionable():
    with pytest.raises(ValueError, match=r"unknown key.*'bogus'.*valid"):
        RuntimeConfig.from_dict({"bogus": 1})


def test_unknown_slot_key_actionable():
    with pytest.raises(ValueError, match=r"slot config: unknown key"):
        RuntimeConfig.from_dict({"slots": {"default": {"archh": "x"}}})


def test_bad_policy_name_actionable():
    with pytest.raises(ValueError,
                       match=r"known trigger policies.*lazytune"):
        RuntimeConfig.from_dict(
            {"slots": {"default": {"policies": {
                "trigger": {"name": "lazy-tune"}}}}})
    with pytest.raises(ValueError, match=r"known hooks"):
        RuntimeConfig(slots={"default": SlotConfig(
            hooks=(HookSpec("quantize", {"bits": 8}),))}).validate()
    with pytest.raises(ValueError, match=r"bits"):
        build_hook(HookSpec("fake-quant", {"bitz": 8}))


def test_bad_scalars_raise():
    with pytest.raises(ValueError, match="boundaries"):
        RuntimeConfig(boundaries="psychic").validate()
    with pytest.raises(ValueError, match="workload_scale"):
        RuntimeConfig(workload="qos",
                      workload_scale={"scenariosss": 2}).validate()
    with pytest.raises(ValueError, match="without a workload"):
        RuntimeConfig(workload_scale={"inferences": 4}).validate()
    with pytest.raises(ValueError, match="inference_batch"):
        RuntimeConfig(inference_batch=0).validate()


def test_device_config_round_trip_and_validation():
    from repro.runtime.config import DeviceConfig

    fleet = (DeviceConfig("dev0"),
             DeviceConfig("jetson1", speed_scale=1.6, energy_scale=0.8,
                          memory_budget_mb=256.0))
    cfg = RuntimeConfig(devices=fleet, routing="least-loaded",
                        aggregate_every=50.0).validate()
    again = RuntimeConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert again.devices == fleet
    assert again.routing == "least-loaded"
    assert again.aggregate_every == 50.0
    # defaults stay out of the serialized form
    assert "devices" not in RuntimeConfig().to_dict()
    assert DeviceConfig("dev0").to_dict() == {"name": "dev0"}
    with pytest.raises(ValueError, match=r"unknown routing.*least-loaded"):
        RuntimeConfig(routing="round-robbin").validate()
    with pytest.raises(ValueError, match="unique"):
        RuntimeConfig(devices=(DeviceConfig("a"),
                               DeviceConfig("a"))).validate()
    with pytest.raises(ValueError, match="speed_scale"):
        DeviceConfig("a", speed_scale=0.0).validate()
    with pytest.raises(ValueError, match=r"unknown key"):
        DeviceConfig.from_dict({"name": "a", "speeed": 2.0})
    with pytest.raises(ValueError, match="aggregate_every"):
        RuntimeConfig(aggregate_every=-1.0).validate()


def test_unknown_workload_preset_actionable():
    with pytest.raises(ValueError, match=r"known presets.*single-poisson"):
        edgeol_session(RuntimeConfig(workload="nope"))


def test_workload_missing_slot_config_actionable():
    with pytest.raises(ValueError, match=r"missing \['nlp'\]"):
        edgeol_session(RuntimeConfig(
            workload="mixed",
            workload_scale=dict(batches_per_scenario=2, inferences=4,
                                num_scenarios=2),
            slots={"cv": SlotConfig()}))


def test_multiple_slots_need_workload_or_pool():
    with pytest.raises(ValueError, match="multi-modality workload"):
        edgeol_session(RuntimeConfig(slots={"a": SlotConfig(),
                                            "b": SlotConfig()}))


def test_baseline_method_rejects_trigger_policy():
    """The priority-weighted trigger is a paper-method policy stack; a
    monolithic baseline must fail fast rather than run mislabeled."""
    from benchmarks.workloads import run_workload

    spec = presets(batches_per_scenario=2, inferences=4,
                   num_scenarios=2)["qos"]
    with pytest.raises(ValueError, match="trigger_policy"):
        run_workload("mobilenetv2", spec, "egeria",
                     trigger_policy="priority-weighted")


def test_injected_pool_keeps_no_controller_error():
    """Controllers are synthesized from slot policies only for a pool the
    config itself built; an injected pool whose slot names happen to
    match the default SlotConfig must still hit the explicit 'no
    controller' error instead of silently running a full ETuner stack."""
    from repro.runtime.costmodel import EdgeCostModel
    from repro.runtime.modelpool import ModelPool, ModelSlot

    pool = ModelPool([ModelSlot("default", model=None, benchmark=None,
                                memory_mb=1.0, cost=EdgeCostModel())])
    rt = edgeol_session(RuntimeConfig(), model_pool=pool)
    with pytest.raises(ValueError, match="no controller"):
        rt.run(events=[])


def test_session_run_warns_on_ignored_timeline_args():
    """run()'s legacy timeline-generation knobs do nothing when the
    session replays a workload config — that conflict warns instead of
    silently dropping the arguments."""
    cfg = RuntimeConfig(
        workload="single-poisson",
        workload_scale=dict(batches_per_scenario=2, inferences=4,
                            num_scenarios=2),
        slots={"cv": SlotConfig()}, pretrain_epochs=1)
    rt = edgeol_session(cfg)
    with pytest.warns(UserWarning, match="ignored"):
        rt.run(inferences_total=99)
