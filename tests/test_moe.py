"""MoE routing semantics: capacity, gating weights, local (per-shard)
dispatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import moe

CFG = get_reduced("qwen3-moe-30b-a3b")
P = moe.init_moe(jax.random.PRNGKey(0), CFG)


def test_capacity_formula():
    c = moe.moe_capacity(CFG, 1024)
    assert c == int(1.25 * 1024 * CFG.experts_per_token / CFG.num_experts)
    assert moe.moe_capacity(CFG, 2) == 2  # never exceeds token count


def test_moe_output_finite_and_gated():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, CFG.d_model))
    out, aux = moe.moe_ffn(P, CFG, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) > 0.0  # load-balance loss is positive


def test_local_dispatch_matches_global_at_ample_capacity():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, CFG.d_model))
    o_global, _ = moe._moe_dispatch(P, CFG, x, groups=1, capacity=64)
    o_local, _ = moe._moe_dispatch(P, CFG, x, groups=2, capacity=32)
    np.testing.assert_allclose(np.asarray(o_local), np.asarray(o_global),
                               atol=1e-5)


def test_dropped_tokens_get_zero_output():
    """With capacity 8 << demand, over-capacity tokens contribute zeros
    (capacity-factor semantics) — output must stay finite."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, CFG.d_model))
    out, _ = moe._moe_dispatch(P, CFG, x, groups=1, capacity=8)
    assert bool(jnp.isfinite(out).all())
    # some tokens must be dropped at this capacity -> some zero rows
    flat = np.asarray(out).reshape(-1, CFG.d_model)
    zero_rows = np.sum(np.abs(flat).sum(-1) < 1e-9)
    assert zero_rows > 0


def test_grad_flows_through_router():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, CFG.d_model))

    def loss(p):
        out, aux = moe.moe_ffn(p, CFG, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(P)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["wg"]).sum()) > 0
