"""Optimizer semantics (freeze masks, clipping, schedules) and checkpoint
fault-tolerance (atomicity, corruption detection, async, rotation)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, ckpt
from repro.optim import (AdamWConfig, SGDMConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm, sgdm_init, sgdm_update)


def _params():
    k = jax.random.PRNGKey(0)
    return {"blocks": (jnp.ones((4, 8, 8)),),  # stacked [G=4, ...]
            "embed": {"tok": jax.random.normal(k, (16, 8))},
            "final_norm": jnp.zeros((8,))}


def test_adamw_moves_params_and_state():
    p = _params()
    cfg = AdamWConfig(lr=1e-2)
    st = adamw_init(p, cfg)
    g = jax.tree.map(jnp.ones_like, p)
    p2, st2 = adamw_update(g, st, p, cfg)
    assert int(st2.step) == 1
    assert float(jnp.abs(p2["final_norm"] - p["final_norm"]).sum()) > 0


def test_adamw_freeze_mask_pins_params_and_moments():
    p = _params()
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.1)
    st = adamw_init(p, cfg)
    g = jax.tree.map(jnp.ones_like, p)
    # freeze groups 0 and 1 of the stacked blocks + the whole embedding
    masks = {"blocks": (jnp.asarray([0.0, 0.0, 1.0, 1.0]),),
             "embed": {"tok": jnp.zeros(())},
             "final_norm": jnp.ones(())}
    p2, st2 = adamw_update(g, st, p, cfg, masks=masks)
    blk = np.asarray(p2["blocks"][0])
    blk0 = np.asarray(p["blocks"][0])
    np.testing.assert_array_equal(blk[:2], blk0[:2])      # frozen slices fixed
    assert np.abs(blk[2:] - blk0[2:]).sum() > 0           # active slices move
    np.testing.assert_array_equal(np.asarray(p2["embed"]["tok"]),
                                  np.asarray(p["embed"]["tok"]))
    m = np.asarray(st2.m["blocks"][0])
    assert np.all(m[:2] == 0) and np.any(m[2:] != 0)      # moments pinned


def test_sgdm_freeze_mask():
    p = _params()
    cfg = SGDMConfig(lr=0.1)
    st = sgdm_init(p, cfg)
    g = jax.tree.map(jnp.ones_like, p)
    masks = jax.tree.map(lambda _: jnp.zeros(()), p)
    p2, _ = sgdm_update(g, st, p, cfg, masks=masks)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm

    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, warmup=10, total=100))
    lr_w = float(cosine_schedule(10, warmup=10, total=100))
    lr_end = float(cosine_schedule(100, warmup=10, total=100, min_frac=0.1))
    assert lr0 == 0.0 and lr_w == pytest.approx(1.0) \
        and lr_end == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# checkpointing


def test_checkpoint_roundtrip(tmp_path):
    p = _params()
    path = str(tmp_path / "c1")
    ckpt.save(path, p, step=7)
    restored, step = ckpt.restore(path, p)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    p = _params()
    path = str(tmp_path / "c2")
    ckpt.save(path, p, step=1)
    assert ckpt.validate(path)
    # corrupt the payload (truncation = torn write)
    pz = os.path.join(path, "data.npz")
    with open(pz, "r+b") as f:
        f.truncate(os.path.getsize(pz) - 64)
    assert not ckpt.validate(path)


def test_manager_restores_latest_valid_and_rotates(tmp_path):
    p = _params()
    mgr = CheckpointManager(str(tmp_path), keep=2, use_async=False)
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x, s=s: x + s, p))
    assert mgr.all_steps() == [2, 3]  # rotation dropped step 1
    # corrupt newest (truncate payload) -> restore falls back to step 2
    p3 = os.path.join(str(tmp_path), "ckpt_0000000003", "data.npz")
    with open(p3, "r+b") as f:
        f.truncate(os.path.getsize(p3) // 2)
    restored, step = mgr.restore_latest(p)
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["final_norm"]),
                               np.asarray(p["final_norm"]) + 2)


def test_async_checkpointer(tmp_path):
    p = _params()
    mgr = CheckpointManager(str(tmp_path), keep=3, use_async=True)
    mgr.save(5, p)
    mgr.wait()
    restored, step = mgr.restore_latest(p)
    assert step == 5


def test_restore_missing_returns_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "empty"))
    tree, step = mgr.restore_latest(_params())
    assert tree is None and step == -1
