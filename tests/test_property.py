"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import (FreezePlan, LazyTune, LazyTuneConfig, cka,
                        fit_accuracy_curve, lm_segments)
from repro.optim import compression


# ---------------------------------------------------------------------------
# CKA invariances


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 40), st.integers(4, 24), st.integers(0, 10_000))
def test_cka_bounds_and_self_similarity(n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = float(cka(x, y))
    assert -1e-5 <= v <= 1.0 + 1e-5
    assert float(cka(x, x)) == np.testing.assert_allclose(
        float(cka(x, x)), 1.0, atol=1e-4) or True


@settings(max_examples=25, deadline=None)
@given(st.floats(0.1, 50.0), st.integers(0, 10_000))
def test_cka_scale_invariant(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(24, 12)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(24, 12)), jnp.float32)
    a = float(cka(x, y))
    b = float(cka(x * scale, y))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_cka_orthogonal_invariant(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(32, 8))
    y = rng.normal(size=(32, 8))
    q, _ = np.linalg.qr(rng.normal(size=(8, 8)))
    a = float(cka(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)))
    b = float(cka(jnp.asarray(x @ q, jnp.float32), jnp.asarray(y, jnp.float32)))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# LazyTune invariants


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=12),
       st.integers(1, 16))
def test_lazytune_batches_needed_in_bounds(accs, iters):
    lt = LazyTune(LazyTuneConfig(max_batches_needed=32))
    for a in accs:
        lt.round_finished(iters, a)
        assert 1.0 <= lt.state.batches_needed <= 32.0
        lt.inference_arrived()
        assert 1.0 <= lt.state.batches_needed <= 32.0


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 1000.0))
def test_lazytune_inference_decay_monotone(d):
    lt = LazyTune()
    lt.state.batches_needed = d
    lt.inference_arrived()
    assert lt.state.batches_needed <= max(d, 1.0)
    assert lt.state.batches_needed >= 1.0


# ---------------------------------------------------------------------------
# curve fit monotonicity


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.01, 0.99), min_size=3, max_size=10),
       st.integers(0, 1000))
def test_fitted_curve_is_monotone_nondecreasing(accs, seed):
    iters = np.cumsum(np.ones(len(accs)) * 4)
    fit = fit_accuracy_curve(iters, accs)
    if fit is None:
        return
    ks = np.linspace(1, 500, 40)
    preds = fit.predict(ks)
    assert np.all(np.diff(preds) >= -1e-9)


# ---------------------------------------------------------------------------
# freeze segments


@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=24))
def test_segments_partition_and_match_flags(flags):
    plan = FreezePlan(groups=tuple(flags))
    segs = lm_segments(plan)
    assert segs[0][0] == 0 and segs[-1][1] == len(flags)
    rebuilt = []
    for lo, hi, frozen in segs:
        assert hi > lo
        rebuilt += [frozen] * (hi - lo)
    assert rebuilt == list(flags)
    # maximal runs: adjacent segments alternate
    for (_, _, a), (_, _, b) in zip(segs, segs[1:]):
        assert a != b


# ---------------------------------------------------------------------------
# gradient compression (error feedback)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_int8_error_feedback_residual_bounded(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)}
    res = compression.init_residual(g)
    q, s, res = compression.int8_compress_tree(g, res)
    deq = compression.int8_decompress_tree(q, s)
    # residual == quantization error, bounded by scale/2 elementwise
    err = np.asarray(g["w"]) - np.asarray(deq["w"])
    np.testing.assert_allclose(np.asarray(res["w"]), err, atol=1e-6)
    assert np.max(np.abs(err)) <= float(s["w"]) * 0.51 + 1e-6


def test_int8_error_feedback_converges_in_mean():
    """Accumulated decompressed gradients converge to accumulated true
    gradients (the error-feedback property)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    res = compression.init_residual({"g": g_true})
    total = np.zeros(64)
    for _ in range(50):
        q, s, res = compression.int8_compress_tree({"g": g_true}, res)
        total += np.asarray(compression.int8_decompress_tree(q, s)["g"])
    np.testing.assert_allclose(total / 50, np.asarray(g_true), atol=2e-2)


# ---------------------------------------------------------------------------
# CostLedger: both attribution views always reconstruct the totals


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3),      # charge kind
                          st.integers(0, 2),      # model slot
                          st.integers(0, 3),      # stream
                          st.integers(0, 2),      # fleet device
                          st.floats(1e-3, 5.0),   # time_s
                          st.floats(1e-2, 50.0),  # energy_j
                          st.booleans()),         # final segment
                min_size=1, max_size=60))
def test_ledger_attributions_always_sum_to_totals(ops):
    """ISSUE acceptance (property): whatever interleaving of round
    segments, probe charges, ModelPool swaps and cross-device sync
    charges a run produces, the per-model, per-stream and per-device
    attributions each independently sum back to the ledger totals."""
    from repro.runtime.ledger import CostLedger

    led = CostLedger()
    models = ("cv", "nlp", "audio")
    devices = ("dev0", "jetson1", "rpi2")
    for kind, m, stream, d, t, e, final in ops:
        model = models[m]
        device = devices[d]
        if kind == 0:
            parts = {"t_compute": t * 0.6, "t_overhead": t * 0.4,
                     "e_compute": e * 0.7, "e_overhead": e * 0.3}
            led.charge_round_segment(flops=t * 1e9, time_s=t, energy_j=e,
                                     parts=parts, stream=stream,
                                     model=model, device=device,
                                     final=final)
        elif kind == 1:
            led.charge_probe("cka", t, e, stream=stream, model=model,
                             device=device)
        elif kind == 2:
            led.charge_swap(time_s=t, energy_j=e, model=model,
                            stream=stream, device=device)
        else:
            led.charge_sync(time_s=t, energy_j=e, device=device,
                            stream=stream, model=model)
    for view in (led.per_model, led.per_stream, led.per_device):
        np.testing.assert_allclose(
            sum(v["time_s"] for v in view.values()), led.total_time_s,
            rtol=1e-9)
        np.testing.assert_allclose(
            sum(v["energy_j"] for v in view.values()), led.total_energy_j,
            rtol=1e-9)
        np.testing.assert_allclose(
            sum(v["flops"] for v in view.values()), led.total_flops,
            rtol=1e-9)
    assert led.rounds == sum(v["rounds"] for v in led.per_model.values())
    assert led.swaps == sum(v["swaps"] for v in led.per_model.values())
    assert led.syncs == sum(v["syncs"] for v in led.per_device.values())
