"""Observability layer (DESIGN.md §14): trace round trips through both
sinks, metrics/ledger reconciliation across all three attribution
dimensions, the NullTracer disabled-path bit-exactness contract, and the
fleet-preset trace's per-device/per-stream track completeness.

The load-bearing tests are `test_telemetry_disabled_is_bit_exact` (the
default session must not move a bit when instrumentation code is merely
*present*) and `test_reconciliation_all_dimensions` (summed span
durations and metric counters reproduce the CostLedger's attributions —
the trace *is* the ledger, unrolled over time)."""
import json
import logging

import numpy as np
import pytest

from repro.data.arrivals import Event
from repro.obs import (DEVICE_TIME_CATS, NULL_TRACER, MetricsRegistry,
                       TelemetrySpec, TraceEvent, Tracer, chrome_trace,
                       chrome_tracks, device_time, events_from_chrome,
                       load_chrome_trace, read_jsonl, write_chrome_trace,
                       write_jsonl)
from repro.runtime import RuntimeConfig, SlotConfig, edgeol_session
from repro.runtime.fleet import fleet_devices
from repro.runtime.scheduler import EventScheduler

SCALE = dict(batches_per_scenario=3, inferences=6, num_scenarios=2)


def _session(workload="two-stream", *, scale=SCALE, **cfg_kw):
    cfg = RuntimeConfig(slots={"cv": SlotConfig()}, workload=workload,
                        workload_scale=dict(scale), seed=0,
                        pretrain_epochs=1, compiled=True, **cfg_kw)
    return edgeol_session(cfg)


def _events():
    return [
        TraceEvent("round/cv", "round", 10.0, 2.5, stream=0, device="dev0",
                   slot="cv", args={"iters": 3, "recompiled": True}),
        TraceEvent("sync/cv", "sync", 20.0, 0.5, stream=-1, device="dev1",
                   slot="cv"),
        TraceEvent("serve/cv", "serve", 12.0, None, device="dev0",
                   slot="cv", args={"requests": 4}),
        TraceEvent("s1", "request", 12.0, 1.25, stream=1, slot="cv"),
    ]


# ---------------------------------------------------------------------------
# sinks: JSONL and Chrome round trips


def test_jsonl_round_trip_is_identity(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    events = _events()
    write_jsonl(events, path)
    assert read_jsonl(path) == events


def test_jsonl_malformed_line_names_file(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write('{"name": "ok", "cat": "round", "ts": 1.0}\n{oops\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl line 2"):
        read_jsonl(path)


def test_chrome_trace_round_trips_and_names_tracks(tmp_path):
    events = _events()
    doc = chrome_trace(events)
    tracks = chrome_tracks(doc)
    assert tracks["devices"] == ["dev0", "dev1"]
    # stream -1 (fleet-caused work) renders as the "fleet" track
    assert tracks["streams"] == ["fleet", "stream 0", "stream 1"]
    # inversion recovers the original event list up to ordering
    back = events_from_chrome(doc)
    key = lambda e: (e.ts, e.name, e.cat)  # noqa: E731
    assert sorted(back, key=key) == sorted(events, key=key)
    # and the on-disk loader accepts what the writer produced
    path = str(tmp_path / "trace.json")
    write_chrome_trace(events, path)
    loaded = load_chrome_trace(path)
    assert chrome_tracks(loaded) == tracks


def test_load_chrome_trace_rejects_malformed(tmp_path):
    bad = tmp_path / "broken.json"
    bad.write_text("{not json")
    with pytest.raises(ValueError, match=r"broken\.json"):
        load_chrome_trace(str(bad))
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    with pytest.raises(ValueError, match="non-empty"):
        load_chrome_trace(str(empty))


def test_device_time_sums_only_occupancy_spans():
    got = device_time(_events())
    # the "request" span has no device tag, the "serve" instant no dur —
    # only the round (2.5s on dev0) and the sync (0.5s on dev1) count
    assert got == {"dev0": 2.5, "dev1": 0.5}
    assert "request" not in DEVICE_TIME_CATS


# ---------------------------------------------------------------------------
# metrics registry


def test_metrics_counters_and_subset_sum():
    m = MetricsRegistry()
    m.counter("time_s", stream=0, device="dev0").inc(2.0)
    m.counter("time_s", stream=1, device="dev0").inc(3.0)
    m.counter("time_s", stream=1, device="dev1").inc(5.0)
    assert m.counter_value("time_s", stream=1, device="dev1") == 5.0
    assert m.sum_counters("time_s", device="dev0") == 5.0
    assert m.sum_counters("time_s", stream=1) == 8.0
    assert m.sum_counters("time_s") == 10.0
    assert m.label_values("time_s", "device") == ["dev0", "dev1"]


def test_metrics_histogram_summary_and_snapshot():
    m = MetricsRegistry()
    h = m.histogram("latency_s", stream=0)
    for v in (0.1, 0.4, 0.2, 0.9):
        h.observe(v)
    m.gauge("utilization", device="dev0").set(0.5)
    snap = m.snapshot()
    s = snap["histograms"]["latency_s{stream=0}"]
    assert s["count"] == 4 and s["min"] == 0.1 and s["max"] == 0.9
    assert abs(s["sum"] - 1.6) < 1e-12
    assert snap["gauges"]["utilization{device=dev0}"] == 0.5


# ---------------------------------------------------------------------------
# TelemetrySpec (the RuntimeConfig knob)


def test_telemetry_spec_round_trip_and_unknown_key():
    spec = TelemetrySpec(enabled=True, chrome_trace="t.json",
                         dispatch_events=False)
    assert TelemetrySpec.from_dict(spec.to_dict()) == spec
    assert TelemetrySpec.from_dict(TelemetrySpec().to_dict()) \
        == TelemetrySpec()
    with pytest.raises(ValueError, match="unknown key"):
        TelemetrySpec.from_dict({"enabled": True, "chrom_trace": "x"})
    # sink paths imply collection even without `enabled`
    assert TelemetrySpec(trace_jsonl="x.jsonl").active
    assert not TelemetrySpec().active


def test_runtime_config_round_trips_telemetry():
    cfg = RuntimeConfig(slots={"cv": SlotConfig()}, workload="two-stream",
                        telemetry=TelemetrySpec(enabled=True))
    back = RuntimeConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back.telemetry == cfg.telemetry
    # the default (inactive) spec stays out of the serialized form
    assert "telemetry" not in RuntimeConfig(
        slots={"cv": SlotConfig()}, workload="two-stream").to_dict()


# ---------------------------------------------------------------------------
# NullTracer disabled path: bit-exactness


def test_null_tracer_is_falsy_and_inert():
    assert not NULL_TRACER
    assert len(NULL_TRACER) == 0
    NULL_TRACER.span("round", "r", 0.0, 1.0)
    NULL_TRACER.instant("serve", "s", 0.0)
    assert NULL_TRACER.events == []
    assert Tracer()  # the live one is truthy even while empty


def test_telemetry_disabled_is_bit_exact():
    """The default session (telemetry=None) and an enabled one produce
    bitwise-identical results — instrumentation observes, never steers."""
    off = _session().run()
    rt = _session(telemetry=TelemetrySpec(enabled=True))
    on = rt.run()
    assert rt.telemetry is not None
    assert len(rt.telemetry.tracer.events) > 0
    np.testing.assert_array_equal(off.inference_accs, on.inference_accs)
    np.testing.assert_array_equal(off.val_curve, on.val_curve)
    assert off.total_time_s == on.total_time_s
    assert off.total_energy_j == on.total_energy_j
    assert off.compute_tflops == on.compute_tflops
    assert off.rounds == on.rounds
    assert off.per_stream == on.per_stream
    assert off.per_model == on.per_model
    assert off.per_device == on.per_device


# ---------------------------------------------------------------------------
# ledger <-> metrics <-> trace reconciliation


def test_reconciliation_all_dimensions():
    rt = _session(telemetry=TelemetrySpec(enabled=True), preemptible=True)
    res = rt.run()
    tel = rt.telemetry
    rec = tel.reconcile(res)
    assert set(rec) == {f"{d}.{f}" for d in
                        ("per_stream", "per_model", "per_device")
                        for f in ("time_s", "energy_j", "flops")}
    assert max(rec.values()) < 1e-9
    # the trace-side half: per-device span-duration sums reproduce the
    # ledger's device time attribution
    spans = device_time(tel.tracer.events)
    for dev, cell in res.per_device.items():
        np.testing.assert_allclose(spans.get(dev, 0.0), cell["time_s"],
                                   atol=1e-6)
    # snapshot attaches both halves
    snap = tel.snapshot(res)
    assert snap["trace_events"] == len(tel.tracer.events)
    assert max(snap["reconciliation"].values()) < 1e-9


def test_fleet_preset_trace_has_all_tracks(tmp_path):
    """ISSUE acceptance: on the fleet preset the Chrome trace loads, has
    one track per device and per stream, and span sums reconcile with the
    ledger's per-device totals."""
    path = str(tmp_path / "fleet.json")
    rt = _session(
        "fleet", scale=dict(SCALE, fleet_streams=4),
        telemetry=TelemetrySpec(enabled=True, chrome_trace=path),
        devices=fleet_devices(3, seed=0, speed_spread=0.4,
                              energy_spread=0.2),
        routing="least-loaded", aggregate_every=25.0)
    res = rt.run()
    assert res.syncs > 0
    doc = load_chrome_trace(path)          # CI's validating loader
    tracks = chrome_tracks(doc)
    assert tracks["devices"] == sorted(res.per_device)
    for s in range(4):
        assert f"stream {s}" in tracks["streams"]
    assert "fleet" in tracks["streams"]    # sync spans on FLEET_STREAM
    spans = device_time(events_from_chrome(doc))
    for dev, cell in res.per_device.items():
        np.testing.assert_allclose(spans.get(dev, 0.0), cell["time_s"],
                                   atol=1e-6)
    assert max(rt.telemetry.reconcile(res).values()) < 1e-9


# ---------------------------------------------------------------------------
# scheduler instrumentation + logged formerly-silent behaviors


def test_dispatch_instants_recorded():
    events = [Event(0.0, "data", 0, 0, stream=0),
              Event(1.0, "inference", 0, 0, stream=0),
              Event(2.0, "inference", 0, 1, stream=0)]
    sched = EventScheduler(events)
    sched.tracer = Tracer()
    sched.run(on_data=lambda e, b: None, on_inference=lambda e: None,
              on_inference_segment=lambda seg: None)
    dispatches = [e for e in sched.tracer.events if e.cat == "dispatch"]
    # segment-mode pops inner inference events in one go — each still
    # gets its own dispatch instant
    assert len(dispatches) == 3
    assert [d.ts for d in dispatches] == [0.0, 1.0, 2.0]


def test_probe_drop_is_counted_and_logged(caplog):
    sched = EventScheduler([Event(1.0, "probe", 0, 0, stream=2)])
    root = logging.getLogger("edgeol")
    old = root.propagate
    root.propagate = True  # let caplog's root handler see the record
    try:
        with caplog.at_level(logging.WARNING, logger="edgeol.scheduler"):
            sched.run(on_data=lambda e, b: None,
                      on_inference=lambda e: None)
    finally:
        root.propagate = old
    assert sched.dropped_probes == 1
    assert any("probe event dropped" in r.message and "stream 2"
               in r.message for r in caplog.records)
