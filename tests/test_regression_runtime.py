"""Fixed-seed regression tests pinning the decomposed runtime to the
pre-refactor monolith.

``tests/data/golden_runtime.json`` was captured by running the original
single-method ``ContinualRuntime.run`` (commit 780bab6's runtime, after
the jax-0.4.x compat fixes) on small fixed-seed configs. The decomposed
scheduler/executor/ledger/server runtime must reproduce every recorded
figure — accuracy trace, round/recompile counts, and the full CostLedger
breakdown — with micro-batching disabled.

Also covers the micro-batched-serving equivalence claim: per-request
accuracies are unchanged by coalescing for models whose predict is
per-example independent (LayerNorm ViT here; batch-statistic models like
the BN CNNs see tiny deviations by construction — DESIGN.md §5).

The construction API is part of the pinned surface (DESIGN.md §11): the
golden trace must replay bit-exact through the declarative
`RuntimeConfig`/`from_config` front door, through an equivalent
fully-declarative policy-stack config, *and* through the deprecated
legacy kwarg constructor (which must warn).
"""
import json
import os

import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (ETunerConfig, ETunerController, LazyTuneConfig,
                        SimFreezeConfig, etuner_stack_spec)
from repro.data import streams
from repro.models import build_model
from repro.runtime import RuntimeConfig, SlotConfig
from repro.runtime.continual import ContinualRuntime

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_runtime.json")


def _model_bench():
    model = build_model(get_reduced("mobilenetv2"))
    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=6,
                                 batch_size=8, seed=0)
    return model, bench


def _ctrl(model, method):
    ecfg = ETunerConfig(
        lazytune=method in ("lazy", "etuner"),
        simfreeze=method in ("freeze", "etuner"),
        detect_scenario_changes=False,
        lazytune_cfg=LazyTuneConfig(max_batches_needed=6),
        simfreeze_cfg=SimFreezeConfig(freeze_interval=6, min_history=2,
                                      cka_threshold=0.01))
    return ETunerController(model, ecfg)


def _config(**cfg_kw):
    hooks = cfg_kw.pop("hooks", ())
    return RuntimeConfig(slots={"default": SlotConfig(hooks=tuple(hooks))},
                         pretrain_epochs=1, seed=0, **cfg_kw)


def _run(method, hooks=(), legacy_kwargs=None, **cfg_kw):
    model, bench = _model_bench()
    ctrl = _ctrl(model, method)
    if legacy_kwargs is not None:
        rt = ContinualRuntime(model, bench, ctrl, pretrain_epochs=1,
                              seed=0, **legacy_kwargs)
    else:
        rt = ContinualRuntime.from_config(_config(hooks=hooks, **cfg_kw),
                                          model=model, benchmark=bench,
                                          controller=ctrl)
    return rt.run(inferences_total=16)


def _check(res, gold):
    assert res.rounds == gold["rounds"]
    assert res.recompiles == gold["recompiles"]
    np.testing.assert_allclose(res.avg_inference_acc,
                               gold["avg_inference_acc"], atol=1e-6)
    np.testing.assert_allclose(res.inference_accs, gold["inference_accs"],
                               atol=1e-6)
    np.testing.assert_allclose(res.val_curve, gold["val_curve"], atol=1e-5)
    np.testing.assert_allclose(res.total_time_s, gold["total_time_s"],
                               rtol=1e-5)
    np.testing.assert_allclose(res.total_energy_j, gold["total_energy_j"],
                               rtol=1e-5)
    np.testing.assert_allclose(res.compute_tflops, gold["compute_tflops"],
                               rtol=1e-5)
    assert set(res.breakdown) >= set(gold["breakdown"])
    for k, v in gold["breakdown"].items():
        np.testing.assert_allclose(res.breakdown[k], v, rtol=1e-5,
                                   atol=1e-9, err_msg=k)


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def test_etuner_matches_pre_refactor_runtime(golden):
    """Full ETuner path: LazyTune + SimFreeze + CKA probe charges +
    replay sampling from the shared RNG stream."""
    _check(_run("etuner"), golden["etuner"])


def test_hooks_match_pre_refactor_runtime(golden):
    """SimSiam semi-supervised + fake-quant paths, now declarative
    per-slot HookSpecs, must reproduce the inlined originals exactly."""
    from repro.runtime import HookSpec

    _check(_run("immed", hooks=(HookSpec("fake-quant", {"bits": 8}),
                                HookSpec("simsiam", {"fraction": 0.5}))),
           golden["semi_quant"])


def test_preemptible_off_replays_golden(golden):
    """QoS off (`preemptible=False`, explicit) keeps the runtime on the
    synchronous round path: the golden trace replays bit-exact, so the
    QoS layer is provably inert unless opted into."""
    _check(_run("etuner", preemptible=False), golden["etuner"])


def test_legacy_kwarg_constructor_warns_and_replays_golden(golden):
    """Acceptance (ISSUE): the deprecated ~18-kwarg constructor still
    replays the `preemptible=False` golden run bit-exact — it delegates
    to the same RuntimeConfig resolution — while emitting a
    DeprecationWarning that steers callers to `from_config`."""
    with pytest.warns(DeprecationWarning, match="legacy kwarg"):
        res = _run("etuner", legacy_kwargs=dict(preemptible=False))
    _check(res, golden["etuner"])
    with pytest.warns(DeprecationWarning, match="legacy kwarg"):
        res = _run("immed", legacy_kwargs=dict(unlabeled_fraction=0.5,
                                               quant_bits=8))
    _check(res, golden["semi_quant"])


def test_declarative_policy_stack_replays_golden(golden):
    """Acceptance (ISSUE): an equivalent fully-declarative RuntimeConfig
    — ETuner expressed as a policy-stack spec, no controller object
    injected — replays the golden run bit-exact, and the built stack's
    stats() match the ETunerController composition's."""
    model, bench = _model_bench()
    cfg = RuntimeConfig(
        slots={"default": SlotConfig(policies=etuner_stack_spec(
            detect_scenario_changes=False,
            lazytune_params={"max_batches_needed": 6.0},
            simfreeze_params={"freeze_interval": 6, "min_history": 2,
                              "cka_threshold": 0.01}))},
        pretrain_epochs=1, seed=0, preemptible=False)
    rt = ContinualRuntime.from_config(cfg, model=model, benchmark=bench)
    res = rt.run(inferences_total=16)
    _check(res, golden["etuner"])
    # the generic PolicyStack and the ETunerController composition are
    # the same policy: identical stats after identical runs
    assert res.controller_stats == _run("etuner").controller_stats


# ---------------------------------------------------------------------------
# micro-batched serving equivalence


def _run_vit(window):
    model = build_model(get_reduced("deit-tiny"))
    bench = streams.nc_benchmark(num_classes=10, num_scenarios=3, batches=4,
                                 batch_size=8, seed=0)
    ctrl = ETunerController(model, ETunerConfig(
        lazytune=False, simfreeze=False, detect_scenario_changes=False))
    rt = ContinualRuntime.from_config(
        RuntimeConfig(slots={"default": SlotConfig()}, pretrain_epochs=1,
                      seed=0, inference_window=window, inference_batch=8),
        model=model, benchmark=bench, controller=ctrl)
    return rt.run(inferences_total=12)


def test_microbatched_serving_matches_per_request():
    per_request = _run_vit(0.0)
    coalesced = _run_vit(10.0)
    np.testing.assert_allclose(coalesced.inference_accs,
                               per_request.inference_accs, atol=1e-6)
    np.testing.assert_allclose(coalesced.avg_inference_acc,
                               per_request.avg_inference_acc, atol=1e-6)
    # cost accounting is independent of the serving path
    assert coalesced.rounds == per_request.rounds
    np.testing.assert_allclose(coalesced.total_energy_j,
                               per_request.total_energy_j, rtol=1e-6)
