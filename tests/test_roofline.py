"""Roofline machinery: HLO collective parsing, per-device cost accounting,
model-FLOPs estimates."""
import jax
import pytest

from repro.configs import get_config
from repro.configs.base import TRAIN_4K, DECODE_32K
from repro.roofline import analysis as RA


def test_parse_collectives_counts_and_factors():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
  %rs = f32[8,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = f32[2,2]{1,0} collective-permute(%w)
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%p, %q)
  %ar-start = f32[10]{0} all-reduce-start(%r)
  %ar-done = f32[10]{0} all-reduce-done(%ar-start)
"""
    stats = RA.parse_collectives(hlo)
    assert stats.counts["all-reduce"] == 2      # ar + ar-start (done skipped)
    assert stats.counts["all-gather"] == 1
    assert stats.counts["reduce-scatter"] == 1
    assert stats.counts["collective-permute"] == 1
    assert stats.counts["all-to-all"] == 1
    # all-reduce has a 2x wire factor
    ar_bytes = 16 * 128 * 4 * 2 + 10 * 4 * 2
    assert stats.bytes_by_kind["all-reduce"] == pytest.approx(ar_bytes)
    # tuple-shaped all-to-all counts both operands
    assert stats.bytes_by_kind["all-to-all"] == pytest.approx(2 * 4 * 4 * 4)


def test_cost_analysis_is_per_device():
    """Documented invariant the roofline relies on: SPMD cost_analysis
    reports per-partition flops."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run via subprocess in CI)")


def test_model_flops_estimates():
    cfg = get_config("gemma2-2b")
    n = cfg.param_count()
    assert 2.0e9 < n < 3.5e9  # ~2.6B incl. embeddings
    f_train = RA.model_flops_estimate(cfg, TRAIN_4K)
    assert f_train == pytest.approx(6.0 * n * TRAIN_4K.tokens)
    f_dec = RA.model_flops_estimate(cfg, DECODE_32K)
    assert f_dec == pytest.approx(2.0 * n * DECODE_32K.global_batch)


def test_moe_active_params_smaller():
    cfg = get_config("qwen3-moe-30b-a3b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 25e9 < total < 36e9       # ~30B total
    assert 2e9 < active < 5e9        # ~3B active
    assert active < total / 5


def test_kimi_param_count_is_about_1t():
    cfg = get_config("kimi-k2-1t-a32b")
    assert 0.8e12 < cfg.param_count() < 1.3e12


def test_roofline_report_finalize():
    rep = RA.RooflineReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        flops_per_chip=197e12, bytes_per_chip=819e9,
        collective_bytes_per_chip=50e9, model_flops=197e12 * 256)
    rep.finalize()
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(1.0)
    assert rep.collective_s == pytest.approx(1.0)
    assert rep.flops_ratio == pytest.approx(1.0)
    assert rep.roofline_fraction() == pytest.approx(1.0)
