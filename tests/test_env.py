"""repro.env (DESIGN.md §15): battery conservation against the ledger,
thermal RC exactness, DVFS governor transitions, the ThrottlePolicy
facet, and the two ends of the integration contract — env disabled is
bit-exact with the pre-env runtime, and a finite battery on a fleet run
really throttles/evicts devices while the ledger never overdraws the
budget and the Chrome trace carries gauges + throttle spans."""
import math

import numpy as np
import pytest

from repro.core.policies import (BudgetThrottle, NullThrottle, PolicySpec,
                                 PolicyStackSpec, ThermalThrottle,
                                 build_throttle)
from repro.env import (BatteryModel, DeviceEnv, DvfsGovernor, EnvSpec,
                       EnvState, ThermalModel)
from repro.obs.export import events_from_chrome, load_chrome_trace
from repro.obs.spec import TelemetrySpec
from repro.runtime import RuntimeConfig, SlotConfig, edgeol_session
from repro.runtime.config import DeviceConfig

SCALE = dict(batches_per_scenario=3, inferences=6, num_scenarios=2)


def _session(workload="two-stream", *, scale=SCALE, slots=None, **cfg_kw):
    cfg = RuntimeConfig(slots=slots or {"cv": SlotConfig()},
                        workload=workload, workload_scale=dict(scale),
                        seed=0, pretrain_epochs=1, compiled=True, **cfg_kw)
    return edgeol_session(cfg)


def _assert_identical(a, b):
    assert a.rounds == b.rounds
    assert a.syncs == b.syncs
    np.testing.assert_array_equal(a.inference_accs, b.inference_accs)
    np.testing.assert_array_equal(a.val_curve, b.val_curve)
    assert a.total_time_s == b.total_time_s
    assert a.total_energy_j == b.total_energy_j
    assert a.per_stream == b.per_stream
    assert a.per_device == b.per_device


# ---------------------------------------------------------------------------
# EnvSpec


def test_env_spec_roundtrip_and_defaults_omitted():
    s = EnvSpec(battery_capacity_j=50.0, thermal_cap_c=60.0,
                dvfs_levels=(1.0, 0.5))
    d = s.to_dict()
    assert set(d) == {"battery_capacity_j", "thermal_cap_c", "dvfs_levels"}
    assert EnvSpec.from_dict(d) == s
    assert EnvSpec().to_dict() == {}          # all-defaults serializes empty
    assert not EnvSpec().active               # and is inactive
    assert EnvSpec(battery_capacity_j=1.0).active
    assert EnvSpec(thermal_cap_c=40.0).active


def test_env_spec_validation_actionable():
    with pytest.raises(ValueError, match="battery_capacity_j"):
        EnvSpec(battery_capacity_j=-1.0).validate()
    with pytest.raises(ValueError, match="dvfs_levels"):
        EnvSpec(dvfs_levels=(0.5, 1.0)).validate()   # must descend from 1.0
    with pytest.raises(ValueError, match="reserve"):
        EnvSpec(battery_reserve_frac=1.0).validate()
    with pytest.raises(ValueError, match="unknown"):
        EnvSpec.from_dict({"battery_capacity_mj": 1.0})


def test_device_config_env_roundtrip():
    dc = DeviceConfig("dev1", env=EnvSpec(battery_capacity_j=20.0))
    dc.validate("test")
    assert DeviceConfig.from_dict(dc.to_dict()) == dc
    # env-less config serializes without the key (backward-compatible)
    assert "env" not in DeviceConfig("dev0").to_dict()


# ---------------------------------------------------------------------------
# physics sub-models


def test_battery_drain_harvest_and_dead_threshold():
    b = BatteryModel(100.0, harvest_w=2.0, reserve_frac=0.1)
    b.drain(30.0)
    assert b.charge_j == 70.0 and b.drained_j == 30.0
    b.harvest(5.0)                            # +10 J
    assert b.charge_j == 80.0 and b.harvested_j == 10.0
    b.harvest(100.0)                          # clamped to capacity
    assert b.charge_j == 100.0
    assert not b.dead
    b.drain(91.0)                             # 9 J < 10% reserve
    assert b.dead and b.soc == pytest.approx(0.09)


def test_thermal_rc_step_is_exact_and_monotone():
    t = ThermalModel(ambient_c=25.0, resistance_c_per_w=2.0,
                     time_constant_s=30.0)
    steady = 25.0 + 3.0 * 2.0
    temps = [t.step(3.0, 10.0) for _ in range(20)]
    assert all(b > a for a, b in zip(temps, temps[1:]))  # monotone rise
    assert temps[-1] < steady
    assert temps[-1] == pytest.approx(steady, abs=1e-2)
    # exactness: composing two half-steps equals one full step
    a = ThermalModel(ambient_c=25.0, resistance_c_per_w=2.0,
                     time_constant_s=30.0)
    b = ThermalModel(ambient_c=25.0, resistance_c_per_w=2.0,
                     time_constant_s=30.0)
    a.step(3.0, 7.0)
    a.step(3.0, 13.0)
    b.step(3.0, 20.0)
    assert a.temp_c == pytest.approx(b.temp_c, rel=1e-12)
    # cooling relaxes back toward ambient, never below
    for _ in range(50):
        t.step(0.0, 10.0)
    assert t.temp_c == pytest.approx(25.0, abs=1e-3)


def test_dvfs_governor_heat_pulse_transitions():
    g = DvfsGovernor((1.0, 0.75, 0.5), cap_c=60.0, hysteresis_c=5.0)
    assert g.update(65.0) == 0.75             # step down under the pulse
    assert g.update(65.0) == 0.5
    assert g.update(65.0) == 0.5              # floor of the ladder
    assert g.update(57.0) == 0.5              # hysteresis band: hold
    assert g.update(54.0) == 0.75             # cooled below cap - hyst
    assert g.update(54.0) == 1.0
    assert g.transitions == 4
    off = DvfsGovernor((1.0, 0.5), cap_c=0.0)
    assert off.update(500.0) == 1.0           # cap 0 disables the governor


# ---------------------------------------------------------------------------
# ThrottlePolicy facet


def test_throttle_policies_decide_and_count():
    mains = EnvState(device="d", temperature_c=30.0, level=1.0)
    ok = EnvState(device="d", temperature_c=30.0, level=1.0, soc=0.5,
                  charge_j=50.0, reserve_j=5.0)
    dead = EnvState(device="d", temperature_c=30.0, level=1.0, soc=0.02,
                    charge_j=2.0, reserve_j=5.0, battery_dead=True)
    assert NullThrottle().allow_round(dead) and NullThrottle().stats() == {}
    bt = BudgetThrottle(min_soc=0.1)
    assert bt.allow_round(mains)              # no battery: always allow
    assert bt.allow_round(ok, energy_j=40.0)  # 40 <= 50 - 5
    assert not bt.allow_round(ok, energy_j=46.0)
    assert not bt.allow_round(dead, energy_j=0.1)
    assert bt.stats() == {"throttle_deferred": 2}
    tt = ThermalThrottle(max_temp_c=80.0)
    assert tt.allow_round(ok)
    hot = EnvState(device="d", temperature_c=85.0, level=0.5)
    assert not tt.allow_round(hot)
    assert tt.stats() == {"throttle_deferred": 1}


def test_throttle_spec_registry_and_stack_roundtrip():
    assert isinstance(build_throttle(PolicySpec("none")), NullThrottle)
    assert isinstance(build_throttle(
        PolicySpec("battery", {"min_soc": 0.2})), BudgetThrottle)
    with pytest.raises(ValueError, match="throttle"):
        build_throttle(PolicySpec("nope"))
    spec = PolicyStackSpec(throttle=PolicySpec("thermal",
                                               {"max_temp_c": 70.0}))
    assert PolicyStackSpec.from_dict(spec.to_dict()) == spec
    # the default facet serializes away entirely (pre-v7 specs reload)
    assert "throttle" not in PolicyStackSpec().to_dict()


# ---------------------------------------------------------------------------
# integration: disabled env is bit-exact


def test_inactive_env_and_null_throttle_are_bit_exact():
    devices = (DeviceConfig("dev0"), DeviceConfig("dev1"))
    base = _session(devices=devices, aggregate_every=50.0).run()
    # an all-defaults EnvSpec is inactive: no DeviceEnv is built
    inert = tuple(DeviceConfig(d.name, env=EnvSpec()) for d in devices)
    withenv = _session(devices=inert, aggregate_every=50.0).run()
    _assert_identical(base, withenv)
    # an explicit NullThrottle facet in the stack spec is equally inert
    pol = PolicyStackSpec(throttle=PolicySpec("none"))
    cfg_kw = dict(devices=devices, aggregate_every=50.0)
    withnull = _session(
        **cfg_kw, slots={"cv": SlotConfig(policies=pol)}).run()
    _assert_identical(base, withnull)


# ---------------------------------------------------------------------------
# integration: battery conservation against the ledger


def test_battery_drain_equals_per_device_ledger_energy():
    # a huge battery never throttles or dies, so the run is undisturbed
    # and drained joules must mirror the ledger's per-device energy 1:1
    env = EnvSpec(battery_capacity_j=1e9)
    devices = (DeviceConfig("dev0", env=env),
               DeviceConfig("dev1", env=env, speed_scale=1.5))
    rt = _session(devices=devices, aggregate_every=50.0)
    res = rt.run()
    envs = rt.fleet.envs
    assert set(envs) == {"dev0", "dev1"}
    for name, cell in res.per_device.items():
        assert envs[name].battery.drained_j == pytest.approx(
            cell["energy_j"], rel=1e-9)
        assert not envs[name].battery_dead


# ---------------------------------------------------------------------------
# integration: the power loop closes (ISSUE acceptance)


def test_finite_battery_fleet_throttles_within_budget(tmp_path):
    budget = 40.0
    env = EnvSpec(battery_capacity_j=budget, thermal_cap_c=26.0)
    devices = (DeviceConfig("dev0", env=env),
               DeviceConfig("dev1", env=env))
    pol = PolicyStackSpec(throttle=PolicySpec("battery"))
    trace = str(tmp_path / "env_trace.json")
    rt = _session(
        devices=devices, aggregate_every=50.0,
        slots={"cv": SlotConfig(policies=pol)},
        telemetry=TelemetrySpec(enabled=True, chrome_trace=trace))
    res = rt.run()
    # >= 1 device throttled (DVFS time or deferred rounds) or evicted
    engaged = any(cell["throttle_s"] > 0 or cell["battery_dead"] > 0
                  or cell["evicted"] > 0
                  for cell in res.per_device.values())
    deferred = res.controller_stats.get("throttle_deferred", 0)
    assert engaged or deferred > 0
    # ledger energy never exceeds the configured budget per device
    for name, cell in res.per_device.items():
        assert cell["energy_j"] <= budget + 1e-6
    # the Chrome trace validates and carries gauges + throttle marks
    doc = load_chrome_trace(trace)
    counters = {r["name"] for r in doc["traceEvents"]
                if r.get("ph") == "C"}
    assert {"temperature_c/dev0", "soc/dev0",
            "temperature_c/dev1", "soc/dev1"} <= counters
    evs = events_from_chrome(doc)
    assert any(e.cat == "gauge" for e in evs)      # "C" records invert
    assert any(e.cat == "throttle" for e in evs)   # spans or defer marks
    assert math.isfinite(res.total_energy_j)
