"""Unit tests for the ETuner core: curve fit, LazyTune, SimFreeze, OOD,
freeze plans."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AccuracyCurve, EnergyOODConfig, EnergyOODDetector,
                        FreezePlan, LazyTune, LazyTuneConfig,
                        SimFreeze, SimFreezeConfig, all_active, cka,
                        fit_accuracy_curve, lm_segments)


# ---------------------------------------------------------------------------
# curvefit


def test_curve_fit_recovers_saturating_curve():
    iters = np.array([1, 2, 4, 8, 16, 32, 64])
    true = AccuracyCurve(0.8, 0.5, 0.2)
    accs = true.predict(iters)
    fit = fit_accuracy_curve(iters, accs)
    np.testing.assert_allclose(fit.predict(iters), accs, atol=1e-6)
    # asymptote and monotonicity
    ks = np.linspace(1, 1000, 64)
    assert np.all(np.diff(fit.predict(ks)) >= -1e-9)


def test_curve_iters_for_gain_bisection():
    c = AccuracyCurve(0.8, 0.5, 0.0)
    k = c.iters_for_gain(10.0, 0.01)
    assert c.predict(k) - c.predict(10.0) >= 0.0099
    # unreachable gain returns k_max
    assert c.iters_for_gain(10.0, 1.0, k_max=1e6) == 1e6


def test_curve_fit_underdetermined_returns_none():
    assert fit_accuracy_curve([1.0], [0.5]) is None


# ---------------------------------------------------------------------------
# lazytune


def test_lazytune_trigger_threshold():
    lt = LazyTune(LazyTuneConfig())
    assert lt.should_trigger(1)
    lt.state.batches_needed = 4.0
    assert not lt.should_trigger(3)
    assert lt.should_trigger(4)


def test_lazytune_saturation_increases_batches_needed():
    """When accuracy saturates, matching the last (tiny) gain requires more
    data -> rounds get delayed and merged."""
    lt = LazyTune(LazyTuneConfig(max_batches_needed=64))
    accs = [0.5, 0.65, 0.72, 0.755, 0.772, 0.780, 0.784, 0.786]
    needed = []
    for a in accs:
        lt.round_finished(int(max(1, lt.state.batches_needed)), a)
        needed.append(lt.state.batches_needed)
    assert needed[-1] > needed[1]
    assert 1.0 <= needed[-1] <= 64.0


def test_lazytune_log_decay_on_inference():
    lt = LazyTune()
    lt.state.batches_needed = 20.0
    lt.inference_arrived()
    assert lt.state.batches_needed == pytest.approx(
        20.0 * (1 - 1 / np.log(20.0)))
    lt.state.batches_needed = 2.0  # log(d) <= 1 -> clamp to 1
    lt.inference_arrived()
    assert lt.state.batches_needed == 1.0


def test_lazytune_scenario_reset():
    lt = LazyTune()
    lt.round_finished(4, 0.5)
    lt.round_finished(4, 0.6)
    lt.state.batches_needed = 30.0
    lt.scenario_changed()
    assert lt.state.batches_needed == 1.0
    assert lt.state.curve is None


# ---------------------------------------------------------------------------
# cka


def test_cka_self_is_one():
    x = np.random.default_rng(0).normal(size=(64, 32))
    assert float(cka(jnp.asarray(x), jnp.asarray(x))) == pytest.approx(1.0, abs=1e-5)


def test_cka_forms_agree():
    from repro.core.cka import _center, cka_example_form, cka_feature_form

    rng = np.random.default_rng(1)
    x = _center(jnp.asarray(rng.normal(size=(48, 96)), jnp.float32))
    y = _center(jnp.asarray(rng.normal(size=(48, 80)), jnp.float32))
    # pad y features for the feature form (zero features are Gram-neutral)
    yp = jnp.pad(y, ((0, 0), (0, 16)))
    a = cka_example_form(x, y)
    b = cka_feature_form(x, yp)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


# ---------------------------------------------------------------------------
# freeze plans


def test_lm_segments_partition():
    plan = FreezePlan(groups=(True, True, False, True, False, False))
    segs = lm_segments(plan)
    assert segs == [(0, 2, True), (2, 3, False), (3, 4, True), (4, 6, False)]
    # contiguous cover
    assert segs[0][0] == 0 and segs[-1][1] == 6
    for (a, b, _), (c, d, _) in zip(segs, segs[1:]):
        assert b == c


def test_freeze_plan_hashable_and_mutators():
    p = all_active(4)
    p2 = p.freeze(1).freeze(2).unfreeze(2)
    assert p2.groups == (False, True, False, False)
    assert hash(p2) != hash(p)
    d = {p: 1, p2: 2}
    assert d[p2] == 2


# ---------------------------------------------------------------------------
# simfreeze


def _fake_model_features(weights):
    """Features are deterministic functions of per-unit 'weights'."""
    def features(params, probe):
        return [np.outer(probe, np.ones(4)) * w for w in params]

    return features


def test_simfreeze_freezes_stable_layers_and_unfreezes_on_change():
    probe = np.linspace(0, 1, 16)
    ref = [1.0, 1.0, 1.0]
    sf = SimFreeze(3, _fake_model_features(ref),
                   SimFreezeConfig(freeze_interval=1, min_history=2,
                                   never_freeze_head=False))
    sf.start_scenario(ref, probe)
    # two passes with identical params -> CKA stable -> all freeze
    sf.maybe_freeze([1.1, 1.1, 1.1], 1)
    assert not any(sf.state.frozen)
    sf.maybe_freeze([1.1, 1.1, 1.1], 1)
    assert all(sf.state.frozen)
    # scenario change with a probe that flips a layer's features
    sf2_params = [1.1, -5.0, 1.1]
    changed = sf.scenario_changed(sf2_params, probe + 3.0)
    assert isinstance(changed, bool)


# ---------------------------------------------------------------------------
# ood detector


def test_ood_detects_mean_shift():
    det = EnergyOODDetector(EnergyOODConfig(window=4, warmup=8,
                                            z_threshold=2.5, cooldown=4))
    rng = np.random.default_rng(0)
    fired = []
    for i in range(40):
        logits = rng.normal(0, 1, (8, 10)) + (0.0 if i < 25 else -6.0)
        fired.append(det.observe(logits))
    assert not any(fired[:25])
    assert any(fired[25:])


def test_ood_no_false_positives_stationary():
    det = EnergyOODDetector()
    rng = np.random.default_rng(3)
    fired = [det.observe(rng.normal(0, 1, (8, 10))) for _ in range(80)]
    assert sum(fired) == 0
