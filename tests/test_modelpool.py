"""ModelPool tests (DESIGN.md §9): residency/LRU/swap-cost mechanics at
the pool level (no models needed), the per-model CostLedger attribution
property, and the faithful two-modality `mixed` runtime — a real
BERT/20news NLP slot next to a CV slot on one device, per-slot inference
accounting consistent with the RunResult totals, and memory budgets small
enough to force swap charges into the breakdown."""
import numpy as np
import pytest

from repro.core import ETunerConfig, ETunerController
from repro.runtime import HookSpec, RuntimeConfig, SlotConfig, edgeol_session
from repro.runtime.continual import ContinualRuntime
from repro.runtime.costmodel import EdgeCostModel
from repro.runtime.executor import FakeQuantHook
from repro.runtime.ledger import CostLedger
from repro.runtime.modelpool import ModelPool, ModelSlot
from repro.workloads import compile_workload, presets


# ---------------------------------------------------------------------------
# pool unit: residency, LRU eviction, swap cost math


def _slot(name, mb, **cost_kw):
    return ModelSlot(name, model=None, benchmark=None, memory_mb=mb,
                     cost=EdgeCostModel(**cost_kw))


def test_pool_validation():
    with pytest.raises(ValueError):
        ModelPool([])
    with pytest.raises(ValueError):
        ModelPool([_slot("cv", 1.0), _slot("cv", 1.0)])
    pool = ModelPool([_slot("cv", 1.0)])
    with pytest.raises(KeyError):
        pool.slot("nlp")


def test_unlimited_budget_never_swaps():
    pool = ModelPool([_slot("cv", 10.0), _slot("nlp", 20.0)],
                     memory_budget_mb=0.0)
    assert pool.warm() == ("cv", "nlp")
    for name in ("nlp", "cv", "nlp"):
        assert pool.ensure_resident(name) == (0.0, 0.0, [])


def test_slot_too_big_for_budget_raises():
    pool = ModelPool([_slot("big", 5.0)], memory_budget_mb=2.0)
    with pytest.raises(ValueError):
        pool.set_memory("big", 5.0)
    with pytest.raises(ValueError):
        pool.ensure_resident("big")


def test_warm_fills_in_declaration_order():
    pool = ModelPool([_slot("a", 1.0), _slot("b", 1.0), _slot("c", 1.0)],
                     memory_budget_mb=2.0)
    assert pool.warm() == ("a", "b")
    assert not pool.is_resident("c")


def test_lru_eviction_order_and_touch_refresh():
    pool = ModelPool([_slot("a", 1.0), _slot("b", 1.0), _slot("c", 1.0)],
                     memory_budget_mb=2.0)
    pool.warm()
    # touching 'a' makes 'b' the least recently used
    pool.ensure_resident("a")
    t, e, evicted = pool.ensure_resident("c")
    assert evicted == ["b"] and t > 0 and e > 0
    assert pool.resident == ("a", "c")
    # and 'a' (still resident) is next to go when 'b' returns
    _, _, evicted = pool.ensure_resident("b")
    assert evicted == ["a"]


def test_swap_cost_uses_per_slot_cost_models():
    """Loading pays the incoming slot's t_load_s; each eviction pays the
    evicted slot's t_save_s — at the respective overhead powers."""
    a = _slot("a", 2.0, t_load_s=0.4, t_save_s=0.3, overhead_power_w=5.0)
    b = _slot("b", 2.0, t_load_s=0.7, t_save_s=0.2, overhead_power_w=8.0)
    pool = ModelPool([a, b], memory_budget_mb=2.0)
    pool.warm()                      # only 'a' fits
    t, e, evicted = pool.ensure_resident("b")
    assert evicted == ["a"]
    assert t == pytest.approx(0.7 + 0.3)
    assert e == pytest.approx(0.7 * 8.0 + 0.3 * 5.0)
    t, e, evicted = pool.ensure_resident("a")
    assert evicted == ["b"]
    assert t == pytest.approx(0.4 + 0.2)
    assert e == pytest.approx(0.4 * 5.0 + 0.2 * 8.0)


# ---------------------------------------------------------------------------
# ledger: per-model attribution sums to totals (property, ISSUE acceptance)


def test_ledger_per_model_and_per_stream_attributions_sum_to_totals():
    """Whatever interleaving of round segments, probes and swaps a run
    charges, the per-model and per-stream attributions each independently
    reconstruct the ledger totals."""
    rng = np.random.default_rng(7)
    led = CostLedger()
    models = ("cv", "nlp", "audio")
    for _ in range(300):
        model = models[rng.integers(len(models))]
        stream = int(rng.integers(4))
        kind = rng.integers(3)
        t = float(rng.uniform(0.01, 2.0))
        e = float(rng.uniform(0.1, 20.0))
        if kind == 0:
            f = float(rng.uniform(1e6, 1e9))
            parts = {"t_compute": t * 0.6, "t_overhead": t * 0.4,
                     "e_compute": e * 0.7, "e_overhead": e * 0.3}
            led.charge_round_segment(flops=f, time_s=t, energy_j=e,
                                     parts=parts, stream=stream,
                                     model=model,
                                     final=bool(rng.integers(2)))
        elif kind == 1:
            led.charge_probe("cka", t, e, stream=stream, model=model)
        else:
            led.charge_swap(time_s=t, energy_j=e, model=model,
                            stream=stream)
    for view in (led.per_model, led.per_stream):
        assert sum(v["time_s"] for v in view.values()) == \
            pytest.approx(led.total_time_s, rel=1e-12)
        assert sum(v["energy_j"] for v in view.values()) == \
            pytest.approx(led.total_energy_j, rel=1e-12)
        assert sum(v["flops"] for v in view.values()) == \
            pytest.approx(led.total_flops, rel=1e-12)
    assert sum(v["rounds"] for v in led.per_model.values()) == led.rounds
    assert led.swaps == sum(v["swaps"] for v in led.per_model.values())


# ---------------------------------------------------------------------------
# two-modality runtime: the faithful `mixed` preset


def _immed(model):
    return ETunerController(model, ETunerConfig(
        lazytune=False, simfreeze=False, detect_scenario_changes=False))


def _mixed_run(memory_budget_mb=0.0):
    from benchmarks.workloads import _stream_benchmarks, build_pool

    spec = presets(batches_per_scenario=3, inferences=8,
                   num_scenarios=2)["mixed"]
    benches = _stream_benchmarks(spec, 0, 8)
    pool = build_pool("mobilenetv2", spec, benches,
                      memory_budget_mb=memory_budget_mb)
    rt = ContinualRuntime.from_config(
        RuntimeConfig(seed=0, pretrain_epochs=1, inference_batch=8),
        stream_benchmarks=benches,
        controller_factory=lambda slot: _immed(pool.slot(slot).model),
        model_pool=pool)
    return rt.run(events=compile_workload(spec)), pool


@pytest.fixture(scope="module")
def mixed_runs():
    """(unbudgeted run, tight-budget run, tight pool)."""
    free, _ = _mixed_run(0.0)
    tight, pool = _mixed_run(2.5)  # fits one slot at a time -> must swap
    return free, tight, pool


def test_mixed_preset_runs_real_nlp_slot(mixed_runs):
    """Acceptance: the mixed preset trains and serves a real BERT/20news
    slot alongside the CV slot on one shared device."""
    free, _, _ = mixed_runs
    assert set(free.per_model) == {"cv", "nlp"}
    for slot in ("cv", "nlp"):
        assert free.per_model[slot]["rounds"] > 0
        assert free.per_model[slot]["inferences"] > 0
        assert free.per_model[slot]["flops"] > 0


def test_per_model_attribution_sums_to_totals(mixed_runs):
    """Acceptance: per-model CostLedger attribution sums to the totals —
    with and without swapping."""
    for res in mixed_runs[:2]:
        for key, total in (("time_s", res.total_time_s),
                           ("energy_j", res.total_energy_j),
                           ("rounds", float(res.rounds))):
            np.testing.assert_allclose(
                sum(v[key] for v in res.per_model.values()), total,
                rtol=1e-9)
        np.testing.assert_allclose(
            sum(v["flops"] for v in res.per_model.values()),
            res.compute_tflops * 1e12, rtol=1e-9)


def test_per_slot_inference_accounting_consistent(mixed_runs):
    """ISSUE satellite: a two-modality run's per-model inference counts
    and accuracies sum/average consistently with the RunResult totals
    (and with the per-stream view of the same requests)."""
    free, _, _ = mixed_runs
    n = len(free.inference_accs)
    for view in (free.per_model, free.per_stream):
        assert sum(v["inferences"] for v in view.values()) == n
        weighted = sum(v["avg_inference_acc"] * v["inferences"]
                       for v in view.values()) / n
        np.testing.assert_allclose(free.avg_inference_acc, weighted,
                                   atol=1e-9)
    # streams bind to slots: stream 0 is the cv slot's, stream 1 the nlp's
    assert free.per_model["cv"]["inferences"] == \
        free.per_stream[0]["inferences"]
    assert free.per_model["nlp"]["inferences"] == \
        free.per_stream[1]["inferences"]


def test_memory_budget_triggers_swap_charges(mixed_runs):
    """Acceptance: a memory budget smaller than both slots together
    forces evictions; the swap overhead shows up in the t_swap/e_swap
    breakdown, the per-model `swaps` counters, and the totals."""
    free, tight, pool = mixed_runs
    assert free.swaps == 0
    assert "t_swap" not in free.breakdown
    assert tight.swaps > 0
    assert tight.breakdown["t_swap"] > 0
    assert tight.breakdown["e_swap"] > 0
    assert sum(v["swaps"] for v in tight.per_model.values()) == tight.swaps
    # swapping costs real modeled time/energy on top of the same work
    assert tight.total_time_s > free.total_time_s
    assert tight.total_energy_j > free.total_energy_j
    # and the budget was honored: never both slots resident
    assert pool.memory_of("cv") + pool.memory_of("nlp") \
        > pool.memory_budget_mb


def test_cold_slot_inference_pays_swap_latency(mixed_runs):
    """A request routed to an evicted slot waits out the swap-in: some
    recorded serving latency must come from swaps even when the device
    was otherwise idle (the free-budget run had zero-latency serving at
    those instants)."""
    free, tight, _ = mixed_runs
    lat_free = sum(v["latency_p95"] for v in free.per_stream.values())
    lat_tight = sum(v["latency_p95"] for v in tight.per_stream.values())
    assert lat_tight > lat_free


def test_pool_rejects_round_hooks():
    """Global hooks (the legacy quant_bits kwarg, extra_hooks injection)
    wrap *one* model and stay rejected with a pool; per-slot binding goes
    through SlotConfig.hooks instead (test_quantized_slot_beside_fp32)."""
    pool = ModelPool([_slot("cv", 1.0)])
    with pytest.warns(DeprecationWarning), pytest.raises(ValueError):
        ContinualRuntime(None, None, None, model_pool=pool, quant_bits=8)
    with pytest.raises(ValueError, match="per slot"):
        ContinualRuntime.from_config(RuntimeConfig(), model_pool=pool,
                                     extra_hooks=[FakeQuantHook(8)])
    # hooks configured for a slot the pool does not have fail fast too
    with pytest.raises(ValueError, match="per slot"):
        ContinualRuntime.from_config(
            RuntimeConfig(slots={"audio": SlotConfig(
                hooks=(HookSpec("fake-quant", {"bits": 8}),))}),
            model_pool=pool)


def test_quantized_slot_beside_fp32_slot():
    """ISSUE satellite (RoundHooks under a pool): per-slot `hooks` in
    RuntimeConfig bind fake-quant QAT to the CV slot of the `mixed`
    preset while the NLP slot stays fp32 — instead of the pre-config
    runtime's blanket ValueError. Both slots train and serve; only the
    CV executor carries the hook, and the quantized CV slot's numbers
    diverge from the fp32 run's while NLP's stay identical."""
    from benchmarks.common import method_policies

    def run(cv_hooks):
        cfg = RuntimeConfig(
            workload="mixed",
            workload_scale=dict(batches_per_scenario=3, inferences=8,
                                num_scenarios=2),
            slots={"cv": SlotConfig(arch="mobilenetv2",
                                    policies=method_policies("immed"),
                                    hooks=cv_hooks),
                   "nlp": SlotConfig(arch="bert-base",
                                     policies=method_policies("immed"))},
            pretrain_epochs=1, inference_batch=8, seed=0)
        rt = edgeol_session(cfg)
        return rt, rt.run()

    rt_q, quant = run((HookSpec("fake-quant", {"bits": 8}),))
    assert [type(h).__name__ for h in rt_q.slot_hooks["cv"]] \
        == ["FakeQuantHook"]
    assert "nlp" not in rt_q.slot_hooks
    assert set(quant.per_model) == {"cv", "nlp"}
    for slot in ("cv", "nlp"):
        assert quant.per_model[slot]["rounds"] > 0
        assert quant.per_model[slot]["inferences"] > 0
    rt_f, fp32 = run(())
    assert rt_f.slot_hooks == {}
    # quantization perturbs the CV slot's training/serving, not NLP's
    assert quant.per_model["nlp"]["inferences"] == \
        fp32.per_model["nlp"]["inferences"]
    np.testing.assert_allclose(quant.per_model["nlp"]["avg_inference_acc"],
                               fp32.per_model["nlp"]["avg_inference_acc"],
                               atol=1e-9)
    assert quant.per_model["cv"]["inferences"] == \
        fp32.per_model["cv"]["inferences"]


def test_unknown_modality_fails_fast():
    from benchmarks.workloads import _stream_benchmarks, build_pool
    import dataclasses

    spec = presets(batches_per_scenario=2, inferences=4,
                   num_scenarios=2)["mixed"]
    benches = _stream_benchmarks(spec, 0, 8)
    pool = build_pool("mobilenetv2", spec, benches)
    events = compile_workload(spec)
    events = [dataclasses.replace(e, modality="audio") for e in events]
    rt = ContinualRuntime.from_config(
        RuntimeConfig(seed=0, pretrain_epochs=1),
        stream_benchmarks=benches,
        controller_factory=lambda slot: _immed(pool.slot(slot).model),
        model_pool=pool)
    with pytest.raises(KeyError):
        rt.run(events=events)
