"""Per-op microbenchmark of the Pallas kernels against their jnp oracles.

Times each runtime-facing kernel — flash attention (the `use_pallas`
serving forward), the CKA Gram-term probe (SimFreeze's drift metric) and
the RWKV wkv recurrence — in interpret mode next to its `ref.py` oracle,
and records the parity error alongside, so the bench artifact tracks
both the per-op cost *and* that the kernels still agree with the math
they replace. On CPU the interpret-mode numbers are emulation costs, not
device timings — the column exists for trajectory tracking (a kernel
whose interpret time explodes got structurally slower) and becomes a
real device measurement on TPU (`bootstrap(platform=...)`).

    PYTHONPATH=src python benchmarks/kernels_micro.py [--iters 5]

Writes ``BENCH_kernels_micro.json`` at the repo root (CI uploads it as
an artifact next to the workload sweep).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

SCHEMA_VERSION = 1
DEFAULT_OUT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..",
                 "BENCH_kernels_micro.json"))

#: Numeric fields every cell must carry (schema contract with CI).
CELL_FIELDS = ("pallas_ms", "ref_ms", "max_abs_err", "iters")


def _time(fn: Callable, iters: int) -> float:
    """Median wall ms per call, after one warmup (compile) call."""
    jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def _cases(seed: int) -> List[Dict]:
    rng = np.random.default_rng(seed)
    f32 = lambda *s: rng.standard_normal(s).astype(np.float32)

    cases = []

    # flash attention at the ViT serving shape (B=8 reduced images,
    # S=65 patch tokens) — the exact call `use_pallas` routes
    from repro.kernels.attention.ops import flash_attention
    from repro.kernels.attention.ref import attention_ref
    q, k, v = f32(8, 65, 3, 64), f32(8, 65, 3, 64), f32(8, 65, 3, 64)
    cases.append(dict(
        op="flash_attention", shape="B8xS65xH3xhd64 causal=False",
        pallas=lambda: flash_attention(q, k, v, causal=False),
        ref=lambda: attention_ref(q, k, v, causal=False)))

    # CKA ratio at the SimFreeze probe shape (one probe batch of
    # activations, flattened tokens x width) — the scalar the drift
    # detector actually consumes, so parity is in CKA units
    from repro.kernels.cka.ops import cka
    from repro.kernels.cka.ref import cka_ref
    x, y = f32(520, 192), f32(520, 192)
    cases.append(dict(
        op="cka", shape="520x192",
        pallas=lambda: cka(x, y),
        ref=lambda: cka_ref(x, y)))

    # RWKV wkv recurrence (the SSM zoo's sequential core)
    from repro.kernels.rwkv.ops import wkv
    from repro.kernels.rwkv.ref import wkv_ref
    r, kk, vv = f32(2, 128, 2, 64), f32(2, 128, 2, 64), f32(2, 128, 2, 64)
    logw = -np.exp(f32(2, 128, 2, 64) * 0.1).astype(np.float32)
    u = f32(2, 64)
    cases.append(dict(
        op="rwkv_wkv", shape="B2xT128xH2xhd64",
        pallas=lambda: wkv(r, kk, vv, logw, u, bt=64),
        ref=lambda: wkv_ref(r, kk, vv, logw, u)))
    return cases


def run(iters: int = 5, seed: int = 0) -> Dict:
    cells = []
    for case in _cases(seed):
        out_p = np.asarray(jax.tree.leaves(case["pallas"]())[0])
        out_r = np.asarray(jax.tree.leaves(case["ref"]())[0])
        err = float(np.max(np.abs(out_p - out_r)))
        cell = {
            "op": case["op"], "shape": case["shape"],
            "pallas_ms": round(_time(case["pallas"], iters), 3),
            "ref_ms": round(_time(case["ref"], iters), 3),
            "max_abs_err": err, "iters": iters,
        }
        cells.append(cell)
        print(f"kernels_micro,{cell['op']},{cell['shape']},"
              f"pallas={cell['pallas_ms']}ms ref={cell['ref_ms']}ms "
              f"err={err:.2e}", flush=True)
    return {
        "schema_version": SCHEMA_VERSION, "suite": "kernels_micro",
        "seed": seed, "created_unix": int(time.time()),
        "jax_version": jax.__version__,
        "interpret": True, "cells": cells,
    }


def validate_bench(doc: Dict) -> List[str]:
    """Return a list of schema violations (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version != {SCHEMA_VERSION}")
    if doc.get("suite") != "kernels_micro":
        errors.append("suite != 'kernels_micro'")
    cells = doc.get("cells") or []
    if not isinstance(cells, list) or len(cells) < 3:
        errors.append("cells must list at least the 3 kernel ops")
        return errors
    for i, cell in enumerate(cells):
        if not cell.get("op") or not cell.get("shape"):
            errors.append(f"cell {i}: missing op/shape")
        for f in CELL_FIELDS:
            v = cell.get(f)
            if not isinstance(v, (int, float)) or v != v or v < 0:
                errors.append(f"cell {i}: field {f!r} missing or not a "
                              f"non-negative finite number (got {v!r})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an existing artifact and exit")
    args = ap.parse_args()

    from repro.launch.platform import bootstrap
    bootstrap()

    if args.validate:
        with open(args.validate) as f:
            errors = validate_bench(json.load(f))
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        print(f"{args.validate}: " +
              ("INVALID" if errors else "schema valid"))
        return 1 if errors else 0

    doc = run(iters=args.iters, seed=args.seed)
    errors = validate_bench(doc)
    if errors:
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}: {len(doc['cells'])} kernel cells")
    return 0


if __name__ == "__main__":
    sys.exit(main())
