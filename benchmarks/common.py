"""Shared benchmark harness: run the 4 ETuner configurations and the SOTA
baselines on a continual benchmark, returning paper-style rows.

The four paper methods are expressed as declarative policy stacks
(`method_policies` -> `repro.core.policies.PolicyStackSpec`); the SOTA
baselines stay monolithic controller objects (they predate the policy
decomposition and exercise the legacy-adapter path). Runtime construction
goes through the `RuntimeConfig` front door (DESIGN.md §11).

Every number is produced by the real runtime (jitted training, measured
HLO FLOPs) + the calibrated EdgeCostModel; nothing is hard-coded."""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from repro.baselines import (EgeriaController, EkyaController, RigLController,
                             SlimFitController, StaticController)
from repro.configs import get_reduced
from repro.core.policies import PolicySpec, PolicyStackSpec
from repro.data import streams
from repro.models import build_model
from repro.runtime import (ContinualRuntime, HookSpec, RuntimeConfig,
                           SlotConfig)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

#: the four paper ablations (Immed. / LazyTune / SimFreeze / ETuner)
PAPER_METHODS = ("immed", "lazytune", "simfreeze", "etuner")

# Accuracy-preserving operating point at reduced scale (EXPERIMENTS.md
# discusses the savings-vs-accuracy frontier; the paper's streams are ~10x
# longer, which is what unlocks its -64% time at +1.75% accuracy).
ET_LAZYTUNE = {"max_batches_needed": 6.0}
ET_SIMFREEZE = {"freeze_interval": 10, "min_history": 3,
                "cka_threshold": 0.01}


def method_policies(method: str,
                    trigger_policy: str = "default") -> PolicyStackSpec:
    """The policy stack of one paper method. `trigger_policy` swaps the
    LazyTune trigger for its priority-weighted variant
    ("priority-weighted", BENCH schema v4): the accumulation target is
    scaled by each stream's QoS priority, so it only makes sense for the
    LazyTune-bearing methods."""
    if method not in PAPER_METHODS:
        raise KeyError(method)
    lazy = method in ("lazytune", "etuner")
    freeze = method in ("simfreeze", "etuner")
    if trigger_policy == "default":
        trigger = PolicySpec("lazytune", dict(ET_LAZYTUNE)) if lazy \
            else PolicySpec("immediate")
    elif trigger_policy == "priority-weighted":
        if not lazy:
            raise ValueError(
                f"trigger_policy 'priority-weighted' scales LazyTune's "
                f"accumulation target; method {method!r} has no LazyTune")
        trigger = PolicySpec("priority-weighted", dict(ET_LAZYTUNE))
    else:
        raise ValueError(f"unknown trigger_policy {trigger_policy!r}; "
                         f"known: ['default', 'priority-weighted']")
    return PolicyStackSpec(
        trigger=trigger,
        freeze=PolicySpec("simfreeze", dict(ET_SIMFREEZE)) if freeze
        else PolicySpec("none"),
        drift=PolicySpec("none"))


def make_controller(model, method: str, trigger_policy: str = "default"):
    if method in PAPER_METHODS:
        return method_policies(method, trigger_policy).build(model)
    if trigger_policy != "default":
        raise ValueError(f"trigger_policy={trigger_policy!r} only applies "
                         f"to the paper methods {PAPER_METHODS}")
    if method == "egeria":
        return EgeriaController(model, with_lazytune=True, interval=4)
    if method == "slimfit":
        return SlimFitController(model, with_lazytune=True, interval=4,
                                 threshold=0.05)
    if method == "rigl":
        return RigLController(model, with_lazytune=True, sparsity=0.5)
    if method == "ekya":
        return EkyaController(model, with_lazytune=True, window_batches=6)
    if method.startswith("static"):
        return StaticController(model, interval=int(method.replace("static", "")))
    raise KeyError(method)


def run_method(arch: str, bench_name: str, method: str, *, seeds=(0,),
               batches: int = 16, scenarios: int = 4, inferences: int = 40,
               quant_bits: int = 0, unlabeled: float = 0.0,
               data_dist: str = "poisson", inf_dist: str = "poisson",
               inference_window: float = 0.0) -> Dict:
    accs, times, energies, tflops, rounds = [], [], [], [], []
    hooks = []
    if quant_bits:
        hooks.append(HookSpec("fake-quant", {"bits": quant_bits}))
    if unlabeled:
        hooks.append(HookSpec("simsiam", {"fraction": unlabeled}))
    for seed in seeds:
        cfg = get_reduced(arch)
        model = build_model(cfg)
        if bench_name == "20news":
            bench = streams.text_benchmark(num_scenarios=scenarios,
                                           batches=batches, seed=seed)
        else:
            maker = streams.REGISTRY[bench_name]
            kw = dict(batches=batches, seed=seed)
            if bench_name != "s-cifar":
                kw["num_scenarios"] = scenarios
            bench = maker(**kw)
        ctrl = make_controller(model, method)
        if method == "rigl":
            model = ctrl.wrap_model()
        rt = ContinualRuntime.from_config(
            RuntimeConfig(
                slots={"default": SlotConfig(arch=arch,
                                             hooks=tuple(hooks))},
                seed=seed, pretrain_epochs=2,
                inference_window=inference_window),
            model=model, benchmark=bench, controller=ctrl)
        res = rt.run(inferences_total=inferences, data_dist=data_dist,
                     inf_dist=inf_dist)
        # Ekya's trial-and-error profiling cost (extra rounds of compute)
        if method == "ekya":
            extra = ctrl.profile_rounds * 0.2 * res.total_energy_j / max(res.rounds, 1)
            res.total_energy_j += extra
            res.total_time_s += ctrl.profile_rounds * 0.2 * res.total_time_s / max(res.rounds, 1)
        accs.append(res.avg_inference_acc)
        times.append(res.total_time_s)
        energies.append(res.total_energy_j)
        tflops.append(res.compute_tflops)
        rounds.append(res.rounds)
    return {"arch": arch, "bench": bench_name, "method": method,
            "acc": float(np.mean(accs)), "acc_std": float(np.std(accs)),
            "time_s": float(np.mean(times)),
            "energy_j": float(np.mean(energies)),
            "tflops": float(np.mean(tflops)),
            "rounds": float(np.mean(rounds)), "seeds": len(seeds)}


def save_rows(name: str, rows: List[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def print_csv(name: str, rows: List[dict], keys=("acc", "time_s", "energy_j")):
    for r in rows:
        derived = " ".join(f"{k}={r[k]:.4g}" for k in keys if k in r)
        print(f"{name},{r['arch']}/{r['bench']}/{r['method']},{derived}")
