"""Shared benchmark harness: run the 4 ETuner configurations and the SOTA
baselines on a continual benchmark, returning paper-style rows.

Every number is produced by the real runtime (jitted training, measured
HLO FLOPs) + the calibrated EdgeCostModel; nothing is hard-coded."""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from repro.baselines import (EgeriaController, EkyaController, RigLController,
                             SlimFitController, StaticController)
from repro.configs import get_reduced
from repro.core import (ETunerConfig, ETunerController, LazyTuneConfig,
                        SimFreezeConfig)
from repro.data import streams
from repro.models import build_model
from repro.runtime.continual import ContinualRuntime

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# Accuracy-preserving operating point at reduced scale (EXPERIMENTS.md
# discusses the savings-vs-accuracy frontier; the paper's streams are ~10x
# longer, which is what unlocks its -64% time at +1.75% accuracy).
ET_KW = dict(lazytune_cfg=LazyTuneConfig(max_batches_needed=6),
             simfreeze_cfg=SimFreezeConfig(freeze_interval=10, min_history=3,
                                           cka_threshold=0.01))


def make_controller(model, method: str):
    if method == "immed":
        return ETunerController(model, ETunerConfig(
            lazytune=False, simfreeze=False, detect_scenario_changes=False))
    if method == "lazytune":
        return ETunerController(model, ETunerConfig(
            lazytune=True, simfreeze=False, detect_scenario_changes=False,
            **ET_KW))
    if method == "simfreeze":
        return ETunerController(model, ETunerConfig(
            lazytune=False, simfreeze=True, detect_scenario_changes=False,
            **ET_KW))
    if method == "etuner":
        return ETunerController(model, ETunerConfig(
            lazytune=True, simfreeze=True, detect_scenario_changes=False,
            **ET_KW))
    if method == "egeria":
        return EgeriaController(model, with_lazytune=True, interval=4)
    if method == "slimfit":
        return SlimFitController(model, with_lazytune=True, interval=4,
                                 threshold=0.05)
    if method == "rigl":
        return RigLController(model, with_lazytune=True, sparsity=0.5)
    if method == "ekya":
        return EkyaController(model, with_lazytune=True, window_batches=6)
    if method.startswith("static"):
        return StaticController(model, interval=int(method.replace("static", "")))
    raise KeyError(method)


def run_method(arch: str, bench_name: str, method: str, *, seeds=(0,),
               batches: int = 16, scenarios: int = 4, inferences: int = 40,
               quant_bits: int = 0, unlabeled: float = 0.0,
               data_dist: str = "poisson", inf_dist: str = "poisson",
               inference_window: float = 0.0) -> Dict:
    accs, times, energies, tflops, rounds = [], [], [], [], []
    for seed in seeds:
        cfg = get_reduced(arch)
        model = build_model(cfg)
        if bench_name == "20news":
            bench = streams.text_benchmark(num_scenarios=scenarios,
                                           batches=batches, seed=seed)
        else:
            maker = streams.REGISTRY[bench_name]
            kw = dict(batches=batches, seed=seed)
            if bench_name != "s-cifar":
                kw["num_scenarios"] = scenarios
            bench = maker(**kw)
        ctrl = make_controller(model, method)
        if method == "rigl":
            model = ctrl.wrap_model()
        rt = ContinualRuntime(model, bench, ctrl, pretrain_epochs=2,
                              seed=seed, quant_bits=quant_bits,
                              unlabeled_fraction=unlabeled,
                              inference_window=inference_window)
        res = rt.run(inferences_total=inferences, data_dist=data_dist,
                     inf_dist=inf_dist)
        # Ekya's trial-and-error profiling cost (extra rounds of compute)
        if method == "ekya":
            extra = ctrl.profile_rounds * 0.2 * res.total_energy_j / max(res.rounds, 1)
            res.total_energy_j += extra
            res.total_time_s += ctrl.profile_rounds * 0.2 * res.total_time_s / max(res.rounds, 1)
        accs.append(res.avg_inference_acc)
        times.append(res.total_time_s)
        energies.append(res.total_energy_j)
        tflops.append(res.compute_tflops)
        rounds.append(res.rounds)
    return {"arch": arch, "bench": bench_name, "method": method,
            "acc": float(np.mean(accs)), "acc_std": float(np.std(accs)),
            "time_s": float(np.mean(times)),
            "energy_j": float(np.mean(energies)),
            "tflops": float(np.mean(tflops)),
            "rounds": float(np.mean(rounds)), "seeds": len(seeds)}


def save_rows(name: str, rows: List[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def print_csv(name: str, rows: List[dict], keys=("acc", "time_s", "energy_j")):
    for r in rows:
        derived = " ".join(f"{k}={r[k]:.4g}" for k in keys if k in r)
        print(f"{name},{r['arch']}/{r['bench']}/{r['method']},{derived}")
