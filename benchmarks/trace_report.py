"""Summarize an EdgeOL telemetry trace (DESIGN.md §14).

Reads either sink format — the JSONL event feed or the Chrome
trace-event export (`events_from_chrome` inverts it) — and prints three
human summaries of the modeled run:

- a per-device **utilization timeline** (bucketed occupancy bars),
- a per-device **round Gantt** (fine-tune rounds / segments / syncs as
  they landed on each lane),
- the **top-N slowest spans** (where the modeled device time went).

``--validate`` instead runs the strict Chrome-trace loader and exits
non-zero on a malformed file — the CI gate for the bench-smoke artifact.

    PYTHONPATH=src python -m benchmarks.trace_report trace.json
    PYTHONPATH=src python -m benchmarks.trace_report trace.jsonl --top 20
    PYTHONPATH=src python -m benchmarks.trace_report trace.json --validate
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.obs import (DEVICE_TIME_CATS, TraceEvent, chrome_tracks,
                       device_time, events_from_chrome, load_chrome_trace,
                       read_jsonl)

#: Occupancy ramp for the utilization bars: " " = idle, "#" = saturated.
RAMP = " .:-=#"

#: Default bucket count of the utilization timeline.
BUCKETS = 60


def load_events(path: str) -> List[TraceEvent]:
    """Load a trace from either sink format: a ``.jsonl`` suffix (or a
    first line that parses as a single event record) means the JSONL
    feed, anything else the Chrome export."""
    if path.endswith(".jsonl"):
        return read_jsonl(path)
    with open(path) as f:
        first = f.readline()
    try:
        head = json.loads(first)
    except json.JSONDecodeError:
        head = None
    if isinstance(head, dict) and "traceEvents" not in head \
            and "ts" in head:
        return read_jsonl(path)
    return events_from_chrome(load_chrome_trace(path))


# ---------------------------------------------------------------------------
# summaries


def _span_of(events: List[TraceEvent]) -> tuple:
    ts = [e.ts for e in events] + \
        [e.ts + e.dur for e in events if e.dur is not None]
    return (min(ts), max(ts)) if ts else (0.0, 0.0)


def utilization_timeline(events: List[TraceEvent], *,
                         buckets: int = BUCKETS) -> str:
    """Per-device occupancy bars: each column is one time bucket, its
    glyph the fraction of the bucket covered by device-time spans."""
    t0, t1 = _span_of(events)
    width = max(t1 - t0, 1e-9)
    step = width / buckets
    occ: Dict[str, List[float]] = {}
    for e in events:
        if e.dur is None or e.device is None or e.cat not in DEVICE_TIME_CATS:
            continue
        lane = occ.setdefault(e.device, [0.0] * buckets)
        lo, hi = e.ts, e.ts + e.dur
        b0 = max(0, min(buckets - 1, int((lo - t0) / step)))
        b1 = max(0, min(buckets - 1, int((hi - t0) / step)))
        for b in range(b0, b1 + 1):
            blo, bhi = t0 + b * step, t0 + (b + 1) * step
            lane[b] += max(0.0, min(hi, bhi) - max(lo, blo))
    lines = [f"utilization ({t0:.1f}s .. {t1:.1f}s, "
             f"{step:.2f}s/bucket, ramp '{RAMP}')"]
    for dev in sorted(occ):
        busy = device_time(events).get(dev, 0.0)
        bar = "".join(
            RAMP[min(len(RAMP) - 1, int(frac / step * (len(RAMP) - 1) + 1e-9))]
            if frac > 0 else RAMP[0]
            for frac in occ[dev])
        lines.append(f"  {dev:>8} |{bar}| busy {busy:.1f}s "
                     f"({busy / width * 100:.0f}%)")
    if len(lines) == 1:
        lines.append("  (no device-time spans in trace)")
    return "\n".join(lines)


def round_gantt(events: List[TraceEvent], *, limit: int = 40) -> str:
    """Chronological listing of the fine-tune work per device lane:
    rounds, preemption segments, resumes, swaps and fleet syncs."""
    cats = {"round", "segment", "resume", "swap", "sync"}
    rows = sorted((e for e in events
                   if e.dur is not None and e.device is not None
                   and e.cat in cats),
                  key=lambda e: (e.ts, e.device or ""))
    lines = [f"round gantt ({len(rows)} spans"
             + (f", first {limit} shown" if len(rows) > limit else "")
             + ")"]
    for e in rows[:limit]:
        tag = f" stream {e.stream}" if e.stream is not None \
            and e.stream >= 0 else ""
        extra = ""
        if e.args.get("recompiled"):
            extra += " [recompiled]"
        if e.cat == "segment":
            extra += f" seg#{e.args.get('seg', '?')}" + \
                (" final" if e.args.get("final") else "")
        lines.append(f"  {e.ts:9.2f}s +{e.dur:7.2f}s  {e.device:>8} "
                     f"{e.cat:>7} {e.name}{tag}{extra}")
    if len(rows) == 0:
        lines.append("  (no fine-tune spans in trace)")
    return "\n".join(lines)


def slowest_spans(events: List[TraceEvent], *, top: int = 10) -> str:
    """The top-N duration spans — where the modeled time went."""
    spans = sorted((e for e in events if e.dur is not None),
                   key=lambda e: -e.dur)[:top]
    lines = [f"top {len(spans)} slowest spans"]
    for e in spans:
        where = e.device or (f"stream {e.stream}"
                             if e.stream is not None else "?")
        lines.append(f"  {e.dur:9.3f}s  {e.cat:>7} {e.name:<20} on {where} "
                     f"@ {e.ts:.2f}s")
    if not spans:
        lines.append("  (no duration spans in trace)")
    return "\n".join(lines)


def summarize(events: List[TraceEvent], *, top: int = 10,
              buckets: int = BUCKETS, gantt_limit: int = 40) -> str:
    n_inst = sum(1 for e in events if e.dur is None)
    devs = sorted({e.device for e in events if e.device is not None})
    streams = sorted({e.stream for e in events if e.stream is not None})
    head = (f"{len(events)} events ({len(events) - n_inst} spans, "
            f"{n_inst} instants) | devices: {', '.join(devs) or '-'} | "
            f"streams: {', '.join(str(s) for s in streams) or '-'}")
    return "\n\n".join([head,
                        utilization_timeline(events, buckets=buckets),
                        round_gantt(events, limit=gantt_limit),
                        slowest_spans(events, top=top)])


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace file: Chrome JSON or JSONL")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest spans to list (default 10)")
    ap.add_argument("--buckets", type=int, default=BUCKETS,
                    help="utilization timeline buckets (default 60)")
    ap.add_argument("--gantt", type=int, default=40,
                    help="max gantt rows (default 40)")
    ap.add_argument("--validate", action="store_true",
                    help="strict Chrome-trace validation only (CI gate): "
                         "check structure + track metadata, print the "
                         "track inventory, exit non-zero on failure")
    args = ap.parse_args(argv)

    if args.validate:
        try:
            doc = load_chrome_trace(args.trace)
        except ValueError as e:
            print(f"INVALID: {e}", file=sys.stderr)
            return 1
        tracks = chrome_tracks(doc)
        print(f"{args.trace}: valid Chrome trace, "
              f"{len(doc['traceEvents'])} records")
        print(f"  device tracks: {json.dumps(tracks['devices'])}")
        print(f"  stream tracks: {json.dumps(tracks['streams'])}")
        return 0

    try:
        events = load_events(args.trace)
    except (ValueError, OSError) as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 1
    print(summarize(events, top=args.top, buckets=args.buckets,
                    gantt_limit=args.gantt))
    return 0


if __name__ == "__main__":
    sys.exit(main())
