"""Compare two ``BENCH_workloads.json`` artifacts and flag regressions.

The ROADMAP's "BENCH trajectory tooling" starter: CI regenerates the
quick sweep on every push and diffs it against the committed baseline —
a cell whose metric moves beyond the noise threshold *in the bad
direction* (accuracy down; modeled time/energy/FLOPs up) fails the job,
so a perf/accuracy regression can't land silently. The same directional
gate covers the per-stream `latency_p50`/`latency_p95` serving-latency
columns (upward = regression; sub-millisecond absolute moves are noise)
and the v3 per-model-slot columns (slot costs up / slot accuracy down =
regression). v4 cells are additionally keyed by `trigger_policy`, so the
priority-weighted-trigger qos cells are gated independently of their
default-trigger siblings. v5 adds two soft directional gates for the
compiled hot path: `wall_s` fails beyond 1.5x the baseline cell (0.5s
absolute floor — wall time is host-measured and noisy) and `recompiles`
fails when a cell grows more than 2 extra XLA programs (compile-ledger
churn). v6 extends the same directional gate to the
per-device attribution columns (device costs/syncs up, device serving
accuracy down) — and a baseline device entry that vanishes from a cell
fails, so a fleet quietly shrinking can't land. v7 additionally keys
cells by their `throttle` mode (the fleet preset's mains and
finite-battery env cells are gated independently) and extends the
per-device gate to the env columns: `battery_dead` and `throttle_s`
regress upward. Baseline cells — and
baseline per-stream/per-model/per-device entries — that vanish also fail
(coverage must never shrink); brand-new cells are reported but don't
fail.

Accuracy gets its own (wider) threshold: cell accuracies average a few
dozen requests, so XLA-CPU codegen differences between the machine that
committed the baseline and the CI runner can flip a borderline request
(~several % relative) with no code change — ``--acc-threshold`` defaults
to 0.25, loose enough to absorb a flip or two yet still catching real
accuracy collapses. The modeled cost metrics stay tight by default; note
they too can step by roughly one round's worth (~10%) when a borderline
val accuracy flips an accuracy-adaptive controller's trigger decision,
which is why CI passes an intermediate ``--threshold``.

    PYTHONPATH=src python benchmarks/bench_diff.py BASE.json NEW.json \
        [--threshold 0.05] [--acc-threshold 0.25] [--list-all]

Exit codes: 0 = within noise, 1 = regression(s), 2 = incomparable
documents (schema mismatch / unreadable).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

#: metric -> direction that counts as a regression ('down' = lower is a
#: regression, 'up' = higher is). Modeled costs regress upward; accuracy
#: regresses downward. `rounds` is a scheduling outcome, not a cost —
#: drifts there show up in time/energy anyway, so it is reported but
#: never fails the diff. v5 promotes two host-side columns to soft
#: directional gates: `wall_s` (the compiled hot path's headline win —
#: wide 50% threshold + 0.5s absolute floor, because wall time on a
#: shared CI runner is noisy) and `recompiles` (the compile-ledger churn
#: guard — a cell quietly re-paying XLA compiles per round fails even
#: when modeled costs are flat; ±2 programs is machine noise).
METRIC_DIRECTIONS = {
    "acc": "down",
    "time_s": "up",
    "energy_j": "up",
    "tflops": "up",
    "wall_s": "up",
    "recompiles": "up",
}
INFO_METRICS = ("rounds", "preemptions", "swaps", "devices", "syncs")

#: per-metric relative-threshold overrides (`--threshold` covers the
#: rest): wall_s fails only beyond 1.5x the baseline cell.
_METRIC_THRESHOLDS = {"wall_s": 0.5}

#: per-stream attribution metrics gated with the same directional rule:
#: serving latency regresses upward. Latencies are often exactly 0 (idle
#: device), where relative change is meaningless — `_ABS_FLOOR` skips
#: sub-millisecond absolute moves.
STREAM_METRIC_DIRECTIONS = {
    "latency_p50": "up",
    "latency_p95": "up",
}

#: per-model-slot attribution metrics (BENCH schema v3): slot costs
#: regress upward, slot accuracy downward (it uses `--acc-threshold`).
MODEL_METRIC_DIRECTIONS = {
    "time_s": "up",
    "energy_j": "up",
    "flops": "up",
    "avg_inference_acc": "down",
}

#: per-device attribution metrics (BENCH schema v6): a device's modeled
#: costs and sync charges regress upward, its serving accuracy downward.
#: A baseline device entry that vanishes fails outright (`_diff_sub`) —
#: a fleet quietly shrinking is a coverage regression, not noise. v7
#: adds the env columns, gated upward: a device newly draining its
#: battery dead, or spending materially more time DVFS-throttled (the
#: 1s absolute floor absorbs boundary jitter), is a power regression
#: even when the modeled cost totals barely move.
DEVICE_METRIC_DIRECTIONS = {
    "time_s": "up",
    "energy_j": "up",
    "flops": "up",
    "syncs": "up",
    "avg_inference_acc": "down",
    "battery_dead": "up",
    "throttle_s": "up",
}

_ABS_FLOOR = {"latency_p50": 1e-3, "latency_p95": 1e-3,
              "wall_s": 0.5, "recompiles": 2, "syncs": 2,
              "throttle_s": 1.0}


def cell_key(cell: Dict) -> Tuple[str, str, int, str, str]:
    """Identity of a sweep cell across artifacts. `preemptible` is part
    of the key (a prioritized preset runs once per QoS mode), and so is
    `trigger_policy` (BENCH v4: the same method may run under its default
    trigger and the priority-weighted one — both are gated) and the v7
    `throttle` mode (the fleet preset runs a mains cell next to its
    finite-battery env cell)."""
    return (cell.get("workload", "?"), cell.get("method", "?"),
            int(cell.get("preemptible", 0)),
            cell.get("trigger_policy", "default"),
            cell.get("throttle", "none"))


def _cell_label(key: Tuple[str, str, int, str, str]) -> str:
    return "{}/{}{}{}{}".format(
        key[0], key[1], "+preempt" if key[2] else "",
        "" if key[3] == "default" else f"+{key[3]}",
        "" if key[4] == "none" else f"+env:{key[4]}")


def _rel_change(base: float, new: float) -> float:
    return (new - base) / max(abs(base), 1e-9)


def _gate_metric(label: str, metric: str, bval: float, nval: float,
                 thr: float, bad_dir: str, regressions: List[str],
                 infos: List[str]) -> None:
    """Apply one directional threshold check and file the result."""
    if abs(nval - bval) <= _ABS_FLOOR.get(metric, 0.0):
        return
    change = _rel_change(bval, nval)
    moved_badly = change < -thr if bad_dir == "down" else change > thr
    line = f"{label}: {metric} {bval:.6g} -> {nval:.6g} ({change:+.1%})"
    if moved_badly:
        regressions.append(line)
    elif abs(change) > thr:
        infos.append(line + " [improvement]")


def _diff_sub(label: str, kind: str, b: Dict, n: Dict,
              directions: Dict[str, str], threshold: float,
              acc_threshold: float, regressions: List[str],
              infos: List[str]) -> None:
    """Gate one attribution sub-dict (`per_stream` / `per_model`): every
    baseline entry must survive, and its tracked metrics obey the same
    directional thresholds as the cell metrics."""
    for sid in sorted(b.get(kind) or {}):
        bsub = b[kind][sid]
        nsub = (n.get(kind) or {}).get(sid)
        if nsub is None:
            regressions.append(
                f"{label}: {kind}[{sid}] missing from new artifact")
            continue
        for metric, bad_dir in directions.items():
            if metric not in bsub or metric not in nsub:
                continue
            thr = acc_threshold if "acc" in metric else threshold
            _gate_metric(f"{label} {kind}[{sid}]", metric,
                         float(bsub[metric]), float(nsub[metric]), thr,
                         bad_dir, regressions, infos)


def diff_cells(base_doc: Dict, new_doc: Dict, *, threshold: float = 0.05,
               acc_threshold: float = 0.25) -> Tuple[List[str], List[str]]:
    """Return (regressions, infos): human-readable lines. A regression is
    a tracked metric moving beyond its threshold (relative; `acc` uses
    the wider `acc_threshold` — module docstring) in its bad direction,
    or a baseline cell missing from the new artifact. Gating covers the
    cell metrics *and* the per-stream serving-latency and per-model-slot
    attribution columns (a QoS or ModelPool regression hiding inside
    unchanged totals still fails)."""
    base_cells = {cell_key(c): c for c in base_doc.get("cells", [])}
    new_cells = {cell_key(c): c for c in new_doc.get("cells", [])}
    regressions: List[str] = []
    infos: List[str] = []
    for key in sorted(base_cells):
        label = _cell_label(key)
        if key not in new_cells:
            regressions.append(f"{label}: cell missing from new artifact")
            continue
        b, n = base_cells[key], new_cells[key]
        for metric, bad_dir in METRIC_DIRECTIONS.items():
            if metric not in b or metric not in n:
                continue
            thr = acc_threshold if metric == "acc" \
                else _METRIC_THRESHOLDS.get(metric, threshold)
            _gate_metric(label, metric, float(b[metric]), float(n[metric]),
                         thr, bad_dir, regressions, infos)
        _diff_sub(label, "per_stream", b, n, STREAM_METRIC_DIRECTIONS,
                  threshold, acc_threshold, regressions, infos)
        _diff_sub(label, "per_model", b, n, MODEL_METRIC_DIRECTIONS,
                  threshold, acc_threshold, regressions, infos)
        _diff_sub(label, "per_device", b, n, DEVICE_METRIC_DIRECTIONS,
                  threshold, acc_threshold, regressions, infos)
        for metric in INFO_METRICS:
            if b.get(metric) != n.get(metric) and metric in b:
                infos.append(f"{label}: {metric} {b.get(metric)} -> "
                             f"{n.get(metric)}")
    for key in sorted(set(new_cells) - set(base_cells)):
        infos.append(f"{_cell_label(key)}: new cell (no baseline)")
    return regressions, infos


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("base", help="baseline BENCH_workloads.json")
    ap.add_argument("new", help="freshly generated BENCH_workloads.json")
    ap.add_argument("--threshold", type=float, default=0.05,
                    help="relative noise threshold for the modeled cost "
                         "metrics (default 0.05)")
    ap.add_argument("--acc-threshold", type=float, default=0.25,
                    help="relative noise threshold for accuracy "
                         "(default 0.25; module docstring)")
    ap.add_argument("--list-all", action="store_true",
                    help="print informational drifts too")
    args = ap.parse_args()

    docs = []
    for path in (args.base, args.new):
        try:
            with open(path) as f:
                docs.append(json.load(f))
        except (OSError, ValueError) as e:
            print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
            return 2
    base_doc, new_doc = docs
    if base_doc.get("schema_version") != new_doc.get("schema_version"):
        print(f"bench_diff: schema_version mismatch "
              f"({base_doc.get('schema_version')} vs "
              f"{new_doc.get('schema_version')}) — regenerate the "
              f"committed baseline alongside the schema bump",
              file=sys.stderr)
        return 2

    regressions, infos = diff_cells(base_doc, new_doc,
                                    threshold=args.threshold,
                                    acc_threshold=args.acc_threshold)
    if args.list_all:
        for line in infos:
            print(f"INFO {line}")
    for line in regressions:
        print(f"REGRESSION {line}", file=sys.stderr)
    n = len(base_doc.get("cells", []))
    print(f"bench_diff: {n} baseline cell(s), threshold "
          f"{args.threshold:.0%}: "
          + (f"{len(regressions)} regression(s)" if regressions
             else "within noise"))
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
