"""Benchmark harness — one function per paper table/figure.
Prints ``name,case,derived`` CSV rows and writes JSON to
benchmarks/results/.

Quick mode (default) uses one seed and the lighter model/benchmark pairs so
the suite completes on CPU; --full widens models, seeds and benchmarks.
All time/energy figures are model-derived (calibrated EdgeCostModel over
XLA-measured FLOPs) — see DESIGN.md §2."""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C


def tab2_accuracy(full: bool):
    """Table II: avg inference accuracy of Immed/LazyTune/SimFreeze/ETuner
    across CL benchmarks. Also feeds Figs. 8-9 (time/energy, normalized)."""
    archs = ["mobilenetv2", "resnet50", "deit-tiny"] if full else ["mobilenetv2"]
    benches = ["nc", "nic", "s-cifar"] if full else ["nc", "s-cifar"]
    seeds = (0, 1, 2) if full else (0,)
    rows = []
    for arch in archs:
        for bench in benches:
            base = None
            for method in ("immed", "lazytune", "simfreeze", "etuner"):
                r = C.run_method(arch, bench, method, seeds=seeds)
                if method == "immed":
                    base = r
                r["time_norm"] = r["time_s"] / base["time_s"]
                r["energy_norm"] = r["energy_j"] / base["energy_j"]
                r["acc_delta_pp"] = 100 * (r["acc"] - base["acc"])
                rows.append(r)
    C.save_rows("tab2_accuracy_fig8_9", rows)
    C.print_csv("tab2/fig8-9", rows,
                keys=("acc", "time_norm", "energy_norm", "acc_delta_pp"))
    return rows


def tab3_flops(full: bool):
    """Table III: computation (TFLOPs) over the whole CL process."""
    rows = []
    for arch in (["mobilenetv2", "resnet50"] if full else ["mobilenetv2"]):
        for method in ("immed", "etuner"):
            r = C.run_method(arch, "nc", method)
            rows.append(r)
    C.save_rows("tab3_flops", rows)
    C.print_csv("tab3", rows, keys=("tflops", "rounds"))
    return rows


def tab4_nlp(full: bool):
    """Table IV: NLP workload (BERT / 20News-style)."""
    rows = []
    for method in ("immed", "lazytune", "simfreeze", "etuner"):
        rows.append(C.run_method("bert-base", "20news", method,
                                 scenarios=4, batches=8))
    C.save_rows("tab4_nlp", rows)
    C.print_csv("tab4", rows)
    return rows


def tab5_sota(full: bool):
    """Table V: SOTA methods, all with LazyTune integrated (as the paper
    does), vs ETuner."""
    rows = []
    methods = ("lazytune", "egeria", "slimfit", "rigl", "ekya", "etuner")
    for bench in (["nc", "nic"] if full else ["nc"]):
        for m in methods:
            rows.append(C.run_method("mobilenetv2", bench, m))
    C.save_rows("tab5_sota", rows)
    C.print_csv("tab5", rows, keys=("acc", "energy_j"))
    return rows


def tab6_semi(full: bool):
    """Table VI: semi-supervised (10% labeled) — SimSiam on unlabeled."""
    rows = []
    for method in ("immed", "etuner"):
        rows.append(C.run_method("mobilenetv2", "nc", method, unlabeled=0.9))
    C.save_rows("tab6_semi", rows)
    C.print_csv("tab6", rows)
    return rows


def tab7_static(full: bool):
    """Table VII: static lazy strategies S1..S4 vs LazyTune."""
    rows = []
    for method in ("immed", "static2", "static4", "static8", "lazytune"):
        rows.append(C.run_method("mobilenetv2", "nc", method))
    C.save_rows("tab7_static", rows)
    C.print_csv("tab7", rows, keys=("acc", "energy_j", "rounds"))
    return rows


def tab8_quant(full: bool):
    """Table VIII: compatibility with int8 quantization-aware training."""
    rows = []
    for bits in (0, 8):
        for method in ("immed", "etuner"):
            r = C.run_method("mobilenetv2", "nc", method, quant_bits=bits)
            r["bits"] = bits or 32
            rows.append(r)
    C.save_rows("tab8_quant", rows)
    C.print_csv("tab8", rows, keys=("acc", "bits"))
    return rows


def fig13_14_sensitivity(full: bool):
    """Figs. 13-14: #inference requests + arrival-distribution sensitivity."""
    rows = []
    for n in ([10, 30, 60] if full else [10, 30]):
        for method in ("immed", "etuner"):
            r = C.run_method("mobilenetv2", "nc", method, inferences=n)
            r["inferences"] = n
            rows.append(r)
    for dist in ("uniform", "normal", "trace"):
        for method in ("immed", "etuner"):
            r = C.run_method("mobilenetv2", "nc", method, data_dist=dist,
                             inf_dist=dist)
            r["dist"] = dist
            rows.append(r)
    C.save_rows("fig13_14_sensitivity", rows)
    C.print_csv("fig13-14", rows, keys=("acc", "energy_j"))
    return rows


def roofline_table(full: bool):
    """§Roofline: format the dry-run JSONs into the 40-cell table."""
    import glob
    import json
    import os

    rows = []
    pat = os.path.join(os.path.dirname(__file__), "results", "dryrun",
                       "*__single.json")
    for path in sorted(glob.glob(pat)):
        with open(path) as f:
            r = json.load(f)
        rows.append(r)
        if r.get("status") == "ok":
            print(f"roofline,{r['arch']}/{r['shape']},dom={r['dominant']} "
                  f"compute_s={r['compute_s']:.3g} memory_s={r['memory_s']:.3g} "
                  f"collective_s={r['collective_s']:.3g} "
                  f"frac={r['roofline_fraction']:.4f}")
        else:
            print(f"roofline,{r['arch']}/{r['shape']},{r['status']}")
    return rows


TABLES = {
    "tab2": tab2_accuracy, "tab3": tab3_flops, "tab4": tab4_nlp,
    "tab5": tab5_sota, "tab6": tab6_semi, "tab7": tab7_static,
    "tab8": tab8_quant, "fig13": fig13_14_sensitivity,
    "roofline": roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    t0 = time.time()
    names = [n for n in args.only.split(",") if n] or list(TABLES)
    for name in names:
        print(f"# === {name} ===", flush=True)
        try:
            TABLES[name](args.full)
        except Exception as e:  # keep the suite going; report at the end
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
    print(f"# total wall: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
