"""Workload benchmark sweep: every controller x every workload preset.

Runs the four paper controllers (immed / lazytune / simfreeze / etuner)
against the declarative workload presets (`repro.workloads`) — multi-
stream, staggered drift, MMPP bursts, diurnal + duty-cycle, mixed — and
emits a schema'd, machine-readable ``BENCH_workloads.json`` at the repo
root so the performance trajectory is tracked over time (CI runs the
``--quick`` sweep on every push and uploads the file as an artifact).

    PYTHONPATH=src python benchmarks/workloads.py --quick
    PYTHONPATH=src python benchmarks/workloads.py --validate BENCH_workloads.json

Every number is produced by the real runtime (jitted training, XLA-
measured FLOPs) + the calibrated EdgeCostModel; nothing is hard-coded.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import make_controller
from repro.configs import get_reduced
from repro.data import streams
from repro.models import build_model
from repro.runtime.continual import ContinualRuntime
from repro.runtime.modelpool import ModelPool, ModelSlot
from repro.workloads import WorkloadSpec, compile_workload, presets

#: v3 adds the ModelPool columns: per-cell `models` (slot count) and
#: `swaps` (cold-slot swap-ins), and a `per_model` attribution dict —
#: one entry per model slot (single-model cells report the "default"
#: slot) whose cost keys sum to the cell totals like `per_stream` does.
#: (v2 added QoS: `preemptible`/`preemptions` cells and per-stream
#: `latency_p50`/`latency_p95` serving-latency columns.)
SCHEMA_VERSION = 3
METHODS = ("immed", "lazytune", "simfreeze", "etuner")
DEFAULT_OUT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_workloads.json"))

#: Per-modality architecture: the model a ModelPool slot runs. "cv" uses
#: the sweep's `--arch`; other modalities are fixed paper models.
MODALITY_ARCH = {"nlp": "bert-base"}

#: Numeric fields every cell must carry (schema contract with CI).
CELL_FIELDS = ("acc", "time_s", "energy_j", "tflops", "rounds",
               "recompiles", "events", "streams", "wall_s",
               "preemptible", "preemptions", "models", "swaps")

#: Numeric fields every per-stream attribution cell must carry.
STREAM_FIELDS = ("time_s", "energy_j", "flops", "rounds", "preemptions",
                 "avg_inference_acc", "inferences",
                 "latency_p50", "latency_p95")

#: Numeric fields every per-model attribution cell must carry (v3).
MODEL_FIELDS = ("time_s", "energy_j", "flops", "rounds", "swaps",
                "avg_inference_acc", "inferences")


# ---------------------------------------------------------------------------
# one sweep cell


def _stream_benchmarks(spec: WorkloadSpec, seed: int,
                       batch_size: int) -> Dict[int, object]:
    """Materialize one continual benchmark per stream (scenario 0 is
    reserved for pretraining, so each needs num_scenarios + 1)."""
    benches = {}
    for i, ss in enumerate(spec.streams):
        maker = streams.REGISTRY[ss.benchmark]
        kw = dict(batches=max(ss.batches_per_scenario, 2),
                  batch_size=batch_size, seed=seed + 13 * i)
        if ss.benchmark != "s-cifar":
            kw["num_scenarios"] = spec.num_scenarios + 1
        benches[i] = maker(**kw)
    return benches


def build_pool(arch: str, spec: WorkloadSpec, benches: Dict[int, object],
               *, memory_budget_mb: float = 0.0) -> ModelPool:
    """One model slot per modality the spec names: 'cv' runs the sweep
    arch, other modalities their `MODALITY_ARCH` paper model; each slot
    pretrains/validates on the benchmark of its first bound stream."""
    slots = []
    for m in spec.modalities:
        if m != "cv" and m not in MODALITY_ARCH:
            raise ValueError(
                f"no architecture mapped for modality {m!r}; extend "
                f"benchmarks.workloads.MODALITY_ARCH (known: "
                f"{['cv'] + sorted(MODALITY_ARCH)})")
        slot_arch = arch if m == "cv" else MODALITY_ARCH[m]
        first = next(i for i, s in enumerate(spec.streams)
                     if s.modality == m)
        slots.append(ModelSlot(m, build_model(get_reduced(slot_arch)),
                               benches[first]))
    return ModelPool(slots, memory_budget_mb=memory_budget_mb)


def run_workload(arch: str, spec: WorkloadSpec, method: str, *,
                 seed: int = 0, batch_size: int = 8,
                 pretrain_epochs: int = 1,
                 inference_batch: int = 8,
                 preemptible: bool = False,
                 memory_budget_mb: float = 0.0) -> Dict:
    """One (workload, controller) cell: full runtime run, paper metrics +
    per-stream and per-model attribution (incl. p50/p95 serving latency).
    `preemptible` turns on QoS round preemption (high-priority arrivals
    split in-flight rounds of lower-priority streams). A spec naming more
    than one modality (the faithful `mixed` preset) runs on a `ModelPool`
    — one model slot per modality sharing the device under
    `memory_budget_mb` (0 = unlimited, no swap charges)."""
    benches = _stream_benchmarks(spec, seed, batch_size)
    events = compile_workload(spec)
    t0 = time.time()
    pool = None
    if len(spec.modalities) > 1:
        pool = build_pool(arch, spec, benches,
                          memory_budget_mb=memory_budget_mb)
        rt = ContinualRuntime(
            None, None, None, seed=seed,
            pretrain_epochs=pretrain_epochs,
            inference_batch=inference_batch,
            stream_benchmarks=benches,
            controller_factory=lambda slot: make_controller(
                pool.slot(slot).model, method),
            preemptible=preemptible, model_pool=pool)
    else:
        model = build_model(get_reduced(arch))
        rt = ContinualRuntime(
            model, benches[0], make_controller(model, method), seed=seed,
            pretrain_epochs=pretrain_epochs,
            inference_batch=inference_batch,
            stream_benchmarks={i: b for i, b in benches.items() if i},
            controller_factory=lambda st: make_controller(model, method),
            preemptible=preemptible)
    res = rt.run(events=events)
    return {
        "workload": spec.name, "method": method,
        "streams": len(spec.streams), "events": len(events),
        "models": len(spec.modalities),
        "acc": res.avg_inference_acc, "time_s": res.total_time_s,
        "energy_j": res.total_energy_j, "tflops": res.compute_tflops,
        "rounds": res.rounds, "recompiles": res.recompiles,
        "preemptible": int(preemptible), "preemptions": res.preemptions,
        "swaps": res.swaps,
        "wall_s": round(time.time() - t0, 2),
        "per_stream": {str(k): v for k, v in res.per_stream.items()},
        "per_model": dict(res.per_model),
        # multi-model cells record the pool manifest (slot footprints as
        # measured at run start + the budget the cell ran under)
        **({"pool": pool.describe()} if pool is not None else {}),
    }


# ---------------------------------------------------------------------------
# sweep + manifest


def sweep(*, quick: bool = True, arch: str = "mobilenetv2", seed: int = 0,
          workload_names: Optional[Sequence[str]] = None,
          methods: Sequence[str] = METHODS) -> Dict:
    scale = (dict(batches_per_scenario=4, inferences=10, num_scenarios=2)
             if quick else
             dict(batches_per_scenario=8, inferences=24, num_scenarios=3))
    specs = presets(seed=seed, **scale)
    names = list(workload_names) if workload_names else list(specs)
    cells: List[Dict] = []
    for name in names:
        spec = specs[name]
        # prioritized presets (qos) sweep both QoS modes so the artifact
        # records the preemption latency win next to its baseline
        modes = ((False, True) if any(s.priority for s in spec.streams)
                 else (False,))
        base = None
        for method in methods:
            for preemptible in modes:
                cell = run_workload(arch, spec, method, seed=seed,
                                    preemptible=preemptible)
                if base is None:
                    base = cell
                cell["time_norm"] = cell["time_s"] / max(base["time_s"], 1e-9)
                cell["energy_norm"] = (cell["energy_j"]
                                       / max(base["energy_j"], 1e-9))
                cells.append(cell)
                tag = "/qos" if preemptible else ""
                print(f"workloads,{name}/{method}{tag},"
                      f"acc={cell['acc']:.4f} "
                      f"time={cell['time_s']:.1f}s "
                      f"energy={cell['energy_j']:.1f}J "
                      f"rounds={cell['rounds']} "
                      f"preempt={cell['preemptions']} "
                      f"models={cell['models']} swaps={cell['swaps']} "
                      f"wall={cell['wall_s']:.0f}s",
                      flush=True)
    import jax
    return {
        "schema_version": SCHEMA_VERSION, "suite": "workloads",
        "arch": arch, "seed": seed, "quick": quick,
        "created_unix": int(time.time()), "jax_version": jax.__version__,
        "workloads": {n: specs[n].describe() for n in names},
        "cells": cells,
    }


# ---------------------------------------------------------------------------
# schema validation (used by CI and tests)


def validate_bench(doc: Dict, *, min_workloads: int = 3,
                   methods: Sequence[str] = METHODS) -> List[str]:
    """Return a list of schema violations (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version != {SCHEMA_VERSION}")
    if doc.get("suite") != "workloads":
        errors.append("suite != 'workloads'")
    for key in ("arch", "workloads", "cells", "created_unix"):
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    cells = doc.get("cells") or []
    if not isinstance(cells, list) or not cells:
        errors.append("cells must be a non-empty list")
        return errors
    seen: Dict[str, set] = {}
    for i, cell in enumerate(cells):
        for f in CELL_FIELDS:
            v = cell.get(f)
            if not isinstance(v, (int, float)) or v != v or v < 0:
                errors.append(f"cell {i}: field {f!r} missing or not a "
                              f"non-negative finite number (got {v!r})")
        per = cell.get("per_stream")
        if not isinstance(per, dict):
            errors.append(f"cell {i}: missing per_stream attribution")
        else:
            for sid, sc in per.items():
                for f in STREAM_FIELDS:
                    v = sc.get(f) if isinstance(sc, dict) else None
                    if not isinstance(v, (int, float)) or v != v or v < 0:
                        errors.append(
                            f"cell {i} stream {sid}: field {f!r} missing "
                            f"or not a non-negative finite number "
                            f"(got {v!r})")
        pm = cell.get("per_model")
        if not isinstance(pm, dict) or not pm:
            errors.append(f"cell {i}: missing per_model attribution (v3)")
        else:
            for mid, mc in pm.items():
                for f in MODEL_FIELDS:
                    v = mc.get(f) if isinstance(mc, dict) else None
                    if not isinstance(v, (int, float)) or v != v or v < 0:
                        errors.append(
                            f"cell {i} model {mid}: field {f!r} missing "
                            f"or not a non-negative finite number "
                            f"(got {v!r})")
        if "workload" not in cell or "method" not in cell:
            errors.append(f"cell {i}: missing workload/method labels")
            continue
        seen.setdefault(cell["workload"], set()).add(cell["method"])
    if len(seen) < min_workloads:
        errors.append(f"only {len(seen)} workload(s) covered; "
                      f"need >= {min_workloads}")
    for wl, ms in seen.items():
        missing = set(methods) - ms
        if missing:
            errors.append(f"workload {wl!r}: missing controllers "
                          f"{sorted(missing)}")
    return errors


# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: 2 scenarios, 4 batches/scenario")
    ap.add_argument("--arch", default="mobilenetv2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--workloads", default="",
                    help="comma-separated preset names (default: all)")
    ap.add_argument("--methods", default=",".join(METHODS))
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an existing BENCH file and exit")
    args = ap.parse_args()

    if args.validate:
        with open(args.validate) as f:
            errors = validate_bench(json.load(f))
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        print(f"{args.validate}: " +
              ("INVALID" if errors else "schema valid"))
        return 1 if errors else 0

    names = [n for n in args.workloads.split(",") if n] or None
    methods = tuple(m for m in args.methods.split(",") if m)
    t0 = time.time()
    doc = sweep(quick=args.quick, arch=args.arch, seed=args.seed,
                workload_names=names, methods=methods)
    errors = validate_bench(doc, min_workloads=min(
        3, len(doc["workloads"])), methods=methods)
    if errors:
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}: {len(doc['cells'])} cells over "
          f"{len(doc['workloads'])} workloads "
          f"(wall {time.time() - t0:.0f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
