"""Workload benchmark sweep: every controller x every workload preset.

Runs the four paper controllers (immed / lazytune / simfreeze / etuner)
against the declarative workload presets (`repro.workloads`) — multi-
stream, staggered drift, MMPP bursts, diurnal + duty-cycle, mixed — and
emits a schema'd, machine-readable ``BENCH_workloads.json`` at the repo
root so the performance trajectory is tracked over time (CI runs the
``--quick`` sweep on every push and uploads the file as an artifact).

Sessions are built through the declarative `RuntimeConfig` front door
(`workload_config` -> `edgeol_session`, DESIGN.md §11); only the
monolithic SOTA baselines inject live controller objects.

    PYTHONPATH=src python benchmarks/workloads.py --quick
    PYTHONPATH=src python benchmarks/workloads.py --validate BENCH_workloads.json

Every number is produced by the real runtime (jitted training, XLA-
measured FLOPs) + the calibrated EdgeCostModel; nothing is hard-coded.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import (PAPER_METHODS, make_controller,
                               method_policies)
from repro.configs import get_reduced
from repro.core.policies import PolicySpec
from repro.models import build_model
from repro.runtime import (EnvSpec, RuntimeConfig, SlotConfig,
                           TelemetrySpec, edgeol_session,
                           materialize_stream_benchmarks)
from repro.runtime.modelpool import ModelPool, ModelSlot
from repro.workloads import WorkloadSpec, presets

#: v7: device-environment columns (DESIGN.md §15) — every cell carries
#: `energy_budget_j` (0 = mains power) and a `throttle` mode string, the
#: per-device attribution grows `battery_dead`/`throttle_s`, and the
#: sweep adds a second `fleet` cell running under a finite per-device
#: battery with the BudgetThrottle policy stack facet + a thermal DVFS
#: cap. (v6 added the DeviceFleet columns — `devices`/`syncs` +
#: validated `per_device` attribution; v5 moved cells to the compiled
#: hot path and gated `wall_s`/`recompiles`; v4 added the PolicyStack
#: `trigger_policy` column + priority-weighted qos cells; v3 the
#: ModelPool columns; v2 QoS — `preemptible`/`preemptions` + per-stream
#: latency.)
SCHEMA_VERSION = 7
METHODS = PAPER_METHODS
DEFAULT_OUT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_workloads.json"))

#: Per-modality architecture: the model a ModelPool slot runs. "cv" uses
#: the sweep's `--arch`; other modalities are fixed paper models.
MODALITY_ARCH = {"nlp": "bert-base"}

#: Numeric fields every cell must carry (schema contract with CI).
CELL_FIELDS = ("acc", "time_s", "energy_j", "tflops", "rounds",
               "recompiles", "events", "streams", "wall_s",
               "preemptible", "preemptions", "models", "swaps",
               "compiled", "devices", "syncs", "energy_budget_j")

#: String fields every cell must carry (schema contract, v4; v7 adds the
#: `throttle` policy mode — "none" for mains-powered cells).
CELL_STR_FIELDS = ("workload", "method", "trigger_policy", "throttle")

#: Numeric fields every per-stream attribution cell must carry.
STREAM_FIELDS = ("time_s", "energy_j", "flops", "rounds", "preemptions",
                 "avg_inference_acc", "inferences",
                 "latency_p50", "latency_p95")

#: Numeric fields every per-model attribution cell must carry (v3).
MODEL_FIELDS = ("time_s", "energy_j", "flops", "rounds", "swaps",
                "avg_inference_acc", "inferences")

#: Numeric fields every per-device attribution cell must carry (v6; v7
#: adds the env columns — `battery_dead` is a 0/1 flag, `throttle_s` the
#: modeled seconds the device spent DVFS-throttled below full speed).
DEVICE_FIELDS = ("time_s", "energy_j", "flops", "rounds", "swaps",
                 "syncs", "avg_inference_acc", "inferences", "streams",
                 "utilization", "battery_dead", "throttle_s")


def trace_spec(path: Optional[str]) -> Optional[TelemetrySpec]:
    """Map a CLI ``--trace-out`` path to a `TelemetrySpec` (None stays
    None): a ``.jsonl`` suffix selects the JSONL event feed, anything
    else the Perfetto-loadable Chrome export (DESIGN.md §14)."""
    if not path:
        return None
    if path.endswith(".jsonl"):
        return TelemetrySpec(enabled=True, trace_jsonl=path)
    return TelemetrySpec(enabled=True, chrome_trace=path)


# ---------------------------------------------------------------------------
# one sweep cell


def _stream_benchmarks(spec: WorkloadSpec, seed: int,
                       batch_size: int) -> Dict[int, object]:
    """One continual benchmark per stream (kept as a thin alias of the
    runtime-config materializer so tests and the sweep share one
    binding)."""
    return materialize_stream_benchmarks(spec, seed, batch_size)


def build_pool(arch: str, spec: WorkloadSpec, benches: Dict[int, object],
               *, memory_budget_mb: float = 0.0) -> ModelPool:
    """One model slot per modality the spec names: 'cv' runs the sweep
    arch, other modalities their `MODALITY_ARCH` paper model; each slot
    pretrains/validates on the benchmark of its first bound stream."""
    slots = []
    for m in spec.modalities:
        first = next(i for i, s in enumerate(spec.streams)
                     if s.modality == m)
        slots.append(ModelSlot(m, build_model(get_reduced(_slot_arch(
            arch, m))), benches[first]))
    return ModelPool(slots, memory_budget_mb=memory_budget_mb)


def _slot_arch(arch: str, modality: str) -> str:
    if modality == "cv":
        return arch
    if modality not in MODALITY_ARCH:
        raise ValueError(
            f"no architecture mapped for modality {modality!r}; extend "
            f"benchmarks.workloads.MODALITY_ARCH (known: "
            f"{['cv'] + sorted(MODALITY_ARCH)})")
    return MODALITY_ARCH[modality]


def workload_config(arch: str, workload, method: str, *, seed: int = 0,
                    batch_size: int = 8, pretrain_epochs: int = 1,
                    inference_batch: int = 8, preemptible: bool = False,
                    memory_budget_mb: float = 0.0,
                    trigger_policy: str = "default",
                    workload_scale: Optional[Dict] = None,
                    compiled: bool = True,
                    use_pallas: bool = False,
                    devices=(), routing: str = "static",
                    aggregate_every: float = 0.0,
                    energy_budget_j: float = 0.0,
                    thermal_cap_c: float = 0.0,
                    throttle: str = "none",
                    telemetry: Optional[TelemetrySpec] = None
                    ) -> RuntimeConfig:
    """The declarative session config of one sweep cell. `workload` is a
    preset name or an already-scaled `WorkloadSpec`; paper methods get
    their policy stacks per slot (baselines keep the default stack and
    inject controllers at session build). Cells run on the compiled hot
    path (DESIGN.md §12) unless `compiled=False`. `devices`/`routing`/
    `aggregate_every` (v6) turn the cell into a DeviceFleet run;
    `telemetry` (PR 9, DESIGN.md §14) attaches a `TelemetrySpec` so the
    cell records a structured trace. `energy_budget_j`/`thermal_cap_c`/
    `throttle` (v7, DESIGN.md §15) attach a device environment: every
    device gets a finite battery and/or thermal DVFS cap, and the paper
    methods' policy stacks grow the named ThrottlePolicy facet
    (baselines stay legacy — no throttle facet means always-allow)."""
    if isinstance(workload, WorkloadSpec):
        spec = workload
    else:
        knobs = {k: v for k, v in (workload_scale or {}).items()
                 if k != "batch_size"}
        spec = presets(seed=seed, **knobs)[workload]
    if energy_budget_j > 0 or thermal_cap_c > 0:
        env = EnvSpec(battery_capacity_j=energy_budget_j,
                      thermal_cap_c=thermal_cap_c)
        devices = tuple(dataclasses.replace(d, env=env) for d in devices)
    policies = method_policies(method, trigger_policy) \
        if method in PAPER_METHODS else None
    if throttle != "none" and policies is not None:
        policies = dataclasses.replace(policies,
                                       throttle=PolicySpec(throttle))
    slots = {}
    for m in spec.modalities:
        slots[m] = SlotConfig(arch=_slot_arch(arch, m),
                              **({"policies": policies} if policies else {}))
    scale = dict(workload_scale or {})
    scale["batch_size"] = batch_size
    return RuntimeConfig(
        slots=slots, workload=spec.name, workload_scale=scale,
        seed=seed, pretrain_epochs=pretrain_epochs,
        inference_batch=inference_batch, preemptible=preemptible,
        memory_budget_mb=memory_budget_mb,
        compiled=compiled, use_pallas=use_pallas,
        devices=tuple(devices), routing=routing,
        aggregate_every=aggregate_every,
        **({"telemetry": telemetry} if telemetry is not None else {}))


def run_workload(arch: str, spec: WorkloadSpec, method: str, *,
                 seed: int = 0, batch_size: int = 8,
                 pretrain_epochs: int = 1,
                 inference_batch: int = 8,
                 preemptible: bool = False,
                 memory_budget_mb: float = 0.0,
                 trigger_policy: str = "default",
                 workload_scale: Optional[Dict] = None,
                 compiled: bool = True,
                 use_pallas: bool = False,
                 devices=(), routing: str = "static",
                 aggregate_every: float = 0.0,
                 energy_budget_j: float = 0.0,
                 thermal_cap_c: float = 0.0,
                 throttle: str = "none",
                 telemetry: Optional[TelemetrySpec] = None) -> Dict:
    """One (workload, controller) cell: full runtime run, paper metrics +
    per-stream, per-model and per-device attribution (incl. p50/p95
    serving latency). `preemptible` turns on QoS round preemption;
    `trigger_policy` ("default" | "priority-weighted") picks the paper
    methods' trigger (BENCH v4). A spec naming more than one modality
    (the faithful `mixed` preset) runs on a `ModelPool` — one model slot
    per modality sharing the device under `memory_budget_mb` (0 =
    unlimited). `devices`/`routing`/`aggregate_every` (v6) run the cell
    on a DeviceFleet — streams routed across the device list, fine-tuned
    deltas merged federated-style every `aggregate_every` seconds.
    `energy_budget_j`/`thermal_cap_c`/`throttle` (v7) run the cell under
    a per-device environment (DESIGN.md §15)."""
    cfg = workload_config(arch, spec, method, seed=seed,
                          batch_size=batch_size,
                          pretrain_epochs=pretrain_epochs,
                          inference_batch=inference_batch,
                          preemptible=preemptible,
                          memory_budget_mb=memory_budget_mb,
                          trigger_policy=trigger_policy,
                          workload_scale=workload_scale,
                          compiled=compiled, use_pallas=use_pallas,
                          devices=devices, routing=routing,
                          aggregate_every=aggregate_every,
                          energy_budget_j=energy_budget_j,
                          thermal_cap_c=thermal_cap_c,
                          throttle=throttle,
                          telemetry=telemetry)
    t0 = time.time()
    if method in PAPER_METHODS:
        # fully declarative: benchmarks, pool, controllers and the event
        # timeline all materialize from the config (the spec object is
        # injected because the sweep pre-scales it)
        rt = edgeol_session(cfg, workload_spec=spec)
    else:
        # monolithic SOTA baselines: inject live controller objects
        # through the factory seam (exercises the legacy adapter)
        benches = _stream_benchmarks(spec, seed, batch_size)
        if len(spec.modalities) > 1:
            pool = build_pool(arch, spec, benches,
                              memory_budget_mb=memory_budget_mb)
            rt = edgeol_session(
                cfg, workload_spec=spec, stream_benchmarks=benches,
                model_pool=pool,
                controller_factory=lambda slot: make_controller(
                    pool.slot(slot).model, method, trigger_policy))
        else:
            model = build_model(get_reduced(arch))
            rt = edgeol_session(
                cfg, workload_spec=spec, stream_benchmarks=benches,
                model=model,
                controller=make_controller(model, method, trigger_policy),
                controller_factory=lambda st: make_controller(
                    model, method, trigger_policy))
    res = rt.run()
    events = rt.session_events or []
    return {
        "workload": spec.name, "method": method,
        "trigger_policy": trigger_policy,
        "throttle": throttle,
        "energy_budget_j": float(energy_budget_j),
        "streams": len(spec.streams), "events": len(events),
        "models": len(spec.modalities),
        "acc": res.avg_inference_acc, "time_s": res.total_time_s,
        "energy_j": res.total_energy_j, "tflops": res.compute_tflops,
        "rounds": res.rounds, "recompiles": res.recompiles,
        "preemptible": int(preemptible), "preemptions": res.preemptions,
        "swaps": res.swaps, "compiled": int(compiled),
        "devices": len(res.per_device), "syncs": res.syncs,
        "wall_s": round(time.time() - t0, 2),
        "per_stream": {str(k): v for k, v in res.per_stream.items()},
        "per_model": dict(res.per_model),
        "per_device": dict(res.per_device),
        # multi-model cells record the pool manifest (slot footprints as
        # measured at run start + the budget the cell ran under)
        **({"pool": rt.pool.describe()} if rt.pool is not None else {}),
    }


# ---------------------------------------------------------------------------
# sweep + manifest


def sweep(*, quick: bool = True, arch: str = "mobilenetv2", seed: int = 0,
          workload_names: Optional[Sequence[str]] = None,
          methods: Sequence[str] = METHODS,
          trace_out: Optional[str] = None) -> Dict:
    scale = (dict(batches_per_scenario=4, inferences=10, num_scenarios=2,
                  fleet_streams=6)
             if quick else
             dict(batches_per_scenario=8, inferences=24, num_scenarios=3,
                  fleet_streams=24))
    # the fleet cell's device count (v6): a few devices at CI scale, a
    # dozen for full local runs (the preset itself scales to hundreds of
    # streams via `fleet_streams`)
    fleet_size = 3 if quick else 12
    specs = presets(seed=seed, **scale)
    names = list(workload_names) if workload_names else list(specs)
    cells: List[Dict] = []
    # --trace-out (PR 9): record a Chrome trace of ONE representative
    # cell — the fleet cell when the sweep includes it (richest track
    # layout: devices x streams), else the first cell run
    tspec = trace_spec(trace_out)
    trace_on = "fleet" if (tspec and "fleet" in names) else \
        (names[0] if tspec and names else None)

    pending_trace = {"spec": tspec}

    def one(spec, method, preemptible, trigger_policy, base, **fleet_kw):
        if spec.name == trace_on and pending_trace["spec"] is not None:
            fleet_kw["telemetry"] = pending_trace.pop("spec")
            pending_trace["spec"] = None
        cell = run_workload(arch, spec, method, seed=seed,
                            preemptible=preemptible,
                            trigger_policy=trigger_policy,
                            workload_scale=scale, **fleet_kw)
        if base is None:
            base = cell
        cell["time_norm"] = cell["time_s"] / max(base["time_s"], 1e-9)
        cell["energy_norm"] = (cell["energy_j"]
                               / max(base["energy_j"], 1e-9))
        cells.append(cell)
        tag = ("/qos" if preemptible else "") + \
            ("/pw" if trigger_policy == "priority-weighted" else "") + \
            (f"/x{cell['devices']}" if cell["devices"] > 1 else "") + \
            (f"/env:{cell['throttle']}"
             if cell["throttle"] != "none" else "")
        print(f"workloads,{spec.name}/{method}{tag},"
              f"acc={cell['acc']:.4f} "
              f"time={cell['time_s']:.1f}s "
              f"energy={cell['energy_j']:.1f}J "
              f"rounds={cell['rounds']} "
              f"preempt={cell['preemptions']} "
              f"models={cell['models']} swaps={cell['swaps']} "
              f"devices={cell['devices']} syncs={cell['syncs']} "
              f"wall={cell['wall_s']:.0f}s",
              flush=True)
        return base

    for name in names:
        spec = specs[name]
        if name == "fleet":
            # DeviceFleet cell (v6): one method (etuner), many streams
            # routed least-loaded across a heterogeneous fleet, federated
            # merges every quarter scenario span. Too many streams for
            # the full method x workload product — it gets its own cell
            # and validate_bench exempts it from method coverage.
            from repro.runtime import fleet_devices
            fleet = fleet_devices(fleet_size, seed=seed,
                                  speed_spread=0.4, energy_spread=0.2)
            # v7 env cell (DESIGN.md §15): the same fleet under a finite
            # per-device battery + a thermal DVFS cap barely above
            # ambient, with the BudgetThrottle facet gating rounds — the
            # budget is sized well below the mains cell's per-device
            # energy so the environment demonstrably engages: devices
            # throttle / drain dead / ride the eviction path
            # (validate_bench and bench-smoke both assert it). Runs
            # first so a `--trace-out` sweep records THIS cell — the
            # richest track layout: devices x streams plus temperature/
            # SoC counter tracks and DVFS throttle spans.
            one(spec, "etuner", False, "default", None,
                devices=fleet, routing="least-loaded",
                aggregate_every=spec.scenario_span / 4.0,
                energy_budget_j=80.0 if quick else 400.0,
                thermal_cap_c=26.0, throttle="battery")
            one(spec, "etuner", False, "default", None,
                devices=fleet, routing="least-loaded",
                aggregate_every=spec.scenario_span / 4.0)
            continue
        # prioritized presets (qos) sweep both QoS modes so the artifact
        # records the preemption latency win next to its baseline
        prioritized = any(s.priority for s in spec.streams)
        modes = (False, True) if prioritized else (False,)
        base = None
        for method in methods:
            for preemptible in modes:
                base = one(spec, method, preemptible, "default", base)
        # v4: prioritized presets add the PriorityWeightedTrigger cell —
        # etuner with the accumulation target scaled by stream priority —
        # in both QoS modes, gated by bench_diff like every other cell
        if prioritized and "etuner" in methods:
            for preemptible in modes:
                base = one(spec, "etuner", preemptible,
                           "priority-weighted", base)
    import jax
    return {
        "schema_version": SCHEMA_VERSION, "suite": "workloads",
        "arch": arch, "seed": seed, "quick": quick,
        "created_unix": int(time.time()), "jax_version": jax.__version__,
        "workloads": {n: specs[n].describe() for n in names},
        "cells": cells,
    }


# ---------------------------------------------------------------------------
# schema validation (used by CI and tests)


def validate_bench(doc: Dict, *, min_workloads: int = 3,
                   methods: Sequence[str] = METHODS) -> List[str]:
    """Return a list of schema violations (empty = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"schema_version != {SCHEMA_VERSION}")
    if doc.get("suite") != "workloads":
        errors.append("suite != 'workloads'")
    for key in ("arch", "workloads", "cells", "created_unix"):
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    cells = doc.get("cells") or []
    if not isinstance(cells, list) or not cells:
        errors.append("cells must be a non-empty list")
        return errors
    seen: Dict[str, set] = {}
    for i, cell in enumerate(cells):
        for f in CELL_FIELDS:
            v = cell.get(f)
            if not isinstance(v, (int, float)) or v != v or v < 0:
                errors.append(f"cell {i}: field {f!r} missing or not a "
                              f"non-negative finite number (got {v!r})")
        for f in CELL_STR_FIELDS:
            if not isinstance(cell.get(f), str) or not cell.get(f):
                errors.append(f"cell {i}: field {f!r} missing or not a "
                              f"non-empty string (got {cell.get(f)!r})")
        per = cell.get("per_stream")
        if not isinstance(per, dict):
            errors.append(f"cell {i}: missing per_stream attribution")
        else:
            for sid, sc in per.items():
                for f in STREAM_FIELDS:
                    v = sc.get(f) if isinstance(sc, dict) else None
                    if not isinstance(v, (int, float)) or v != v or v < 0:
                        errors.append(
                            f"cell {i} stream {sid}: field {f!r} missing "
                            f"or not a non-negative finite number "
                            f"(got {v!r})")
        pm = cell.get("per_model")
        if not isinstance(pm, dict) or not pm:
            errors.append(f"cell {i}: missing per_model attribution (v3)")
        else:
            for mid, mc in pm.items():
                for f in MODEL_FIELDS:
                    v = mc.get(f) if isinstance(mc, dict) else None
                    if not isinstance(v, (int, float)) or v != v or v < 0:
                        errors.append(
                            f"cell {i} model {mid}: field {f!r} missing "
                            f"or not a non-negative finite number "
                            f"(got {v!r})")
        pd = cell.get("per_device")
        if not isinstance(pd, dict) or not pd:
            errors.append(f"cell {i}: missing per_device attribution (v6)")
        else:
            if len(pd) != cell.get("devices"):
                errors.append(f"cell {i}: devices={cell.get('devices')!r} "
                              f"but per_device has {len(pd)} entries")
            for did, dc in pd.items():
                for f in DEVICE_FIELDS:
                    v = dc.get(f) if isinstance(dc, dict) else None
                    if not isinstance(v, (int, float)) or v != v or v < 0:
                        errors.append(
                            f"cell {i} device {did}: field {f!r} missing "
                            f"or not a non-negative finite number "
                            f"(got {v!r})")
        if "workload" not in cell or "method" not in cell:
            continue
        seen.setdefault(cell["workload"], set()).add(cell["method"])
    if len(seen) < min_workloads:
        errors.append(f"only {len(seen)} workload(s) covered; "
                      f"need >= {min_workloads}")
    for wl, ms in seen.items():
        if wl == "fleet":
            continue  # v6: the fleet preset runs one dedicated cell
        missing = set(methods) - ms
        if missing:
            errors.append(f"workload {wl!r}: missing controllers "
                          f"{sorted(missing)}")
    # v4: a prioritized preset must carry its priority-weighted cell(s)
    pw = [c for c in cells
          if c.get("trigger_policy") == "priority-weighted"]
    if any(wl == "qos" for wl in seen) and not pw:
        errors.append("qos preset present but no priority-weighted "
                      "trigger cell (v4)")
    # v6: a fleet preset cell must really be multi-device
    fleet_cells = [c for c in cells if c.get("workload") == "fleet"]
    if "fleet" in seen and not any(
            c.get("devices", 0) >= 2 for c in fleet_cells):
        errors.append("fleet preset present but no cell with >= 2 "
                      "devices (v6)")
    # v7: the fleet preset must carry an env cell (finite battery +
    # throttle facet) in which the environment demonstrably engaged —
    # at least one device drained dead, spent time DVFS-throttled, or
    # was evicted — and no device's ledger energy may exceed its budget
    env_cells = [c for c in fleet_cells
                 if c.get("throttle", "none") != "none"
                 and c.get("energy_budget_j", 0) > 0]
    if "fleet" in seen and not env_cells:
        errors.append("fleet preset present but no env cell (finite "
                      "energy_budget_j + throttle mode, v7)")
    for c in env_cells:
        pd = c.get("per_device") or {}
        if not any(dc.get("battery_dead", 0) > 0
                   or dc.get("throttle_s", 0) > 0
                   or dc.get("evicted", 0) > 0 for dc in pd.values()):
            errors.append(
                "env cell ran but no device throttled, drained dead or "
                "was evicted — env not engaged (v7)")
        for did, dc in pd.items():
            if dc.get("energy_j", 0) > c["energy_budget_j"] + 1e-6:
                errors.append(
                    f"env cell device {did}: ledger energy "
                    f"{dc.get('energy_j'):.3f} J exceeds the "
                    f"{c['energy_budget_j']:.3f} J battery budget (v7)")
    return errors


# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: 2 scenarios, 4 batches/scenario")
    ap.add_argument("--arch", default="mobilenetv2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--workloads", default="",
                    help="comma-separated preset names (default: all)")
    ap.add_argument("--methods", default=",".join(METHODS))
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record a Chrome trace (DESIGN.md §14) of one "
                         "representative cell — the fleet cell when the "
                         "sweep includes it — to PATH; summarize with "
                         "`python -m benchmarks.trace_report PATH`")
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an existing BENCH file and exit")
    args = ap.parse_args()

    from repro.launch.platform import bootstrap
    bootstrap()

    if args.validate:
        with open(args.validate) as f:
            errors = validate_bench(json.load(f))
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        print(f"{args.validate}: " +
              ("INVALID" if errors else "schema valid"))
        return 1 if errors else 0

    names = [n for n in args.workloads.split(",") if n] or None
    methods = tuple(m for m in args.methods.split(",") if m)
    t0 = time.time()
    doc = sweep(quick=args.quick, arch=args.arch, seed=args.seed,
                workload_names=names, methods=methods,
                trace_out=args.trace_out)
    errors = validate_bench(doc, min_workloads=min(
        3, len(doc["workloads"])), methods=methods)
    if errors:
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}: {len(doc['cells'])} cells over "
          f"{len(doc['workloads'])} workloads "
          f"(wall {time.time() - t0:.0f}s)")
    if args.trace_out:
        print(f"# wrote {args.trace_out}: Chrome trace — load at "
              f"https://ui.perfetto.dev or summarize with "
              f"`python -m benchmarks.trace_report {args.trace_out}`")
    return 0


if __name__ == "__main__":
    sys.exit(main())
