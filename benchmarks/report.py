"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HERE = os.path.dirname(__file__)


def load(mesh: str, tag: str = ""):
    suffix = f"_{tag}" if tag else ""
    pat = os.path.join(HERE, "results", "dryrun", f"*__{mesh}{suffix}.json")
    out = []
    for p in sorted(glob.glob(pat)):
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        if (tag and not name.endswith(suffix)) or (not tag and len(parts) > 3):
            continue
        with open(p) as f:
            try:
                out.append(json.load(f))
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"malformed dry-run record {p}: {e}") from e
    return out


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    return f"{b/1e6:.1f}MB"


ARCH_ORDER = ["qwen2-vl-72b", "jamba-1.5-large-398b", "gemma2-2b",
              "granite-20b", "gemma2-27b", "qwen1.5-32b", "rwkv6-3b",
              "qwen3-moe-30b-a3b", "kimi-k2-1t-a32b", "musicgen-medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def roofline_markdown(mesh: str = "single", tag: str = "") -> str:
    rows = load(mesh, tag)
    idx = {(r["arch"], r["shape"]): r for r in rows}
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPs/HLO | roofline frac | HBM/chip (args+temp) | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = idx.get((arch, shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | - | MISSING |")
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | - | "
                             f"SKIP (full attention @500k) |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | - | "
                             f"ERROR {r.get('error','')[:40]} |")
                continue
            mem = r.get("memory_per_chip", {})
            hbm = fmt_bytes(mem.get("argument", 0) + mem.get("temp", 0))
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.3g} | {r['memory_s']:.3g} "
                f"| {r['collective_s']:.3g} | **{r['dominant']}** "
                f"| {r['flops_ratio']:.3f} | {r['roofline_fraction']:.4f} "
                f"| {hbm} | ok ({r.get('compile_s','?')}s compile) |")
    return "\n".join(lines)


def dryrun_summary(mesh: str) -> str:
    rows = load(mesh)
    ok = sum(r["status"] == "ok" for r in rows)
    skip = sum(r["status"] == "skip" for r in rows)
    err = sum(r["status"] not in ("ok", "skip") for r in rows)
    return f"{mesh}: {ok} compiled, {skip} skipped (documented), {err} errors"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(dryrun_summary(args.mesh))
    print()
    print(roofline_markdown(args.mesh, args.tag))


if __name__ == "__main__":
    main()
