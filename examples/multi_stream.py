"""Multi-stream workload driver: run one workload preset (multi-stream /
bursty MMPP / diurnal+duty-cycle / mixed / qos — see
repro.workloads.presets) against a chosen controller and print the
global, per-stream and per-model outcome (accuracy, modeled time/energy,
rounds — the CostLedger attributes every charge both to the arrival
stream whose batches the round trained and to the model slot that
executed it).

Sessions are built through the declarative `RuntimeConfig` API
(`benchmarks.workloads.workload_config` -> `edgeol_session`; DESIGN.md
§11). The `mixed` preset is a true mixed-modality run: its NLP stream
binds to a real BERT/20news model slot in a ModelPool, sharing the
device with the CV slot; `--memory-budget` caps device memory (MB) so a
budget smaller than the resident set forces cold-slot swap charges. The
`qos` preset pairs a latency-critical stream with a bulk stream:
`--preemptible` lets its arrivals split in-flight rounds, and
`--trigger-policy priority-weighted` scales LazyTune's accumulation
target by stream priority (BENCH v4).

Runs execute on the compiled hot path by default (DESIGN.md §12):
homogeneous event segments dispatch as one fused `lax.scan` / vmapped
program with donated (params, opt_state) buffers, and the process
bootstraps the platform + persistent XLA compile cache so repeat
invocations skip compilation. `--no-compiled` selects the pure-Python
per-event fallback (bit-identical results, just slower); `--use-pallas`
additionally routes attention forwards and the CKA drift probe through
the Pallas kernels (interpret mode on CPU).

    PYTHONPATH=src python examples/multi_stream.py --preset two-stream \
        --method etuner --batches 6 --inferences 16 --scenarios 3
    PYTHONPATH=src python examples/multi_stream.py --preset mixed \
        --memory-budget 2.5
    PYTHONPATH=src python examples/multi_stream.py --preset qos \
        --preemptible --trigger-policy priority-weighted
    PYTHONPATH=src python examples/multi_stream.py --arch deit-tiny \
        --use-pallas
    PYTHONPATH=src python examples/multi_stream.py --preset qos \
        --preemptible --trace-out /tmp/qos_trace.json

`--trace-out` turns on telemetry (DESIGN.md §14): the run records every
round/segment/swap/serve/publish on the modeled timeline and writes a
Perfetto-loadable Chrome trace (or a JSONL event feed when the path ends
in ``.jsonl``); summarize it with `python -m benchmarks.trace_report`.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import METHODS, run_workload, trace_spec
from repro.workloads import presets


def main():
    names = sorted(presets())
    ap = argparse.ArgumentParser(
        description="Run one workload preset through the declarative "
                    "EdgeOL session API and report per-stream/per-model "
                    "attribution.")
    ap.add_argument("--preset", "--workload", dest="preset",
                    default="two-stream", choices=names,
                    help="workload preset (--workload is a legacy alias)")
    ap.add_argument("--method", default="etuner",
                    choices=list(METHODS) + ["egeria", "slimfit", "ekya"],
                    help="paper methods run as declarative policy stacks; "
                         "the SOTA baselines inject monolithic controllers")
    ap.add_argument("--arch", default="mobilenetv2",
                    choices=["mobilenetv2", "resnet50", "deit-tiny"],
                    help="model for 'cv' streams (an 'nlp' stream always "
                         "gets the BERT slot)")
    ap.add_argument("--scenarios", type=int, default=3)
    ap.add_argument("--batches", type=int, default=6,
                    help="training batches per scenario per stream")
    ap.add_argument("--inferences", type=int, default=16,
                    help="inference requests per stream over the horizon")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preemptible", action="store_true",
                    help="QoS: let higher-priority inference arrivals "
                         "split in-flight fine-tuning rounds (try with "
                         "--preset qos)")
    ap.add_argument("--trigger-policy", default="default",
                    choices=["default", "priority-weighted"],
                    help="priority-weighted scales LazyTune's accumulation "
                         "target by StreamSpec.priority (paper methods "
                         "with LazyTune only; try with --preset qos)")
    ap.add_argument("--memory-budget", type=float, default=0.0,
                    help="ModelPool device memory budget in MB (0 = "
                         "unlimited); only multi-modality workloads "
                         "(mixed) swap — try 2.5 to force it")
    ap.add_argument("--no-compiled", dest="compiled", action="store_false",
                    help="use the pure-Python per-event fallback instead "
                         "of the segment-batched compiled hot path "
                         "(bit-identical results; DESIGN.md §12)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route attention forwards and the CKA drift "
                         "probe through the Pallas kernels (interpret "
                         "mode on CPU)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record the run's telemetry trace (DESIGN.md "
                         "§14) to PATH: a Perfetto-loadable Chrome trace, "
                         "or the JSONL event feed if PATH ends in "
                         "'.jsonl'; summarize with `python -m "
                         "benchmarks.trace_report PATH`")
    args = ap.parse_args()

    from repro.launch.platform import bootstrap
    bootstrap()

    spec = presets(batches_per_scenario=args.batches,
                   inferences=args.inferences,
                   num_scenarios=args.scenarios,
                   seed=args.seed)[args.preset]
    print(f"workload {spec.name}: {len(spec.streams)} stream(s), "
          f"{len(spec.modalities)} model slot(s) {spec.modalities}, "
          f"{spec.num_scenarios} scenarios, drift={spec.drift}, "
          f"preemptible={args.preemptible}, "
          f"trigger={args.trigger_policy}")
    cell = run_workload(args.arch, spec, args.method, seed=args.seed,
                        preemptible=args.preemptible,
                        memory_budget_mb=args.memory_budget,
                        trigger_policy=args.trigger_policy,
                        compiled=args.compiled,
                        use_pallas=args.use_pallas,
                        workload_scale=dict(
                            batches_per_scenario=args.batches,
                            inferences=args.inferences,
                            num_scenarios=args.scenarios),
                        telemetry=trace_spec(args.trace_out))
    print(f"{args.method:10s} acc={cell['acc']*100:6.2f}% "
          f"time={cell['time_s']:7.1f}s energy={cell['energy_j']:7.1f}J "
          f"rounds={cell['rounds']} events={cell['events']} "
          f"preemptions={cell['preemptions']} swaps={cell['swaps']} "
          f"(wall {cell['wall_s']:.0f}s)")
    for sid, per in sorted(cell["per_stream"].items()):
        ss = spec.streams[int(sid)]
        print(f"  stream {sid} [{ss.modality}/{ss.benchmark} "
              f"data={ss.data_dist} inf={ss.inf_dist} prio={ss.priority}] "
              f"acc={per['avg_inference_acc']*100:6.2f}% "
              f"time={per['time_s']:6.1f}s energy={per['energy_j']:6.1f}J "
              f"rounds={per['rounds']:.0f} requests={per['inferences']:.0f} "
              f"p50={per['latency_p50']:.2f}s p95={per['latency_p95']:.2f}s")
    for mid, per in sorted(cell["per_model"].items()):
        print(f"  model  {mid:7s} acc={per['avg_inference_acc']*100:6.2f}% "
              f"time={per['time_s']:6.1f}s energy={per['energy_j']:6.1f}J "
              f"rounds={per['rounds']:.0f} requests={per['inferences']:.0f} "
              f"swaps={per['swaps']:.0f}")
    if args.trace_out:
        print(f"trace written to {args.trace_out} — load at "
              f"https://ui.perfetto.dev or run "
              f"`python -m benchmarks.trace_report {args.trace_out}`")


if __name__ == "__main__":
    main()
