"""End-to-end continual-learning driver (paper Table II / Figs. 8-9 style):
compare Immed / LazyTune / SimFreeze / ETuner on a chosen model and
benchmark, with per-method time/energy/accuracy and the controller's
decision log. Each method is a declarative policy stack
(`benchmarks.common.method_policies`) run through the `RuntimeConfig`
session API (DESIGN.md §11).

    PYTHONPATH=src python examples/continual_cv.py --arch mobilenetv2 \
        --bench nc --scenarios 4 --batches 8 --inferences 30
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import run_method


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mobilenetv2",
                    choices=["mobilenetv2", "resnet50", "deit-tiny"])
    ap.add_argument("--bench", default="nc",
                    choices=["nc", "ni", "nic", "s-cifar"])
    ap.add_argument("--scenarios", type=int, default=4)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--inferences", type=int, default=30)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--inference-window", type=float, default=0.0,
                    help="micro-batched serving: coalesce requests landing "
                         "within this many timeline seconds into one "
                         "forward pass (0 = per-request serving)")
    args = ap.parse_args()

    base = None
    for method in ("immed", "lazytune", "simfreeze", "etuner"):
        r = run_method(args.arch, args.bench, method,
                       seeds=tuple(range(args.seeds)),
                       scenarios=args.scenarios, batches=args.batches,
                       inferences=args.inferences,
                       inference_window=args.inference_window)
        if base is None:
            base = r
        print(f"{method:10s} acc={r['acc']*100:6.2f}% "
              f"time={r['time_s']:7.1f}s ({r['time_s']/base['time_s']*100:5.1f}%) "
              f"energy={r['energy_j']:7.1f}J ({r['energy_j']/base['energy_j']*100:5.1f}%) "
              f"rounds={r['rounds']:.0f} tflops={r['tflops']:.3f}")


if __name__ == "__main__":
    main()
