"""DeviceFleet driver: run a workload across a simulated multi-device
fleet (DESIGN.md §13) and print per-device utilization, swap/sync counts
and the fleet-level accuracy.

A fleet session is the same declarative `RuntimeConfig` session as any
other — plus a device list (`--devices` heterogeneous edge devices with
deterministic speed/energy spread), a routing policy (`--routing static`
pins stream i to device i mod N; `least-loaded` packs streams onto
devices LPT-style by event count over device speed), and a federated
aggregation period (`--aggregate-every` timeline seconds: devices'
fine-tuned params merge as a rounds-weighted average, each participant
charged a cross-device sync). `--devices 1` degenerates to the classic
single-device run — bit-for-bit, which `tests/test_fleet.py` pins.

The default preset is `fleet` (hundreds of light camera streams, scaled
down here by --streams); any other preset works too:

    PYTHONPATH=src python examples/fleet.py --devices 4 --streams 12
    PYTHONPATH=src python examples/fleet.py --devices 8 --routing static \
        --aggregate-every 50 --streams 24 --inferences 8
    PYTHONPATH=src python examples/fleet.py --preset two-stream --devices 2
    PYTHONPATH=src python examples/fleet.py --devices 4 --streams 12 \
        --trace-out /tmp/fleet_trace.json

`--trace-out` turns on telemetry (DESIGN.md §14): a Perfetto-loadable
Chrome trace with one track per device lane (the occupancy Gantt of
rounds, swaps and fleet syncs) and one per stream (request latency
spans); ``.jsonl`` paths get the raw event feed instead. Summarize with
`python -m benchmarks.trace_report`.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import METHODS, run_workload, trace_spec
from repro.runtime import ROUTING_POLICIES, fleet_devices
from repro.workloads import presets


def main():
    names = sorted(presets())
    ap = argparse.ArgumentParser(
        description="Run a workload across a simulated DeviceFleet and "
                    "report per-device utilization and fleet-level "
                    "accuracy.")
    ap.add_argument("--preset", default="fleet", choices=names,
                    help="workload preset (default: the many-stream "
                         "fleet preset)")
    ap.add_argument("--devices", type=int, default=4,
                    help="fleet size; heterogeneous speed/energy scales "
                         "are drawn deterministically from the seed "
                         "(device 0 is always the 1.0x reference)")
    ap.add_argument("--routing", default="least-loaded",
                    choices=sorted(ROUTING_POLICIES),
                    help="initial stream->device placement policy")
    ap.add_argument("--aggregate-every", type=float, default=50.0,
                    help="federated merge period in timeline seconds "
                         "(0 = never aggregate; devices drift apart)")
    ap.add_argument("--method", default="etuner",
                    choices=list(METHODS) + ["egeria", "slimfit", "ekya"])
    ap.add_argument("--arch", default="mobilenetv2",
                    choices=["mobilenetv2", "resnet50", "deit-tiny"])
    ap.add_argument("--streams", type=int, default=8,
                    help="stream count of the 'fleet' preset (other "
                         "presets have a fixed stream mix)")
    ap.add_argument("--scenarios", type=int, default=2)
    ap.add_argument("--batches", type=int, default=4,
                    help="training batches per scenario per stream")
    ap.add_argument("--inferences", type=int, default=8,
                    help="inference requests per stream over the horizon")
    ap.add_argument("--speed-spread", type=float, default=0.4,
                    help="clone devices draw speed scales from 1 +- this")
    ap.add_argument("--energy-spread", type=float, default=0.2)
    ap.add_argument("--battery-j", type=float, default=0.0,
                    help="per-device battery capacity in joules "
                         "(DESIGN.md §15; 0 = mains power). Pairs with "
                         "--throttle battery so rounds defer instead of "
                         "overdrawing; a device draining to its reserve "
                         "anyway is evicted like a straggler")
    ap.add_argument("--thermal-cap", type=float, default=0.0,
                    help="DVFS thermal cap in deg C (0 = no governor): "
                         "devices at/above the cap step down the "
                         "frequency ladder — slower but cooler and "
                         "cheaper per unit work")
    ap.add_argument("--throttle", default="none",
                    choices=["none", "battery", "thermal"],
                    help="ThrottlePolicy facet for the paper methods' "
                         "policy stacks (DESIGN.md §15)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-compiled", dest="compiled", action="store_false",
                    help="pure-Python per-event fallback (bit-identical)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record the fleet's telemetry trace (DESIGN.md "
                         "§14) to PATH: a Perfetto-loadable Chrome trace "
                         "with one track per device and per stream, or "
                         "the JSONL event feed if PATH ends in '.jsonl'; "
                         "summarize with `python -m "
                         "benchmarks.trace_report PATH`")
    args = ap.parse_args()

    from repro.launch.platform import bootstrap
    bootstrap()

    scale = dict(batches_per_scenario=args.batches,
                 inferences=args.inferences,
                 num_scenarios=args.scenarios,
                 fleet_streams=args.streams)
    spec = presets(seed=args.seed, **scale)[args.preset]
    devices = fleet_devices(args.devices, seed=args.seed,
                            speed_spread=args.speed_spread,
                            energy_spread=args.energy_spread)
    print(f"workload {spec.name}: {len(spec.streams)} stream(s) over "
          f"{len(devices)} device(s), routing={args.routing}, "
          f"aggregate_every={args.aggregate_every:g}s, "
          f"method={args.method}")
    for d in devices:
        print(f"  {d.name}: speed x{d.speed_scale:.2f} "
              f"energy x{d.energy_scale:.2f}")
    cell = run_workload(args.arch, spec, args.method, seed=args.seed,
                        compiled=args.compiled, workload_scale=scale,
                        devices=devices, routing=args.routing,
                        aggregate_every=args.aggregate_every,
                        energy_budget_j=args.battery_j,
                        thermal_cap_c=args.thermal_cap,
                        throttle=args.throttle,
                        telemetry=trace_spec(args.trace_out))
    print(f"{args.method:10s} fleet acc={cell['acc']*100:6.2f}% "
          f"time={cell['time_s']:7.1f}s energy={cell['energy_j']:7.1f}J "
          f"rounds={cell['rounds']} syncs={cell['syncs']} "
          f"events={cell['events']} (wall {cell['wall_s']:.0f}s)")
    for did, per in sorted(cell["per_device"].items()):
        print(f"  device {did:6s} util={per['utilization']*100:5.1f}% "
              f"acc={per['avg_inference_acc']*100:6.2f}% "
              f"streams={per['streams']:.0f} rounds={per['rounds']:.0f} "
              f"requests={per['inferences']:.0f} "
              f"swaps={per['swaps']:.0f} syncs={per['syncs']:.0f} "
              f"time={per['time_s']:6.1f}s energy={per['energy_j']:6.1f}J"
              + (f" throttled={per['throttle_s']:.0f}s"
                 if per.get("throttle_s") else "")
              + ("  [battery dead]" if per.get("battery_dead") else "")
              + ("  [evicted]" if per.get("evicted") else ""))
    if args.trace_out:
        print(f"trace written to {args.trace_out} — load at "
              f"https://ui.perfetto.dev or run "
              f"`python -m benchmarks.trace_report {args.trace_out}`")


if __name__ == "__main__":
    main()
