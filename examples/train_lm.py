"""End-to-end LM training driver: train a dense decoder LM for a few
hundred steps on synthetic next-token data with the full substrate —
AdamW + cosine schedule, checkpoint manager (async, crash-safe), and
SimFreeze freezing groups mid-run (recompile-cached, exactly like the
production path).

Default preset is CPU-sized (~6M params); --preset 100m builds a ~100M
model (same code path, heavier).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

PRESETS = {
    "tiny": dict(num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
                 head_dim=32, d_ff=512, vocab_size=2048),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768),
}


def synthetic_batch(rng, vocab, batch, seq):
    # Markov-ish synthetic stream: next token correlated with current
    toks = rng.integers(0, vocab, (batch, seq + 1))
    toks[:, 1:] = (toks[:, :-1] * 31 + toks[:, 1:]) % vocab
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "targets": jnp.asarray(toks[:, 1:], jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--freeze-at", type=int, default=120,
                    help="step at which SimFreeze-style prefix freezing kicks in")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"train-lm-{args.preset}", family="dense",
                      remat="none", **PRESETS[args.preset])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  groups={model.num_freeze_units}")

    opt_cfg = AdamWConfig(lr=3e-3)
    opt_state = adamw_init(params, opt_cfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    # resume if a checkpoint exists (crash-safe restart path)
    restored, step0 = mgr.restore_latest((params, opt_state))
    if restored is not None:
        params, opt_state = restored
        print(f"resumed from step {step0}")
    step0 = max(step0, 0)

    from repro.core.freeze_plan import FreezePlan

    step_cache = {}

    def make_step(plan):
        def train_step(params, opt_state, batch, lr_scale):
            (loss, m), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, plan), has_aux=True)(params)
            params, opt_state = adamw_update(grads, opt_state, params,
                                             opt_cfg, lr_scale=lr_scale)
            return params, opt_state, loss

        return jax.jit(train_step)

    rng = np.random.default_rng(0)
    plan = None
    t0 = time.time()
    losses = []
    for step in range(step0, args.steps):
        if step == args.freeze_at:
            G = model.num_freeze_units
            plan = FreezePlan(groups=tuple(i < G // 2 for i in range(G)),
                              embed=True)
            print(f"step {step}: freezing prefix {G//2}/{G} groups + embed "
                  f"(recompile, cached)")
        key = plan
        if key not in step_cache:
            step_cache[key] = make_step(plan)
        batch = synthetic_batch(rng, cfg.vocab_size, args.batch, args.seq)
        lr = cosine_schedule(step, warmup=20, total=args.steps)
        params, opt_state, loss = step_cache[key](params, opt_state, batch, lr)
        losses.append(float(loss))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(loss):.4f} "
                  f"({(step - step0 + 1) / (time.time() - t0):.1f} it/s)")
        if step % 50 == 49:
            mgr.save(step, (params, opt_state))
    mgr.save(args.steps - 1, (params, opt_state), block=True)
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
