"""Lower+compile one (arch x shape) cell on the production mesh and print
its roofline terms — a thin, readable wrapper over repro.launch.dryrun.

    PYTHONPATH=src python examples/distributed_dryrun.py \
        --arch gemma2-2b --shape train_4k --mesh single
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    record = run_cell(args.arch, args.shape, args.mesh)
    print(json.dumps(record, indent=1))


if __name__ == "__main__":
    main()
