"""Quickstart: ETuner vs immediate fine-tuning on a tiny continual-learning
stream (CPU, ~1 minute), built through the declarative session API
(DESIGN.md §11): a `RuntimeConfig` names the model slot, its benchmark
and its policy stack (trigger / freeze / drift / publish), and
`edgeol_session` materializes the runtime.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.policies import PolicySpec, PolicyStackSpec
from repro.runtime import RuntimeConfig, SlotConfig, edgeol_session

BENCH = dict(num_classes=10, num_scenarios=4, batches=8, batch_size=16)

STACKS = {
    # immediate fine-tuning: every batch triggers, nothing freezes
    "Immediate": PolicyStackSpec(trigger=PolicySpec("immediate"),
                                 freeze=PolicySpec("none"),
                                 drift=PolicySpec("none")),
    # ETuner = LazyTune trigger + SimFreeze plan (paper Algorithm 1)
    "ETuner": PolicyStackSpec(
        trigger=PolicySpec("lazytune", {"max_batches_needed": 8.0}),
        freeze=PolicySpec("simfreeze", {"freeze_interval": 6}),
        drift=PolicySpec("none")),
}


def main():
    for name, stack in STACKS.items():
        cfg = RuntimeConfig(
            slots={"default": SlotConfig(arch="mobilenetv2", benchmark="nc",
                                         benchmark_kw=BENCH,
                                         policies=stack)},
            pretrain_epochs=2)
        rt = edgeol_session(cfg)
        res = rt.run(inferences_total=24)
        print(f"{name:10s} {res.summary()}")
        bd = {k: round(v, 2) for k, v in res.breakdown.items()}
        print(f"           breakdown: {bd}")
        print(f"           controller: {rt.controller.stats()}")


if __name__ == "__main__":
    main()
