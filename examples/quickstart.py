"""Quickstart: ETuner vs immediate fine-tuning on a tiny continual-learning
stream (CPU, ~1 minute).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_reduced
from repro.core import (ETunerConfig, ETunerController, LazyTuneConfig,
                        SimFreezeConfig)
from repro.data import streams
from repro.models import build_model
from repro.runtime.continual import ContinualRuntime


def main():
    model = build_model(get_reduced("mobilenetv2"))
    bench = streams.nc_benchmark(num_classes=10, num_scenarios=4, batches=8,
                                 batch_size=16)
    for name, (lazy, freeze) in [("Immediate", (False, False)),
                                 ("ETuner", (True, True))]:
        ctrl = ETunerController(model, ETunerConfig(
            lazytune=lazy, simfreeze=freeze, detect_scenario_changes=False,
            lazytune_cfg=LazyTuneConfig(max_batches_needed=8),
            simfreeze_cfg=SimFreezeConfig(freeze_interval=6)))
        rt = ContinualRuntime(model, bench, ctrl, pretrain_epochs=2)
        res = rt.run(inferences_total=24)
        print(f"{name:10s} {res.summary()}")
        bd = {k: round(v, 2) for k, v in res.breakdown.items()}
        print(f"           breakdown: {bd}")
        print(f"           controller: {ctrl.stats()}")


if __name__ == "__main__":
    main()
