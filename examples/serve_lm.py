"""Serve a small LM with batched requests through the prefill/decode engine
(the inference half of the continual-learning loop).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --batch 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_reduced
from repro.models import build_model
from repro.runtime.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, max_len=args.prompt_len + args.steps + 8)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    t0 = time.time()
    out = engine.generate(params, prompts, steps=args.steps)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prefill={args.prompt_len} "
          f"decode={args.steps}")
    print(f"generated ids[0]: {out[0].tolist()}")
    print(f"wall={dt:.2f}s  ({args.batch * args.steps / dt:.1f} tok/s total; "
          f"stats={engine.stats})")


if __name__ == "__main__":
    main()
