"""Synthetic continual-learning benchmarks mirroring the paper's setup
(§II "Scenario change", §V-A):

- ``nc_benchmark``   — CORe50-NC-style: each scenario introduces new
  classes on top of the existing ones (class-incremental).
- ``ni_benchmark``   — new-instance: same classes, new feature patterns
  (illumination / background / occlusion-style transforms).
- ``nic_benchmark``  — NICv2-style mix of both.
- ``split_benchmark``— S-CIFAR-10-style: disjoint class pairs per scenario.
- ``text_benchmark`` — 20News-style class-incremental token streams for the
  BERT model.

Data is synthetic (no dataset downloads in this container) but structured:
every class has a latent prototype; instances are prototype + structured
noise; "new pattern" scenarios apply a fixed per-scenario transform
(brightness/contrast shift + channel mix + spatial roll) so a model really
must adapt. Labels are exact. The same generator yields train batches,
a 5% validation split (paper §IV-A) and a held-out test set per scenario.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class Scenario:
    index: int
    train_batches: List[dict]     # list of {"images"/"tokens", "labels"}
    val: dict
    test: dict
    classes: List[int]
    kind: str = "nc"              # nc | ni | nic


@dataclass
class ContinualBenchmark:
    name: str
    scenarios: List[Scenario]
    num_classes: int
    modality: str = "image"       # image | text

    @property
    def num_scenarios(self) -> int:
        return len(self.scenarios)


# ---------------------------------------------------------------------------
# image benchmarks


class _ImageWorld:
    """Latent class prototypes + per-scenario appearance transforms."""

    def __init__(self, num_classes: int, size: int, seed: int):
        rng = np.random.default_rng(seed)
        self.size = size
        self.rng = rng
        # smooth prototypes: low-frequency random fields per class
        base = rng.normal(0, 1, (num_classes, 8, 8, 3))
        self.protos = np.stack([_upsample(b, size) for b in base])

    def sample(self, cls: np.ndarray, transform_id: int, n_noise: float = 0.35):
        rng = self.rng
        imgs = self.protos[cls] + rng.normal(0, n_noise, (len(cls), self.size, self.size, 3))
        if transform_id:
            t = np.random.default_rng(1000 + transform_id)
            bright = t.uniform(0.5, 1.6)
            mix = np.eye(3) + t.normal(0, 0.25, (3, 3))
            roll = t.integers(0, self.size // 2)
            imgs = (imgs * bright) @ mix
            imgs = np.roll(imgs, roll, axis=2)
        return imgs.astype(np.float32)


def _upsample(x: np.ndarray, size: int) -> np.ndarray:
    reps = size // x.shape[0]
    return np.repeat(np.repeat(x, reps, axis=0), reps, axis=1)


def _make_image_scenario(world: _ImageWorld, idx: int, classes: List[int],
                         transform_id: int, batches: int, batch_size: int,
                         test_size: int, kind: str, seed: int) -> Scenario:
    rng = np.random.default_rng(seed)
    train_batches = []
    n_train = batches * batch_size
    cls = rng.choice(classes, n_train + max(test_size, 8))
    imgs = world.sample(cls, transform_id)
    val_n = max(batch_size, int(0.05 * n_train))  # ~5% validation (paper)
    test = {"images": imgs[n_train:], "labels": cls[n_train:].astype(np.int32)}
    # validation carved from the head of the train stream
    val = {"images": imgs[:val_n], "labels": cls[:val_n].astype(np.int32)}
    for b in range(batches):
        sl = slice(b * batch_size, (b + 1) * batch_size)
        train_batches.append({"images": imgs[sl], "labels": cls[sl].astype(np.int32)})
    return Scenario(index=idx, train_batches=train_batches, val=val, test=test,
                    classes=list(classes), kind=kind)


def nc_benchmark(num_classes=10, num_scenarios=5, batches=24, batch_size=16,
                 image_size=32, test_size=64, seed=0) -> ContinualBenchmark:
    """Class-incremental: scenario s adds `num_classes/num_scenarios` new
    classes; train data covers the new classes, test covers all seen."""
    world = _ImageWorld(num_classes, image_size, seed)
    per = num_classes // num_scenarios
    scenarios = []
    seen: List[int] = []
    for s in range(num_scenarios):
        new = list(range(s * per, (s + 1) * per))
        seen = seen + new
        sc = _make_image_scenario(world, s, new if s else seen, 0, batches,
                                  batch_size, test_size, "nc", seed + 7 * s + 1)
        # test on all classes seen so far (average inference accuracy def.)
        rng = np.random.default_rng(seed + 91 * s)
        cls = rng.choice(seen, test_size)
        sc.test = {"images": world.sample(cls, 0),
                   "labels": cls.astype(np.int32)}
        scenarios.append(sc)
    return ContinualBenchmark("nc", scenarios, num_classes)


def ni_benchmark(num_classes=10, num_scenarios=5, batches=24, batch_size=16,
                 image_size=32, test_size=64, seed=0) -> ContinualBenchmark:
    """New-instance: all classes from the start; each scenario applies a
    new appearance transform (illumination/background-style shift)."""
    world = _ImageWorld(num_classes, image_size, seed)
    classes = list(range(num_classes))
    scenarios = [
        _make_image_scenario(world, s, classes, s, batches, batch_size,
                             test_size, "ni", seed + 7 * s + 1)
        for s in range(num_scenarios)]
    return ContinualBenchmark("ni", scenarios, num_classes)


def nic_benchmark(num_classes=10, num_scenarios=8, batches=12, batch_size=16,
                  image_size=32, test_size=64, seed=0) -> ContinualBenchmark:
    """NICv2-style: alternates new-class and new-instance scenarios."""
    world = _ImageWorld(num_classes, image_size, seed)
    per = max(1, num_classes // (num_scenarios // 2 + 1))
    scenarios = []
    seen: List[int] = list(range(per))
    transform = 0
    for s in range(num_scenarios):
        if s % 2 == 1 and len(seen) < num_classes:  # new classes
            new = list(range(len(seen), min(len(seen) + per, num_classes)))
            seen += new
            sc = _make_image_scenario(world, s, new, transform, batches,
                                      batch_size, test_size, "nc", seed + 7 * s)
        else:  # new instances
            transform += 1
            sc = _make_image_scenario(world, s, seen, transform, batches,
                                      batch_size, test_size, "ni", seed + 7 * s)
        rng = np.random.default_rng(seed + 91 * s)
        cls = rng.choice(seen, test_size)
        sc.test = {"images": world.sample(cls, transform),
                   "labels": cls.astype(np.int32)}
        scenarios.append(sc)
    return ContinualBenchmark("nic", scenarios, num_classes)


def split_benchmark(num_classes=10, batches=24, batch_size=16, image_size=32,
                    test_size=64, seed=0) -> ContinualBenchmark:
    """S-CIFAR-10-style: 5 scenarios x 2 disjoint classes."""
    world = _ImageWorld(num_classes, image_size, seed)
    scenarios = []
    for s in range(num_classes // 2):
        classes = [2 * s, 2 * s + 1]
        sc = _make_image_scenario(world, s, classes, 0, batches, batch_size,
                                  test_size, "nc", seed + 7 * s + 1)
        seen = list(range(0, 2 * s + 2))
        rng = np.random.default_rng(seed + 91 * s)
        cls = rng.choice(seen, test_size)
        sc.test = {"images": world.sample(cls, 0), "labels": cls.astype(np.int32)}
        scenarios.append(sc)
    return ContinualBenchmark("s-cifar", scenarios, num_classes)


# ---------------------------------------------------------------------------
# text benchmark (20News-style)


def text_benchmark(num_classes=10, num_scenarios=5, batches=20, batch_size=16,
                   seq_len=32, vocab=512, seed=0) -> ContinualBenchmark:
    """Class-incremental text: each class boosts a distinct token subset on
    top of a shared Zipf background (20News split into class pairs)."""
    rng = np.random.default_rng(seed)
    zipf = 1.0 / np.arange(1, vocab + 1)
    zipf /= zipf.sum()
    boosts = []
    for c in range(num_classes):
        b = np.zeros(vocab)
        toks = rng.choice(vocab, 24, replace=False)
        b[toks] = 1.0
        boosts.append(b)

    def sample(cls, n):
        out = np.zeros((n, seq_len), np.int64)
        for i, c in enumerate(cls):
            p = zipf + 0.3 * boosts[c] / boosts[c].sum()
            p /= p.sum()
            out[i] = rng.choice(vocab, seq_len, p=p)
        return out.astype(np.int32)

    per = num_classes // num_scenarios
    scenarios = []
    seen: List[int] = []
    for s in range(num_scenarios):
        new = list(range(s * per, (s + 1) * per))
        seen = seen + new
        cls_pool = new if s else seen
        n_train = batches * batch_size
        cls = rng.choice(cls_pool, n_train)
        toks = sample(cls, n_train)
        val_n = max(batch_size, int(0.05 * n_train))
        train_batches = [{"tokens": toks[b * batch_size:(b + 1) * batch_size],
                          "labels": cls[b * batch_size:(b + 1) * batch_size].astype(np.int32)}
                         for b in range(batches)]
        tcls = rng.choice(seen, 64)
        test = {"tokens": sample(tcls, 64), "labels": tcls.astype(np.int32)}
        val = {"tokens": toks[:val_n], "labels": cls[:val_n].astype(np.int32)}
        scenarios.append(Scenario(index=s, train_batches=train_batches,
                                  val=val, test=test, classes=cls_pool, kind="nc"))
    return ContinualBenchmark("20news", scenarios, num_classes, modality="text")


REGISTRY = {"nc": nc_benchmark, "ni": ni_benchmark, "nic": nic_benchmark,
            "s-cifar": split_benchmark, "20news": text_benchmark}
