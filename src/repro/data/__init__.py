from repro.data import arrivals, streams

__all__ = ["arrivals", "streams"]
