"""Arrival processes for training-data batches and inference requests
(paper §V-A: Poisson by default; §V-D sensitivity adds uniform, normal and
a real-world trace). Deterministic given a seed."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Literal, Sequence

import numpy as np

Kind = Literal["data", "inference", "probe"]

#: Tie-break rank at equal timestamps: data batches dispatch before
#: inference requests, and drift-confirmation probes (detector mode) run
#: last — they observe the state the colliding events produced. Single
#: source of truth for both the scheduler's heap ordering and the workload
#: compiler's sort — they must agree or a pre-sorted timeline would not
#: replay in its constructed order.
KIND_ORDER = {"data": 0, "inference": 1, "probe": 2}


@dataclass(frozen=True)
class Event:
    time: float
    kind: Kind
    scenario: int
    index: int   # index within its (stream, kind) sequence
    stream: int = 0  # arrival stream id (0 = the single legacy stream)
    # QoS priority inherited from the stream's `StreamSpec.priority`:
    # higher dispatches first at equal (time, kind), and a high enough
    # priority may preempt an in-flight fine-tuning round (scheduler.py).
    # 0 = the legacy don't-care priority, so single-stream timelines are
    # byte-identical to their pre-QoS selves.
    priority: int = 0
    # Modality of the stream that emitted the event
    # (`StreamSpec.modality`, stamped by workloads/generators). A
    # ModelPool runtime resolves the event's model slot from this tag;
    # the single-model runtime ignores it. "cv" is the legacy default so
    # hand-built timelines stay valid.
    modality: str = "cv"


def interarrivals(dist: str, n: int, mean_gap: float,
                  rng: np.random.Generator,
                  trace: Sequence[float] = ()) -> np.ndarray:
    """Draw `n` inter-arrival gaps with the given mean from one of the
    paper's §V-D distributions. Shared by `build_timeline` and the
    workload generators (repro.workloads.generators), which add the
    modulated processes (MMPP, diurnal) on top."""
    if n <= 0:
        return np.zeros(0)
    if dist == "poisson":
        return rng.exponential(mean_gap, n)
    if dist == "uniform":
        return rng.uniform(0.0, 2.0 * mean_gap, n)
    if dist == "normal":
        return np.clip(rng.normal(mean_gap, 0.3 * mean_gap, n), 0.01 * mean_gap, None)
    if dist == "trace":
        # Real-world-trace mode: resample the provided inter-arrival trace
        # (normalized to the requested mean), mimicking §V-D's VTT trace.
        t = np.asarray(trace if len(trace) else _DEFAULT_TRACE, np.float64)
        t = t / t.mean() * mean_gap
        reps = int(np.ceil(n / t.size))
        return np.tile(t, reps)[:n]
    raise ValueError(dist)


# A bursty inter-arrival pattern standing in for the Video-Timeline-Tags
# trace used by the paper (long gaps between dense bursts).
_DEFAULT_TRACE = [0.2, 0.1, 0.15, 0.1, 3.0, 0.2, 0.1, 0.1, 4.5, 0.3,
                  0.1, 0.2, 0.1, 0.1, 6.0, 0.5, 0.2, 0.1, 2.5, 0.2]


def build_timeline(*, num_scenarios: int, batches_per_scenario: int,
                   inferences_total: int, scenario_span: float = 100.0,
                   data_dist: str = "poisson", inf_dist: str = "poisson",
                   seed: int = 0) -> List[Event]:
    """Merged, time-sorted event list. Scenario s occupies
    [s*span, (s+1)*span); its training batches arrive inside it; inference
    requests arrive over the whole horizon (paper Fig. 1: bursts allowed)."""
    rng = np.random.default_rng(seed)
    events: List[Event] = []
    for s in range(num_scenarios):
        gaps = interarrivals(data_dist, batches_per_scenario,
                             scenario_span / max(batches_per_scenario, 1) * 0.9,
                             rng)
        t = s * scenario_span + np.cumsum(gaps)
        t = np.minimum(t, (s + 1) * scenario_span - 1e-3)
        for i, ti in enumerate(t):
            events.append(Event(float(ti), "data", s, i))
    horizon = num_scenarios * scenario_span
    gaps = interarrivals(inf_dist, inferences_total,
                         horizon / max(inferences_total, 1), rng)
    t = np.cumsum(gaps)
    t = t * (horizon / max(t[-1], 1e-9)) if len(t) else t
    for i, ti in enumerate(t):
        s = min(int(ti // scenario_span), num_scenarios - 1)
        events.append(Event(float(ti), "inference", s, i))
    events.sort(key=lambda e: (e.time, e.kind))
    return events
