"""Host platform bootstrap for benchmarks and examples (DESIGN.md §12).

One idempotent entry point, `bootstrap()`, to be called before the first
jax dispatch: it pins the jax platform, applies the GPU latency-hiding
XLA scheduler flags (no-ops elsewhere), optionally fans the CPU backend
out into several host devices (`--xla_force_host_platform_device_count`,
useful for mesh dry-runs on a laptop), and silences the CPU
buffer-donation warning the compiled hot path would otherwise emit per
program. Library code never calls this — sessions must work under
whatever platform the embedder configured — which is why it lives under
`repro.launch` next to the other entry-point helpers.
"""
from __future__ import annotations

import os
import warnings

_GPU_XLA_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

_bootstrapped = False


def _merge_xla_flags(*flags: str) -> None:
    """Append flags to XLA_FLAGS, replacing an existing setting of the
    same flag rather than duplicating it."""
    current = os.environ.get("XLA_FLAGS", "").split()
    keys = {f.split("=", 1)[0] for f in flags}
    kept = [f for f in current if f.split("=", 1)[0] not in keys]
    os.environ["XLA_FLAGS"] = " ".join(kept + list(flags))


def set_host_device_count(n: int) -> None:
    """Split the host platform into `n` devices (CPU mesh dry-runs).
    Must run before the jax backend initializes."""
    _merge_xla_flags(f"--xla_force_host_platform_device_count={int(n)}")


def set_platform(platform: str) -> None:
    """Pin the jax platform ('cpu' | 'gpu' | 'tpu') and apply the
    platform's XLA scheduling flags. Must run before the first jax
    computation."""
    import jax

    if platform == "gpu":
        _merge_xla_flags(*_GPU_XLA_FLAGS)
    jax.config.update("jax_platform_name", platform)


def enable_compile_cache(cache_dir: str = None) -> None:
    """Point XLA's persistent compilation cache at `cache_dir` (default:
    $EDGEOL_XLA_CACHE, else ~/.cache/edgeol/xla; pass "" via either
    route to disable). Must run before the first jax compile.

    This is the cross-process half of the compiled hot path's
    initialization story (DESIGN.md §12): within one process, sessions
    share programs through the registries in runtime/train_loop.py; with
    the disk cache, a fresh process (the CI sweep, a relaunched edge
    runtime) deserializes yesterday's programs in tens of milliseconds
    instead of re-paying multi-second XLA compiles — the same
    "amortize system initialization" premise LazyTune applies to
    in-process retraces (paper §IV-B)."""
    import jax

    if cache_dir is None:
        cache_dir = os.environ.get(
            "EDGEOL_XLA_CACHE",
            os.path.join(os.path.expanduser("~"), ".cache", "edgeol", "xla"))
    if not cache_dir:
        return
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # default thresholds skip small/fast programs; an edge deployment
    # wants every program persisted — the point is a compile-free restart
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


def bootstrap(platform: str = None, host_devices: int = None,
              enable_x64: bool = False, cache_dir: str = None) -> None:
    """Idempotent process setup for entry points (benchmarks, examples,
    microbenches). `platform` defaults to the EDGEOL_PLATFORM environment
    variable when set, else jax's own default backend."""
    global _bootstrapped
    if _bootstrapped:
        return
    _bootstrapped = True
    # logging first, so everything after (including jax config paths)
    # reports through the "edgeol" logger tree; level from $EDGEOL_LOG
    from repro.obs.log import configure_logging

    configure_logging()
    if host_devices:
        set_host_device_count(host_devices)
    platform = platform or os.environ.get("EDGEOL_PLATFORM")
    if platform:
        set_platform(platform)
    enable_compile_cache(cache_dir)
    if enable_x64:
        import jax

        jax.config.update("jax_enable_x64", True)
    # CPU backends have no donation support; the donated steps are still
    # correct (see runtime/train_loop.py) and the warning is pure noise
    warnings.filterwarnings(
        "ignore", message="Some donated buffers were not usable")
