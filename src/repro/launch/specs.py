"""ShapeDtypeStruct stand-ins for every model input/state — weak-type
correct, shardable, zero allocation. The dry-run lowers against these."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.models import transformer as T


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None and spec is not None else None
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype), sharding=sharding)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      policy: sh.ShardingPolicy = sh.ShardingPolicy()) -> Dict[str, Any]:
    """{tokens, targets, (frontend_embeds)} ShapeDtypeStructs."""
    specs = sh.batch_specs(cfg, shape, mesh, policy)
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((B, S), jnp.int32, mesh, specs["tokens"]),
        "targets": _sds((B, S), jnp.int32, mesh, specs["targets"]),
    }
    if cfg.frontend != "none":
        batch["frontend_embeds"] = _sds(
            (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16,
            mesh, specs["frontend_embeds"])
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        policy: sh.ShardingPolicy = sh.ShardingPolicy()):
    batch = train_batch_specs(cfg, shape, mesh, policy)
    del batch["targets"]
    return batch


def param_structs(cfg: ModelConfig, mesh: Mesh,
                  policy: sh.ShardingPolicy = sh.ShardingPolicy()):
    """(ShapeDtypeStruct pytree, spec pytree) for the model params —
    via eval_shape, so nothing is allocated."""
    structs = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(structs, cfg, mesh, policy)
    with_sharding = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), structs, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return with_sharding, specs


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  policy: sh.ShardingPolicy = sh.ShardingPolicy(),
                  cache_dtype=jnp.bfloat16):
    B, L = shape.global_batch, shape.seq_len
    structs = jax.eval_shape(lambda: T.init_lm_cache(cfg, B, L, cache_dtype))
    specs = sh.cache_specs(cfg, shape, mesh, structs, policy)
    with_sharding = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), structs, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return with_sharding, specs


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       policy: sh.ShardingPolicy = sh.ShardingPolicy()):
    da = sh.data_axes(mesh)
    B = shape.global_batch
    ok = B % max(sh._axis_size(mesh, da), 1) == 0 and sh._axis_size(mesh, da) > 1
    spec = P(da if ok else None, None)
    return _sds((B, 1), jnp.int32, mesh, spec)
