import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first
# init, and the production meshes need 512 placeholder devices.

"""Multi-pod dry-run launcher.

Per cell (arch x input-shape x mesh): build ShapeDtypeStruct inputs with
production shardings, ``jax.jit(step).lower(...).compile()``, print
memory_analysis (proves the per-device footprint) + cost_analysis (FLOPs /
bytes for the roofline), parse the partitioned HLO for collective bytes,
and append the JSON record to benchmarks/results/dryrun/.

Worker mode:      python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
Orchestrator:     python -m repro.launch.dryrun --all [--mesh single|multi|both]
(the orchestrator shells out one subprocess per cell so each gets a fresh
XLA runtime and an enforceable timeout).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Optional

from repro.obs.log import configure_logging, get_logger

log = get_logger("launch.dryrun")

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def cell_filename(arch: str, shape: str, mesh: str, tag: str = "") -> str:
    suffix = f"_{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             fsdp: bool = True, freeze_prefix: float = 0.0,
             remat: Optional[str] = None, tag: str = "",
             print_analysis: bool = True) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import cell_is_applicable, get_config, get_shape
    from repro.core.freeze_plan import FreezePlan
    from repro.distributed import sharding as sh
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as T
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.roofline import analysis as RA

    t0 = time.time()
    cfg = get_config(arch).replace(ssm_chunk=2048, attn_q_block=4096,
                                   attn_k_block=4096)
    if remat:
        cfg = cfg.replace(remat=remat)
    # Perf-iteration hook: REPRO_OVERRIDES="field=value,..." patches the
    # ModelConfig (types coerced from the field's current value).
    for kv in filter(None, os.environ.get("REPRO_OVERRIDES", "").split(",")):
        key, val = kv.split("=")
        cur = getattr(cfg, key)
        typ = type(cur)
        coerced = (val.lower() in ("1", "true")) if typ is bool else typ(val)
        cfg = cfg.replace(**{key: coerced})
    shape = get_shape(shape_name)
    skip = cell_is_applicable(cfg, shape)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "fsdp": fsdp, "freeze_prefix": freeze_prefix, "tag": tag,
              "remat": cfg.remat}
    if skip:
        record.update({"status": "skip", "reason": skip})
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    policy = sh.ShardingPolicy(fsdp=fsdp)
    # bf16 optimizer moments for >=100B-param configs (DESIGN.md §4)
    big = cfg.param_count() > 100e9
    opt_cfg = AdamWConfig(lr=1e-4, state_dtype="bfloat16" if big else None,
                          clip_norm=0.0)

    params_sds, param_spec = S.param_structs(cfg, mesh, policy)

    if shape.kind == "train":
        batch_sds = S.train_batch_specs(cfg, shape, mesh, policy)
        G = T.num_groups(cfg)
        k = int(G * freeze_prefix)
        plan = FreezePlan(groups=tuple(i < k for i in range(G)),
                          embed=k > 0) if k else None

        def train_step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: T.lm_loss(p, cfg, batch, plan), has_aux=True)(params)
            params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, loss

        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        from repro.optim.optimizer import AdamWState
        opt_spec = AdamWState(step=jax.sharding.PartitionSpec(),
                              m=param_spec, v=param_spec)
        opt_sds = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=jax.sharding.NamedSharding(mesh, sp)),
            opt_sds, opt_spec,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        jitted = jax.jit(
            train_step,
            in_shardings=(sh.named(mesh, param_spec),
                          sh.named(mesh, opt_spec),
                          sh.named(mesh, sh.batch_specs(cfg, shape, mesh, policy))),
            donate_argnums=(0, 1))
        with sh.activation_sharding(mesh):
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        batch_sds = S.prefill_batch_specs(cfg, shape, mesh, policy)

        def prefill_step(params, batch):
            return T.lm_prefill(params, cfg, batch)

        # batch shardings come from the ShapeDtypeStructs themselves
        jitted = jax.jit(prefill_step)
        with sh.activation_sharding(mesh):
            lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        cache_sds, cache_spec = S.cache_structs(cfg, shape, mesh, policy)
        tok_sds = S.decode_token_specs(cfg, shape, mesh, policy)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def decode_step(params, cache, tokens, pos):
            return T.lm_decode(params, cfg, tokens, cache, pos)

        jitted = jax.jit(decode_step, donate_argnums=(1,))
        with sh.activation_sharding(mesh):
            lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    if print_analysis:
        log.info("[%s x %s x %s] memory_analysis: %s",
                 arch, shape_name, mesh_name, mem)
        ca = RA.cost_analysis_dict(compiled)
        log.info("[%s x %s x %s] cost_analysis: %s",
                 arch, shape_name, mesh_name,
                 {k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    rep = RA.analyze(compiled, arch=arch, shape=shape_name,
                     mesh_name=mesh_name, chips=chips,
                     model_flops=RA.model_flops_estimate(cfg, shape))

    if mesh_name == "single":
        # --- depth-probe extrapolation for the roofline terms -----------
        # XLA:CPU cost_analysis counts a while-loop body ONCE regardless of
        # trip count, so the rolled full-depth compile above (which proves
        # compilation + gives the honest memory picture) undercounts FLOPs,
        # bytes and collective ops by ~G. Shallow UNROLLED probes give
        # exact per-group costs; extrapolation reconstructs full depth
        # (layers are depth-homogeneous in all 10 archs).
        g = T.group_size(cfg)
        G = T.num_groups(cfg)
        if not freeze_prefix:
            p1 = _probe_costs(arch, shape_name, cfg.replace(
                num_layers=g, scan_unroll=True), shape, mesh, policy,
                opt_cfg, 0, 0)
            p2 = _probe_costs(arch, shape_name, cfg.replace(
                num_layers=2 * g, scan_unroll=True), shape, mesh, policy,
                opt_cfg, 0, 0)
            per_group = {k: p2[k] - p1[k] for k in p1}
            outer = {k: p1[k] - per_group[k] for k in p1}
            tot = {k: outer[k] + G * per_group[k] for k in p1}
        else:
            # Frozen and active groups cost differently -> 3 probes:
            #   f21 = outer + fr + ac    (2 groups, first frozen)
            #   f41 = outer + fr + 3ac   (4 groups, first frozen)
            #   f42 = outer + 2fr + 2ac  (4 groups, first two frozen)
            # ac = (f41-f21)/2; fr = f42-f41+ac; outer = f21-fr-ac;
            # total = outer + k*fr + (G-k)*ac  with k = int(G*prefix).
            f21 = _probe_costs(arch, shape_name, cfg.replace(
                num_layers=2 * g, scan_unroll=True), shape, mesh, policy,
                opt_cfg, 1, 2)
            f41 = _probe_costs(arch, shape_name, cfg.replace(
                num_layers=4 * g, scan_unroll=True), shape, mesh, policy,
                opt_cfg, 1, 4)
            f42 = _probe_costs(arch, shape_name, cfg.replace(
                num_layers=4 * g, scan_unroll=True), shape, mesh, policy,
                opt_cfg, 2, 4)
            k_full = int(G * freeze_prefix)
            tot, per_group, outer = {}, {}, {}
            for key in f21:
                ac = (f41[key] - f21[key]) / 2.0
                fr = f42[key] - f41[key] + ac
                out_ = f21[key] - fr - ac
                tot[key] = out_ + k_full * fr + (G - k_full) * ac
                per_group[key] = ac
                outer[key] = out_
        rep.flops_per_chip = max(tot["flops"], 0.0)
        rep.bytes_per_chip = max(tot["bytes"], 0.0)
        rep.collective_bytes_per_chip = max(tot["coll"], 0.0)
        rep.finalize()
        record["probe_per_group"] = per_group
        record["probe_outer"] = outer

    record.update({"status": "ok", "lower_s": round(t_lower, 1),
                   "compile_s": round(t_compile, 1), **rep.to_dict()})
    return record


def _probe_costs(arch, shape_name, cfg, shape, mesh, policy, opt_cfg,
                 frozen_groups, total_groups=0):
    """Compile a shallow unrolled variant; return per-chip flops/bytes/
    collective bytes. `frozen_groups` freezes that many leading groups
    (+ the embedding) to probe frozen-group costs."""
    import jax
    import jax.numpy as jnp

    from repro.core.freeze_plan import FreezePlan
    from repro.distributed import sharding as sh
    from repro.launch import specs as S
    from repro.models import transformer as T
    from repro.optim import adamw_init, adamw_update
    from repro.optim.optimizer import AdamWState
    from repro.roofline import analysis as RA

    params_sds, param_spec = S.param_structs(cfg, mesh, policy)
    if shape.kind == "train":
        batch_sds = S.train_batch_specs(cfg, shape, mesh, policy)
        G = T.num_groups(cfg)
        k = frozen_groups
        plan = FreezePlan(groups=tuple(i < k for i in range(G)),
                          embed=k > 0) if k else None

        def step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: T.lm_loss(p, cfg, batch, plan), has_aux=True)(params)
            params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, loss

        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        opt_spec = AdamWState(step=jax.sharding.PartitionSpec(),
                              m=param_spec, v=param_spec)
        opt_sds = jax.tree.map(
            lambda s_, sp: jax.ShapeDtypeStruct(
                s_.shape, s_.dtype,
                sharding=jax.sharding.NamedSharding(mesh, sp)),
            opt_sds, opt_spec,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        with sh.activation_sharding(mesh):
            compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, batch_sds).compile()
    elif shape.kind == "prefill":
        batch_sds = S.prefill_batch_specs(cfg, shape, mesh, policy)
        with sh.activation_sharding(mesh):
            compiled = jax.jit(
                lambda p, b: T.lm_prefill(p, cfg, b)).lower(
                    params_sds, batch_sds).compile()
    else:
        cache_sds, _ = S.cache_structs(cfg, shape, mesh, policy)
        tok_sds = S.decode_token_specs(cfg, shape, mesh, policy)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        with sh.activation_sharding(mesh):
            compiled = jax.jit(
                lambda p, c, t, i: T.lm_decode(p, cfg, t, c, i),
                donate_argnums=(1,)).lower(
                    params_sds, cache_sds, tok_sds, pos_sds).compile()
    ca = RA.cost_analysis_dict(compiled)
    stats = RA.parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": stats.bytes_per_chip}


def save_record(record: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = cell_filename(record["arch"], record["shape"], record["mesh"],
                         record.get("tag", ""))
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return path


def orchestrate(mesh_modes, archs=None, shapes=None, timeout=2400,
                tag="", extra_args=()):
    from repro.configs import ARCHS, LM_SHAPES

    archs = archs or list(ARCHS)
    shapes = shapes or [s.name for s in LM_SHAPES]
    failures = []
    for mesh_name in mesh_modes:
        for arch in archs:
            for shape in shapes:
                out = cell_filename(arch, shape, mesh_name, tag)
                if os.path.exists(out):
                    log.info("skip existing %s", out)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_name,
                       "--save"] + list(extra_args)
                if tag:
                    cmd += ["--tag", tag]
                log.info(">> %s", " ".join(cmd))
                try:
                    r = subprocess.run(cmd, timeout=timeout)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_name, r.returncode))
                except subprocess.TimeoutExpired:
                    failures.append((arch, shape, mesh_name, "timeout"))
    if failures:
        log.error("FAILURES: %s", failures)
        return 1
    log.info("all cells complete")
    return 0


def main():
    configure_logging(os.environ.get("EDGEOL_LOG") or "INFO")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--freeze-prefix", type=float, default=0.0)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    if args.all:
        modes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        extra = []
        if args.no_fsdp:
            extra.append("--no-fsdp")
        if args.remat:
            extra += ["--remat", args.remat]
        if args.freeze_prefix:
            extra += ["--freeze-prefix", str(args.freeze_prefix)]
        sys.exit(orchestrate(modes, timeout=args.timeout, tag=args.tag,
                             extra_args=extra))

    try:
        record = run_cell(args.arch, args.shape, args.mesh,
                          fsdp=not args.no_fsdp,
                          freeze_prefix=args.freeze_prefix,
                          remat=args.remat, tag=args.tag)
    except Exception as e:
        record = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:], "tag": args.tag}
        if args.save:
            save_record(record)
        # the JSON record is the worker's machine-readable stdout
        # contract; diagnostics go through the logger (stderr)
        sys.stdout.write(json.dumps(
            {k: v for k, v in record.items() if k != "traceback"},
            indent=1) + "\n")
        log.error("cell failed:\n%s", record["traceback"])
        sys.exit(2)
    if args.save:
        path = save_record(record)
        log.info("saved %s", path)
    sys.stdout.write(json.dumps(record, indent=1) + "\n")


if __name__ == "__main__":
    main()
