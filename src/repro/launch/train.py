"""Production continual fine-tuning driver: the ETuner loop running on a
device mesh with sharded params, freeze-plan recompile caching, gradient
sync and crash-safe checkpointing. On this CPU container it runs a reduced
arch on a small host mesh; on a real fleet the same code takes the
production mesh from launch/mesh.py.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 60
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_reduced
from repro.core.freeze_plan import FreezePlan
from repro.distributed import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.obs.log import configure_logging, get_logger
from repro.optim import AdamWConfig, adamw_init, adamw_update

log = get_logger("launch.train")


def main():
    # a CLI driver wants its progress visible by default; EDGEOL_LOG
    # still wins when set (e.g. EDGEOL_LOG=WARNING for quiet runs)
    configure_logging(os.environ.get("EDGEOL_LOG") or "INFO")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--freeze-at", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    log.info("mesh: %s devices=%d", dict(mesh.shape), mesh.devices.size)

    params = model.init(jax.random.PRNGKey(0))
    specs = sh.param_specs(params, cfg, mesh)
    params = jax.device_put(params, sh.named(mesh, specs))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params, opt_cfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    cache = {}

    def get_step(plan):
        if plan not in cache:
            def step(p, o, b):
                (l, _), g = jax.value_and_grad(
                    lambda q: model.loss(q, b, plan), has_aux=True)(p)
                p, o = adamw_update(g, o, p, opt_cfg)
                return p, o, l
            cache[plan] = jax.jit(step, donate_argnums=(0, 1))
        return cache[plan]

    rng = np.random.default_rng(0)
    plan = None
    t0 = time.time()
    with sh.activation_sharding(mesh):
        for step_i in range(args.steps):
            if step_i == args.freeze_at:
                G = model.num_freeze_units
                plan = FreezePlan(groups=tuple(i < G // 2 for i in range(G)),
                                  embed=True)
                log.info("step %d: structural freeze of %d/%d groups",
                         step_i, G // 2, G)
            toks = rng.integers(0, cfg.vocab_size, (args.batch, args.seq + 1))
            batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                     "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
            if cfg.frontend != "none":
                batch["frontend_embeds"] = jnp.zeros(
                    (args.batch, cfg.frontend_tokens, cfg.frontend_dim),
                    jnp.bfloat16)
            params, opt_state, loss = get_step(plan)(params, opt_state, batch)
            if step_i % 10 == 0:
                log.info("step %3d loss=%.4f", step_i, float(loss))
            if step_i % 25 == 24:
                mgr.save(step_i, params)
    mgr.save(args.steps - 1, params, block=True)
    log.info("done in %.1fs; ckpts at %s", time.time() - t0, args.ckpt_dir)


if __name__ == "__main__":
    main()
