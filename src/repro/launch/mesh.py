"""Production meshes. Functions, not module-level constants — importing
this module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count before first jax init)."""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax >= 0.5 takes axis_types; 0.4.x predates AxisType entirely.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    return _mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh for tests on the default host device count."""
    n = len(jax.devices())
    data = min(data, max(n // model, 1))
    if data * model > n:
        model = n // data
    return make_mesh((data, model), ("data", "model"))
