"""Named workload presets — the workload axis the benchmark sweep (and any
future PR) runs controllers against.

Each preset is a `WorkloadSpec` builder parameterized by scale knobs so the
same shapes serve both the CI quick sweep and full local runs. The mix
covers the regimes the paper's single Poisson timeline cannot express:
multi-stream contention, staggered drift, MMPP bursts, diurnal + duty-
cycle capture, and a heterogeneous two-benchmark mix.

The 'mixed' preset is a faithful CV+NLP mix: its NLP stream binds
(`modality="nlp"`, `benchmark="20news"`) to a real BERT model slot in a
`ModelPool` runtime — both modalities fine-tune and serve on the one
shared device under its memory budget (DESIGN.md §9). The trace arrival
process mimics the bursty VTT query pattern of paper §V-D.
"""
from __future__ import annotations

from typing import Dict

from repro.workloads.spec import (DiurnalConfig, DutyCycle, MMPPConfig,
                                  StreamSpec, WorkloadSpec)


def presets(*, batches_per_scenario: int = 8, inferences: int = 24,
            num_scenarios: int = 3, scenario_span: float = 100.0,
            seed: int = 0,
            fleet_streams: int = 120) -> Dict[str, WorkloadSpec]:
    """The standard preset set, scaled by the given knobs.
    `fleet_streams` sizes only the `fleet` preset (the DeviceFleet cell,
    DESIGN.md §13): hundreds of light camera streams by default, scaled
    down to a handful for the CI quick sweep."""
    def cv(**kw) -> StreamSpec:
        base = dict(modality="cv", benchmark="nc",
                    batches_per_scenario=batches_per_scenario,
                    inferences=inferences)
        base.update(kw)
        return StreamSpec(**base)

    geom = dict(num_scenarios=num_scenarios, scenario_span=scenario_span,
                seed=seed)
    specs = [
        # the paper's own setting, expressed as a workload (baseline cell)
        WorkloadSpec("single-poisson", (cv(),), **geom),
        # two cameras sharing one device; drift reaches them staggered
        WorkloadSpec("two-stream", (cv(), cv(benchmark="ni")),
                     drift="staggered", **geom),
        # motion-triggered capture: MMPP bursts on both batches + queries
        WorkloadSpec("bursty-mmpp",
                     (cv(data_dist="mmpp", inf_dist="mmpp",
                         mmpp=MMPPConfig(burst_mult=6.0, idle_mult=0.2,
                                         mean_dwell=scenario_span / 4)),),
                     **geom),
        # day/night query curve + duty-cycled capture windows
        WorkloadSpec("diurnal-duty",
                     (cv(inf_dist="diurnal",
                         diurnal=DiurnalConfig(period=scenario_span,
                                               amplitude=0.8),
                         duty_cycle=DutyCycle(period=scenario_span / 2,
                                              on_fraction=0.6)),),
                     **geom),
        # heterogeneous modality mix: steady CV stream + a real NLP
        # stream (BERT on the 20News-style token benchmark, bursty trace
        # arrivals) — one model slot per modality, one shared device
        WorkloadSpec("mixed",
                     (cv(),
                      cv(modality="nlp", benchmark="20news",
                         data_dist="trace", inf_dist="trace",
                         inferences=max(inferences // 2, 4),
                         phase=scenario_span / 7)),
                     **geom),
        # QoS: a latency-critical query stream (high priority, few
        # training batches, many requests) sharing the device with a bulk
        # tuning stream (priority 0, heavy batch load — its rounds keep
        # the device busy, which is exactly what preemption must cut
        # through). The sweep runs this preset with preemption off and on
        # and reports per-stream p50/p95 serving latency.
        WorkloadSpec("qos",
                     (cv(priority=2, inferences=inferences * 2,
                         batches_per_scenario=max(
                             batches_per_scenario // 2, 2)),
                      cv(benchmark="ni", priority=0,
                         batches_per_scenario=batches_per_scenario * 2,
                         inferences=max(inferences // 2, 4))),
                     **geom),
        # adversarial flash crowd: four cameras replaying the SAME
        # recorded trace — a long quiet stretch, then a dense burst
        # hitting every stream at the same instant (a stadium goal, a
        # doorbell storm). 'trace-replay' honors the recorded gaps
        # verbatim (no window rescale), so the burst stays exactly as
        # tight as recorded no matter the scale knobs — the worst case
        # for triggers, serving latency and (with env enabled) thermal
        # headroom.
        WorkloadSpec("flash-crowd",
                     tuple(cv(benchmark="ni" if i % 2 else "nc",
                              data_dist="trace-replay",
                              inf_dist="trace-replay",
                              trace=(scenario_span * 0.55,)
                              + (scenario_span / 200.0,) * 23)
                           for i in range(4)),
                     **geom),
        # DeviceFleet cell (DESIGN.md §13): a whole fleet of light camera
        # streams — each a fraction of the single-device load, phased so
        # arrivals spread over the scenario span — routed across tens of
        # devices by the runtime's `RuntimeConfig.devices` axis. Every
        # fourth stream is latency-critical (priority 1) so the routing
        # policies have asymmetry to work with; drift is staggered like a
        # rolling multi-camera deployment.
        WorkloadSpec("fleet",
                     tuple(cv(benchmark="ni" if i % 3 == 2 else "nc",
                              batches_per_scenario=max(
                                  batches_per_scenario // 2, 2),
                              inferences=max(inferences // 4, 3),
                              priority=1 if i % 4 == 3 else 0,
                              phase=(i % 8) * scenario_span / 8.0)
                           for i in range(fleet_streams)),
                     drift="staggered", **geom),
    ]
    return {s.validate().name: s for s in specs}


WORKLOADS: Dict[str, WorkloadSpec] = presets()
