"""Declarative multi-stream workloads (DESIGN.md §7): `WorkloadSpec` +
generators that compile arrival processes (Poisson / uniform / normal /
trace / MMPP / diurnal, with duty-cycle windows and staggered drift) down
to the multi-stream `Event` timeline the `EventScheduler` replays."""
from repro.workloads.generators import compile_workload, stream_events
from repro.workloads.presets import WORKLOADS, presets
from repro.workloads.spec import (ARRIVAL_DISTS, DRIFT_SCHEDULES,
                                  DiurnalConfig, DutyCycle, MMPPConfig,
                                  StreamSpec, WorkloadSpec)

__all__ = [
    "ARRIVAL_DISTS", "DRIFT_SCHEDULES", "DiurnalConfig", "DutyCycle",
    "MMPPConfig", "StreamSpec", "WorkloadSpec", "WORKLOADS",
    "compile_workload", "presets", "stream_events",
]
