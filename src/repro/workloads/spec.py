"""Declarative workload specifications.

EdgeOL's premise is that fine-tuning and serving contend for one device
under realistic arrival patterns (§V-A Poisson arrivals, §V-D sensitivity
to uniform / normal / real-world-trace). A `WorkloadSpec` makes that axis
declarative: it names the arrival process *per stream*, the drift
(scenario) schedule, device duty-cycle windows and the stream mix, and
compiles (repro.workloads.generators) down to the `Event` timeline the
`EventScheduler` replays. Everything is a frozen dataclass so specs are
hashable, comparable and trivially serializable for benchmark manifests.

Stream semantics: a stream is one independent arrival source (a camera, a
sensor, an app's query flow). Streams share the device (`busy_until`) and
the model parameters; scenario drift, controller signals and cost
attribution are tracked per stream (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

ARRIVAL_DISTS = ("poisson", "uniform", "normal", "trace", "mmpp", "diurnal",
                 "trace-replay")
DRIFT_SCHEDULES = ("aligned", "staggered")


@dataclass(frozen=True)
class MMPPConfig:
    """2-state Markov-modulated Poisson process: a bursty arrival pattern
    (dense bursts separated by quiet stretches — the capture pattern of
    motion-triggered edge cameras). The process alternates between a
    *burst* state and an *idle* state; each state holds for an
    exponentially distributed dwell time and scales the base arrival rate
    by its multiplier."""
    burst_mult: float = 6.0
    idle_mult: float = 0.25
    mean_dwell: float = 25.0  # mean sojourn per state, timeline seconds


@dataclass(frozen=True)
class DiurnalConfig:
    """Sinusoidal rate modulation — a smooth day/night load curve. The
    instantaneous rate swings between ``(1-amplitude)`` and
    ``(1+amplitude)`` times the base rate over one `period`."""
    period: float = 120.0
    amplitude: float = 0.8


@dataclass(frozen=True)
class DutyCycle:
    """Hard on/off capture windows (duty-cycled devices: the stream emits
    only during the first ``on_fraction`` of every ``period``)."""
    period: float = 50.0
    on_fraction: float = 0.5


@dataclass(frozen=True)
class StreamSpec:
    """One arrival source. `benchmark` binds the stream to a continual-
    learning data stream (repro.data.streams.REGISTRY) when a spec is
    materialized by the benchmark harness; the arrival fields shape *when*
    its batches and requests land. `modality` names the stream's **model
    slot**: `compile_workload` stamps it on every event the stream emits,
    and a `ModelPool` runtime (DESIGN.md §9) resolves each event to the
    slot of that name — so a 'cv' and an 'nlp' stream really train and
    serve different models on the one shared device."""
    modality: str = "cv"              # model-slot key ('cv' | 'nlp' | ...)
    benchmark: str = "nc"             # repro.data.streams.REGISTRY key
    data_dist: str = "poisson"        # one of ARRIVAL_DISTS
    inf_dist: str = "poisson"
    batches_per_scenario: int = 8
    inferences: int = 24              # requests over the whole horizon
    phase: float = 0.0                # wall-clock offset of this stream
    # QoS priority (higher = more latency-critical): rides on every event
    # the stream emits; at equal timestamps higher-priority events
    # dispatch first, and when the runtime runs `preemptible=True` the
    # stream's inference arrivals split in-flight fine-tuning rounds of
    # strictly lower-priority streams. 0 = bulk / best-effort.
    priority: int = 0
    mmpp: Optional[MMPPConfig] = None
    diurnal: Optional[DiurnalConfig] = None
    duty_cycle: Optional[DutyCycle] = None
    # Recorded inter-arrival gaps (seconds) for the 'trace-replay'
    # distribution: consumed verbatim — tiled when the event count
    # outruns the recording, never rescaled to the window, so the
    # recorded burst geometry survives every scale knob. Empty falls
    # back to `repro.data.arrivals._DEFAULT_TRACE` (the VTT-style
    # bursty stand-in). Contrast with 'trace', which *resamples* the
    # same recording normalized to the requested mean rate.
    trace: Tuple[float, ...] = ()


@dataclass(frozen=True)
class WorkloadSpec:
    """A full workload: stream mix + drift schedule + horizon geometry.

    - `num_scenarios` counts *tuning* scenarios; the harness maps them to
      benchmark scenarios 1..num_scenarios (scenario 0 pretrains).
    - `drift`: 'aligned' — every stream crosses scenario boundaries at the
      same wall-clock; 'staggered' — stream i's boundaries are offset by
      ``i/len(streams)`` of a scenario span, so drift hits streams at
      different times (the multi-camera rollout case).
    """
    name: str
    streams: Tuple[StreamSpec, ...]
    num_scenarios: int = 3
    scenario_span: float = 100.0
    drift: str = "aligned"
    seed: int = 0

    def validate(self) -> "WorkloadSpec":
        if not self.streams:
            raise ValueError(f"workload {self.name!r}: needs >= 1 stream")
        if self.num_scenarios < 1 or self.scenario_span <= 0:
            raise ValueError(f"workload {self.name!r}: bad horizon geometry")
        if self.drift not in DRIFT_SCHEDULES:
            raise ValueError(f"workload {self.name!r}: drift {self.drift!r} "
                             f"not in {DRIFT_SCHEDULES}")
        for i, s in enumerate(self.streams):
            if not isinstance(s.modality, str) or not s.modality:
                raise ValueError(
                    f"workload {self.name!r} stream {i}: modality must be "
                    f"a non-empty model-slot key (got {s.modality!r})")
            if not isinstance(s.priority, int) or s.priority < 0:
                raise ValueError(
                    f"workload {self.name!r} stream {i}: priority must be "
                    f"a non-negative int (got {s.priority!r})")
            for d in (s.data_dist, s.inf_dist):
                if d not in ARRIVAL_DISTS:
                    raise ValueError(
                        f"workload {self.name!r} stream {i}: arrival "
                        f"{d!r} not in {ARRIVAL_DISTS}")
            if "mmpp" in (s.data_dist, s.inf_dist):
                m = s.mmpp
                if m is None:
                    raise ValueError(
                        f"workload {self.name!r} stream {i}: 'mmpp' "
                        f"arrivals need an MMPPConfig")
                if m.burst_mult <= 0 or m.idle_mult <= 0 or m.mean_dwell <= 0:
                    raise ValueError(
                        f"workload {self.name!r} stream {i}: MMPP "
                        f"multipliers and dwell must be positive")
            if "diurnal" in (s.data_dist, s.inf_dist):
                d = s.diurnal
                if d is None:
                    raise ValueError(
                        f"workload {self.name!r} stream {i}: 'diurnal' "
                        f"arrivals need a DiurnalConfig")
                # amplitude > 1 makes the NHPP rate negative and its
                # cumulative integral non-monotone (inversion breaks)
                if not (0.0 <= d.amplitude <= 1.0) or d.period <= 0:
                    raise ValueError(
                        f"workload {self.name!r} stream {i}: diurnal "
                        f"amplitude must be in [0, 1] and period > 0")
            if s.duty_cycle is not None and not (
                    0 < s.duty_cycle.on_fraction <= 1):
                raise ValueError(
                    f"workload {self.name!r} stream {i}: on_fraction "
                    f"must be in (0, 1]")
            if "trace-replay" in (s.data_dist, s.inf_dist):
                for g in s.trace:
                    if not (isinstance(g, (int, float))
                            and math.isfinite(g) and g > 0):
                        raise ValueError(
                            f"workload {self.name!r} stream {i}: "
                            f"trace-replay gaps must be positive finite "
                            f"seconds (got {g!r})")
        return self

    @property
    def horizon(self) -> float:
        return self.num_scenarios * self.scenario_span

    @property
    def modalities(self) -> Tuple[str, ...]:
        """Distinct model-slot keys, in first-stream order — the slots a
        `ModelPool` must provide to run this workload. A single-entry
        result means the workload runs on the plain single-model path."""
        seen = []
        for s in self.streams:
            if s.modality not in seen:
                seen.append(s.modality)
        return tuple(seen)

    def stream_offset(self, stream: int) -> float:
        """Wall-clock offset of `stream`'s scenario boundaries."""
        if self.drift == "staggered" and len(self.streams) > 1:
            return self.scenario_span * stream / len(self.streams)
        return 0.0

    def describe(self) -> Dict:
        """JSON-ready summary used by benchmark manifests."""
        return {
            "name": self.name, "num_streams": len(self.streams),
            "num_scenarios": self.num_scenarios,
            "scenario_span": self.scenario_span, "drift": self.drift,
            "seed": self.seed,
            "streams": [dataclasses.asdict(s) for s in self.streams],
        }
