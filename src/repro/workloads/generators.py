"""Compile a `WorkloadSpec` down to the `Event` timeline.

The output is a plain, time-sorted ``List[Event]`` — exactly what
`EventScheduler` replays — with each event tagged by its arrival stream
and its stream's model-slot `modality` (the binding a `ModelPool` runtime
resolves to decide *which* model an event trains or serves).
Generation is **bit-reproducible**: every stream draws from its own
`np.random.Generator` seeded by ``(spec.seed, stream_index)``, so the
compiled timeline is a pure function of the spec and independent of
iteration order (a regression test pins this down).

Arrival processes: the four paper distributions (poisson / uniform /
normal / trace) are delegated to `repro.data.arrivals.interarrivals`; on
top of those this module adds the modulated processes a single-stream
timeline cannot express — 2-state MMPP bursts and diurnal (sinusoidal)
rate curves — plus hard duty-cycle on/off windows applied as a time-warp.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.arrivals import (_DEFAULT_TRACE, KIND_ORDER, Event,
                                 interarrivals)
from repro.workloads.spec import StreamSpec, WorkloadSpec


# ---------------------------------------------------------------------------
# modulated inter-arrival processes


def _mmpp_gaps(n: int, mean_gap: float, rng: np.random.Generator,
               cfg) -> np.ndarray:
    """2-state Markov-modulated Poisson: exponential gaps whose rate is
    scaled by the current state's multiplier; states hold for exponential
    dwell times. Normalized so the expected gap stays ~`mean_gap`."""
    if n <= 0:
        return np.zeros(0)
    # normalize the two multipliers so the *time-averaged* rate matches
    # the base rate (each state occupies half the time in expectation)
    scale = 2.0 / (cfg.burst_mult + cfg.idle_mult)
    mults = (cfg.burst_mult * scale, cfg.idle_mult * scale)
    base_rate = 1.0 / mean_gap
    state = int(rng.integers(2))          # 0 = burst, 1 = idle
    dwell_left = rng.exponential(cfg.mean_dwell)
    gaps = np.empty(n)
    for i in range(n):
        # time-change construction: each event needs Exp(1) of intensity
        # mass; the current state supplies it at base_rate * multiplier
        u = rng.exponential(1.0)
        gap = 0.0
        while True:
            rate = base_rate * mults[state]
            if u <= rate * dwell_left:
                gap += u / rate
                dwell_left -= u / rate
                break
            u -= rate * dwell_left
            gap += dwell_left
            state = 1 - state
            dwell_left = rng.exponential(cfg.mean_dwell)
        gaps[i] = gap
    return gaps


def _diurnal_times(n: int, horizon: float, rng: np.random.Generator,
                   cfg, duty=None) -> np.ndarray:
    """Non-homogeneous Poisson with rate(t) ∝ 1 + a·sin(2πt/period) on
    **wall-clock** time, realized by inverting the cumulative rate Λ(t)
    on a dense grid (standard NHPP time-change construction). A duty
    cycle composes as a multiplicative on/off indicator in the same rate
    function, so the configured diurnal period is never distorted and no
    arrival lands in an off-window."""
    if n <= 0:
        return np.zeros(0)
    grid = np.linspace(0.0, horizon, max(int(horizon * 8), 256))
    if duty is not None:
        # snap the grid to the on/off edges so every integration cell lies
        # entirely inside one window — the midpoint test below is then
        # exact and no inverted arrival can straddle a boundary
        starts = np.arange(0.0, horizon + duty.period, duty.period)
        edges = np.concatenate(
            [starts, starts + duty.period * duty.on_fraction])
        edges = edges[(edges > 0.0) & (edges < horizon)]
        grid = np.unique(np.concatenate([grid, edges]))
    rate = 1.0 + cfg.amplitude * np.sin(2 * np.pi * grid / cfg.period)
    seg = 0.5 * (rate[1:] + rate[:-1]) * np.diff(grid)
    if duty is not None:
        mid = 0.5 * (grid[1:] + grid[:-1])
        seg = seg * (mid % duty.period < duty.period * duty.on_fraction)
    lam = np.concatenate([[0.0], np.cumsum(seg)])
    # n homogeneous arrivals on [0, Λ(horizon)] -> warp back through Λ⁻¹
    u = np.sort(rng.uniform(0.0, lam[-1], n))
    return np.interp(u, lam, grid)


def _duty_cycle_warp(times: np.ndarray, cfg) -> np.ndarray:
    """Map 'active time' to wall-clock: each period contributes only its
    first ``on_fraction`` as live capture time, so arrivals generated on
    the compressed active axis land inside the on-windows."""
    on = cfg.period * cfg.on_fraction
    cycles = np.floor(times / on)
    return cycles * cfg.period + (times - cycles * on)


# ---------------------------------------------------------------------------
# per-stream event generation


def _arrival_times(dist: str, n: int, window: float,
                   rng: np.random.Generator, s: StreamSpec) -> np.ndarray:
    """`n` arrival times in [0, `window`) of **wall-clock** time, by
    distribution, honoring the stream's duty cycle. Diurnal composes the
    duty windows directly into its NHPP rate; the gap-based processes are
    generated on the duty-compressed active-time axis and warped back, so
    every arrival lands inside an on-window either way."""
    if n <= 0:
        return np.zeros(0)
    if dist == "diurnal":
        return _diurnal_times(n, window, rng, s.diurnal, s.duty_cycle)
    active = window * (s.duty_cycle.on_fraction if s.duty_cycle else 1.0)
    if dist == "trace-replay":
        # recorded-timestamp replay: consume the stream's recorded gaps
        # verbatim (tiled when n outruns the recording, falling back to
        # the module's VTT-style default trace) — deliberately NOT
        # rescaled into the window, so the recorded burst geometry
        # survives every scale knob; only the duty warp applies, like
        # any other gap-based process. Identical traces across streams
        # give perfectly correlated arrivals (the flash-crowd preset).
        gaps = np.asarray(s.trace if len(s.trace) else _DEFAULT_TRACE,
                          np.float64)
        t = np.cumsum(np.tile(gaps, int(np.ceil(n / gaps.size)))[:n])
        if s.duty_cycle is not None:
            t = _duty_cycle_warp(np.minimum(t, active - 1e-6),
                                 s.duty_cycle)
        return t
    if dist == "mmpp":
        t = np.cumsum(_mmpp_gaps(n, active / n, rng, s.mmpp))
    else:
        t = np.cumsum(interarrivals(dist, n, active / n, rng))
    # scale into the window (build_timeline does the same for inference
    # arrivals) so every spec'd event lands inside the horizon; clamp
    # strictly below the active span *before* warping — an arrival pinned
    # exactly to the end of active time would otherwise warp onto the
    # next period's off-boundary
    t = t * (active / max(t[-1], 1e-9))
    if s.duty_cycle is not None:
        t = _duty_cycle_warp(np.minimum(t, active - 1e-6), s.duty_cycle)
    return t


def stream_events(spec: WorkloadSpec, stream: int,
                  first_scenario: int = 1) -> List[Event]:
    """All events of one stream, un-merged. Scenario ids run
    ``first_scenario .. first_scenario + num_scenarios - 1`` (the runtime
    reserves benchmark scenario 0 for pretraining)."""
    s = spec.streams[stream]
    rng = np.random.default_rng([spec.seed, stream])
    offset = spec.stream_offset(stream) + s.phase
    span, horizon = spec.scenario_span, spec.horizon
    events: List[Event] = []
    # -- training-data batches: per scenario, inside its window ------------
    # (duty-cycle phase is anchored to each generation window's start —
    # coincident with the wall-clock duty grid whenever scenario_span is a
    # whole number of duty periods, as in the presets)
    for sc in range(spec.num_scenarios):
        t = _arrival_times(s.data_dist, s.batches_per_scenario, span * 0.9,
                           rng, s)
        t = offset + sc * span + np.minimum(t, span - 1e-3)
        for i, ti in enumerate(t):
            events.append(Event(float(ti), "data", first_scenario + sc, i,
                                stream=stream, priority=s.priority,
                                modality=s.modality))
    # -- inference requests: over the whole horizon ------------------------
    t = _arrival_times(s.inf_dist, s.inferences, horizon, rng, s)
    t = offset + np.minimum(t, horizon - 1e-3)
    for i, ti in enumerate(t):
        sc = min(int((ti - offset) // span), spec.num_scenarios - 1)
        events.append(Event(float(ti), "inference", first_scenario + sc, i,
                            stream=stream, priority=s.priority,
                            modality=s.modality))
    return events


def compile_workload(spec: WorkloadSpec,
                     first_scenario: int = 1) -> List[Event]:
    """Merged, time-sorted multi-stream timeline for `spec`. Ties break
    (kind: data first, then higher priority, then stream, then index) — a
    total order matching `EventScheduler`'s heap key, so the compiled
    timeline is deterministic given the spec and replays in exactly its
    constructed order."""
    spec.validate()
    events: List[Event] = []
    for stream in range(len(spec.streams)):
        events.extend(stream_events(spec, stream, first_scenario))
    events.sort(key=lambda e: (e.time, KIND_ORDER.get(e.kind, 2),
                               -e.priority, e.stream, e.index))
    return events
