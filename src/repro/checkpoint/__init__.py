from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager

__all__ = ["ckpt", "CheckpointManager"]
