"""CheckpointManager: rotation, integrity-checked restore-latest, and
restart-after-failure semantics for the continual-learning runtime.

A fine-tuning round on the cluster is: restore -> (re)compile -> steps ->
save. LazyTune reduces how often this whole cycle runs; the manager makes
each cycle crash-safe: a host failure mid-save leaves the previous valid
checkpoint in place (atomic rename + checksums), and `restore_latest`
skips any checkpoint that fails validation."""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, List, Optional, Tuple

from repro.checkpoint import ckpt


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, use_async: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async = ckpt.AsyncCheckpointer() if use_async else None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:010d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def save(self, step: int, tree, extra: Optional[dict] = None,
             block: bool = False) -> str:
        path = self._path(step)
        if self._async is not None:
            self._async.save(path, tree, step, extra)
            if block:
                self._async.wait()
        else:
            ckpt.save(path, tree, step, extra)
        self._gc()
        return path

    def wait(self) -> None:
        if self._async is not None:
            self._async.wait()

    def restore_latest(self, like, shardings=None) -> Tuple[Optional[Any], int]:
        """Newest *valid* checkpoint, skipping corrupt ones. (None, -1) if
        nothing restorable — the caller falls back to fresh init."""
        self.wait()
        for step in reversed(self.all_steps()):
            path = self._path(step)
            if ckpt.validate(path):
                tree, s = ckpt.restore(path, like, shardings=shardings)
                return tree, s
        return None, -1

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)
