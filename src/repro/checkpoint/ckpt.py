"""Fault-tolerant checkpointing.

- atomic: writes go to a temp dir, fsync'd, then renamed; a manifest with
  per-leaf checksums validates integrity on restore (torn writes from a
  preempted host are detected and the checkpoint is skipped).
- sharded: each host saves only the shards it owns (`save_sharded`);
  restore reassembles on any mesh ("elastic": target mesh may differ from
  the source mesh — leaves are saved unsharded per-shard with index
  metadata and re-sharded on load).
- async: `AsyncCheckpointer` copies device arrays to host then writes on a
  background thread so the training loop is blocked only for the
  device->host copy.

Format: one ``.npz`` per payload + ``manifest.json`` (pytree structure,
shapes, dtypes, checksums, step). No external deps.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _tree_flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def _checksum(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save(path: str, tree, step: int = 0, extra: Optional[dict] = None) -> None:
    """Atomic full-tree save (gathered to host)."""
    named = _tree_flatten_with_names(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "format": "full", "treedef": None}
    for i, (name, leaf) in enumerate(named):
        a = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i}"
        arrays[key] = a
        manifest["leaves"][key] = {"name": name, "shape": list(a.shape),
                                   "dtype": str(a.dtype), "sum": _checksum(a)}
    tmp = tempfile.mkdtemp(dir=os.path.dirname(os.path.abspath(path)) or ".")
    try:
        np.savez(os.path.join(tmp, "data.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def validate(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "data.npz")) as data:
            for key, meta in manifest["leaves"].items():
                a = data[key]
                if list(a.shape) != meta["shape"] or _checksum(a) != meta["sum"]:
                    return False
        return True
    except Exception:
        return False


def restore(path: str, like, mesh=None, shardings=None):
    """Restore into the structure of `like`. If `shardings` (a pytree of
    NamedSharding matching `like`) is given, leaves are placed sharded —
    this is the elastic path: the target mesh may have any shape/size."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "data.npz")) as data:
        arrays = [data[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(arrays) == len(leaves_like), \
        f"checkpoint has {len(arrays)} leaves, expected {len(leaves_like)}"
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [np.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays), manifest["step"]


def load_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["manifest" if False else "step"]


class AsyncCheckpointer:
    """Device->host copy on the caller thread; disk write on a worker
    thread. `wait()` joins the in-flight write (call before exit and before
    starting a save to the same path)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, path: str, tree, step: int = 0,
             extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            try:
                save(path, host_tree, step, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
