"""ETunerController — composes LazyTune (inter-tuning), SimFreeze
(intra-tuning) and the energy-score scenario detector into one event-driven
policy object consumed by runtime/continual.py (Algorithm 1 of the paper).

Ablation switches make the controller cover all four paper configurations:
  Immed.    = ETunerController(lazytune=False, simfreeze=False)
  LazyTune  = ETunerController(lazytune=True,  simfreeze=False)
  SimFreeze = ETunerController(lazytune=False, simfreeze=True)
  ETuner    = ETunerController(lazytune=True,  simfreeze=True)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.freeze_plan import LayerFreezePlan, all_active
from repro.core.lazytune import LazyTune, LazyTuneConfig
from repro.core.ood import EnergyOODConfig, EnergyOODDetector
from repro.core.simfreeze import SimFreeze, SimFreezeConfig


@runtime_checkable
class ControllerProtocol(Protocol):
    """The contract every scheduling policy implements (DESIGN.md §2).

    Controllers are *driven* by the runtime's event loop — they never see
    the `EventScheduler` or executor internals. The runtime calls, in
    event order:

    - `plan` (property): the current freeze plan — a hashable static jit
      argument; a changed plan implies a recompile charge.
    - `should_trigger(batches_available, staleness=0.0)`: called on every
      buffered data batch; return True to launch a fine-tuning round now
      (the runtime additionally requires the device to be idle).
      `staleness` is the wall-clock seconds since *this stream's* last
      round completed (run start counts as fresh) — a QoS-aware policy
      can use it to keep low-priority streams from starving while a
      latency-critical stream's arrivals keep winning the device.
    - `round_finished(iters, val_acc, params)`: after each round, with the
      number of iterations run, validation accuracy, and the new params.
    - `inference_served(logits)`: after each served request, with that
      request's logits; return True to signal a detected scenario change
      (only honored when the runtime runs with boundaries='detector').
    - `scenario_changed(params, probe_batch)`: at an oracle scenario
      boundary or a detector-confirmed change.
    - `start_scenario(reference_params, probe_batch)` (optional): offered
      once per scenario to controllers that track reference-model
      similarity; gate with a `needs_reference` attribute.
    - `stats()` (optional): a dict folded into `RunResult.controller_stats`.
    """

    @property
    def plan(self) -> Any: ...

    def should_trigger(self, batches_available: int,
                       staleness: float = 0.0) -> bool: ...

    def round_finished(self, iters: int, val_acc: float, params) -> None: ...

    def inference_served(self, logits) -> bool: ...

    def scenario_changed(self, params, probe_batch) -> None: ...


@dataclass
class ETunerConfig:
    lazytune: bool = True
    simfreeze: bool = True
    detect_scenario_changes: bool = True
    lazytune_cfg: LazyTuneConfig = field(default_factory=LazyTuneConfig)
    simfreeze_cfg: SimFreezeConfig = field(default_factory=SimFreezeConfig)
    ood_cfg: EnergyOODConfig = field(default_factory=EnergyOODConfig)
    # QoS starvation guard: trigger a round regardless of LazyTune's
    # accumulation target once this stream has gone `max_staleness`
    # timeline-seconds without one (None = disabled, the paper behaviour)
    max_staleness: Optional[float] = None


class ETunerController:
    def __init__(self, model, config: ETunerConfig = ETunerConfig()):
        self.cfg = config
        self.model = model
        self.lazytune = LazyTune(config.lazytune_cfg)
        scan_mode = getattr(model.cfg, "is_lm", False) and model.cfg.scan_layers
        self.simfreeze = SimFreeze(model.num_freeze_units, model.features,
                                   config.simfreeze_cfg, scan_mode=scan_mode)
        self.detector = EnergyOODDetector(config.ood_cfg)
        self._plan = self._empty_plan()
        self.plan_changes = 0

    def _empty_plan(self):
        if self.simfreeze.scan_mode:
            return all_active(self.model.num_freeze_units)
        return LayerFreezePlan(layers=(False,) * self.model.num_freeze_units)

    # ---- plan (a hashable static jit arg; a change implies a recompile) ----
    @property
    def plan(self):
        return self._plan

    def _refresh_plan(self) -> None:
        new = self.simfreeze.plan() if self.cfg.simfreeze else self._empty_plan()
        if new != self._plan:
            self.plan_changes += 1
        self._plan = new

    # ---- events -------------------------------------------------------------
    def start_scenario(self, reference_params, probe_batch) -> None:
        if self.cfg.simfreeze:
            self.simfreeze.start_scenario(reference_params, probe_batch)

    def should_trigger(self, batches_available: int,
                       staleness: float = 0.0) -> bool:
        if self.cfg.max_staleness is not None and batches_available \
                and staleness >= self.cfg.max_staleness:
            return True  # starvation guard (QoS; DESIGN.md §8)
        if not self.cfg.lazytune:
            return batches_available >= 1  # immediate fine-tuning
        return self.lazytune.should_trigger(batches_available)

    def round_finished(self, iters: int, val_acc: float, params) -> None:
        if self.cfg.lazytune:
            self.lazytune.round_finished(iters, val_acc)
        if self.cfg.simfreeze and self.simfreeze.probe_batch is not None:
            if self.simfreeze.maybe_freeze(params, iters):
                self._refresh_plan()

    def inference_served(self, logits: np.ndarray) -> bool:
        """Returns True when a scenario change was detected."""
        if self.cfg.lazytune:
            self.lazytune.inference_arrived()
        if self.cfg.detect_scenario_changes:
            return self.detector.observe(logits)
        return False

    def probe_served(self, logits: np.ndarray) -> bool:
        """Dedicated drift-confirmation pass (detector-driven probes): the
        runtime pushes a probe Event when `inference_served` flags a
        change, runs one forward pass over the stream's validation split,
        and only latches the change if this returns True. Side-effect-free
        — LazyTune's inference-arrival decay counts real requests only."""
        if not self.cfg.detect_scenario_changes:
            return True
        return self.detector.confirm(logits)

    def scenario_changed(self, params, new_probe_batch) -> None:
        """External or detected scenario boundary (Alg. 1 l.19-26)."""
        if self.cfg.lazytune:
            self.lazytune.scenario_changed()
        if self.cfg.simfreeze and self.simfreeze.reference_params is not None:
            if self.simfreeze.scenario_changed(params, new_probe_batch):
                self._refresh_plan()

    # ---- reporting ------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "rounds_triggered": self.lazytune.state.rounds_triggered,
            "batches_needed": self.lazytune.state.batches_needed,
            "frozen_fraction": self.simfreeze.frozen_fraction(),
            "freezes": self.simfreeze.state.freezes,
            "unfreezes": self.simfreeze.state.unfreezes,
            "plan_changes": self.plan_changes,
            "ood_detections": self.detector.detections,
        }
