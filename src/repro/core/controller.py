"""ETunerController — the paper's combined policy (Algorithm 1), now a
thin `PolicyStack` composition (repro.core.policies, DESIGN.md §11):
LazyTune (inter-tuning) is a `TriggerPolicy`, SimFreeze (intra-tuning) a
`FreezePolicy`, and the energy-score scenario detector a `DriftPolicy`.
The composition's behaviour is pinned bit-exact to the pre-stack
monolith by the golden regression suite.

Ablation switches make the controller cover all four paper configurations:
  Immed.    = ETunerController(lazytune=False, simfreeze=False)
  LazyTune  = ETunerController(lazytune=True,  simfreeze=False)
  SimFreeze = ETunerController(lazytune=False, simfreeze=True)
  ETuner    = ETunerController(lazytune=True,  simfreeze=True)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Protocol, runtime_checkable

from repro.core.lazytune import LazyTuneConfig
from repro.core.ood import EnergyOODConfig
from repro.core.policies.drift import EnergyDriftPolicy, NoDriftPolicy
from repro.core.policies.freeze import NoFreezePolicy, SimFreezePolicy
from repro.core.policies.stack import PolicyStack
from repro.core.policies.trigger import (ImmediateTrigger, LazyTuneTrigger,
                                         StalenessGuard)
from repro.core.simfreeze import SimFreezeConfig


@runtime_checkable
class ControllerProtocol(Protocol):
    """The contract every scheduling policy implements (DESIGN.md §2).

    Controllers are *driven* by the runtime's event loop — they never see
    the `EventScheduler` or executor internals. The runtime calls, in
    event order:

    - `plan` (property): the current freeze plan — a hashable static jit
      argument; a changed plan implies a recompile charge.
    - `should_trigger(batches_available, staleness=0.0, priority=0)`:
      called on every buffered data batch; return True to launch a
      fine-tuning round now (the runtime additionally requires the
      device to be idle). `staleness` is the wall-clock seconds since
      *this stream's* last round completed (run start counts as fresh);
      `priority` is the stream's QoS priority (`StreamSpec.priority`) —
      a priority-aware policy (e.g. `PriorityWeightedTrigger`) can weigh
      both against LazyTune's accumulation target. Controllers written
      against the older two- or one-argument contracts keep working: the
      runtime adapts them via `repro.core.policies.adapt_controller`.
    - `round_finished(iters, val_acc, params)`: after each round, with the
      number of iterations run, validation accuracy, and the new params.
    - `inference_served(logits)`: after each served request, with that
      request's logits; return True to signal a detected scenario change
      (only honored when the runtime runs with boundaries='detector').
    - `scenario_changed(params, probe_batch)`: at an oracle scenario
      boundary or a detector-confirmed change.
    - `start_scenario(reference_params, probe_batch)` (optional): offered
      once per scenario to controllers that track reference-model
      similarity; gate with a `needs_reference` attribute.
    - `stats()` (optional): a dict folded into `RunResult.controller_stats`.
    - `publish_policy` (optional): a `repro.core.policies.PublishPolicy`
      deciding when a round's params reach serving (default: the
      bug-compat immediate publish, DESIGN.md §5).
    """

    @property
    def plan(self) -> Any: ...

    def should_trigger(self, batches_available: int,
                       staleness: float = 0.0,
                       priority: int = 0) -> bool: ...

    def round_finished(self, iters: int, val_acc: float, params) -> None: ...

    def inference_served(self, logits) -> bool: ...

    def scenario_changed(self, params, probe_batch) -> None: ...


@dataclass
class ETunerConfig:
    lazytune: bool = True
    simfreeze: bool = True
    detect_scenario_changes: bool = True
    lazytune_cfg: LazyTuneConfig = field(default_factory=LazyTuneConfig)
    simfreeze_cfg: SimFreezeConfig = field(default_factory=SimFreezeConfig)
    ood_cfg: EnergyOODConfig = field(default_factory=EnergyOODConfig)
    # QoS starvation guard: trigger a round regardless of LazyTune's
    # accumulation target once this stream has gone `max_staleness`
    # timeline-seconds without one (None = disabled, the paper behaviour)
    max_staleness: Optional[float] = None


class ETunerController(PolicyStack):
    def __init__(self, model, config: Optional[ETunerConfig] = None):
        # default must be constructed per instance: a shared module-level
        # default ETunerConfig() is mutable (e.g. cfg.max_staleness), so
        # one controller's tweak would leak into every other
        # default-constructed controller (regression-tested)
        config = ETunerConfig() if config is None else config
        self.cfg = config
        self.model = model
        if config.lazytune:
            trigger = LazyTuneTrigger(config.lazytune_cfg)
        else:
            trigger = ImmediateTrigger(
                config.lazytune_cfg.initial_batches_needed)
        if config.max_staleness is not None:
            trigger = StalenessGuard(trigger, config.max_staleness)
        freeze = SimFreezePolicy(model, config.simfreeze_cfg) \
            if config.simfreeze else NoFreezePolicy(model)
        drift = EnergyDriftPolicy(config.ood_cfg) \
            if config.detect_scenario_changes else NoDriftPolicy()
        super().__init__(model, trigger=trigger, freeze=freeze, drift=drift)
