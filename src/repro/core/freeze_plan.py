"""FreezePlan: the bridge between SimFreeze's decisions (which layers are
converged) and the execution engine (what compute/communication to skip).

Two granularities:
- *unrolled* models (paper CV/NLP models, reduced configs): one flag per
  layer; every frozen layer's params are `stop_gradient`-ed individually,
  so XLA dead-code-eliminates its weight-gradient ops (paper Fig. 2 case 2)
  and a frozen prefix stops activation gradients (case 3).
- *scan* models (the 10 assigned LM archs): one flag per layer-*group*;
  contiguous runs of equal flags become scan segments (see
  models/transformer.py).

The plan is hashable -> usable as a static jit argument; changing the plan
recompiles, and that recompile cost is exactly the "system initialization"
overhead the paper's LazyTune amortizes (the runtime caches compiled
variants keyed on the plan).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class FreezePlan:
    groups: Tuple[bool, ...] = ()   # True = frozen
    embed: bool = False
    head: bool = False

    @property
    def num_frozen(self) -> int:
        return sum(self.groups) + int(self.embed) + int(self.head)

    @property
    def all_active(self) -> bool:
        return self.num_frozen == 0

    def freeze(self, idx: int) -> "FreezePlan":
        g = list(self.groups)
        g[idx] = True
        return dataclasses.replace(self, groups=tuple(g))

    def unfreeze(self, idx: int) -> "FreezePlan":
        g = list(self.groups)
        g[idx] = False
        return dataclasses.replace(self, groups=tuple(g))

    def frozen_fraction(self) -> float:
        n = len(self.groups) + 2
        return self.num_frozen / n


def all_active(num_groups: int) -> FreezePlan:
    return FreezePlan(groups=(False,) * num_groups)


def lm_segments(plan: FreezePlan) -> List[Tuple[int, int, bool]]:
    """Contiguous (lo, hi, frozen) runs over the group axis."""
    segs: List[Tuple[int, int, bool]] = []
    lo = 0
    for i in range(1, len(plan.groups) + 1):
        if i == len(plan.groups) or plan.groups[i] != plan.groups[lo]:
            segs.append((lo, i, plan.groups[lo]))
            lo = i
    return segs


def grad_multiplier_tree(plan: FreezePlan, params) -> "jax.Array pytree":
    """0/1 multipliers matching the params pytree: for stacked [G, ...]
    block leaves a [G]-shaped mask broadcast over the leaf; scalars for
    embed/head. Used by the optimizer to pin frozen slices exactly (weight
    decay / momentum must not move them) even in mask-mode execution."""
    gmask = jnp.asarray([0.0 if f else 1.0 for f in plan.groups], jnp.float32)

    def for_leaf(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        if "blocks" in keys:
            m = gmask
            return m.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype) \
                if leaf.ndim >= 1 and leaf.shape[0] == gmask.shape[0] else \
                jnp.ones((), leaf.dtype)
        if "embed" in keys:
            return jnp.zeros((), leaf.dtype) if plan.embed else jnp.ones((), leaf.dtype)
        return jnp.ones((), leaf.dtype)

    return jax.tree_util.tree_map_with_path(for_leaf, params)


# ---------------------------------------------------------------------------
# unrolled-model plans (paper models): per-layer tuple


@dataclass(frozen=True)
class LayerFreezePlan:
    layers: Tuple[bool, ...] = ()

    @property
    def num_frozen(self) -> int:
        return sum(self.layers)

    def freeze(self, idx: int) -> "LayerFreezePlan":
        l = list(self.layers)
        l[idx] = True
        return LayerFreezePlan(tuple(l))

    def unfreeze(self, idx: int) -> "LayerFreezePlan":
        l = list(self.layers)
        l[idx] = False
        return LayerFreezePlan(tuple(l))

    def frozen_prefix(self) -> int:
        n = 0
        for f in self.layers:
            if not f:
                break
            n += 1
        return n


def maybe_stop(params_layer, frozen: bool):
    return jax.lax.stop_gradient(params_layer) if frozen else params_layer
