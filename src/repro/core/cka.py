"""Linear Centered Kernel Alignment (CKA) — the layer-convergence metric
behind SimFreeze (paper Eq. 1, after Kornblith et al. 2019).

Two mathematically equivalent evaluation routes for CKA(X, Y) with
X: [n, dx], Y: [n, dy] (row = example, column = feature, centered):

- *feature form*  ||Y^T X||_F^2 / (||X^T X||_F ||Y^T Y||_F): Gram over
  features; cheap when d <= n. This is what the Pallas kernel in
  kernels/cka tiles (never materializing the d x d Gram in HBM).
- *example form*  <K, L>_F / (||K||_F ||L||_F) with K = X X^T, L = Y Y^T:
  Gram over examples; cheap when n << d (CNN feature maps flattened to
  ~1e5 features but probe batches of 16-64 examples).

``cka(X, Y)`` picks the cheaper route; both are validated against each
other in tests (a property of the identity ||Y^T X||_F^2 = <XX^T, YY^T>_F).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _center(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    return x - x.mean(axis=0, keepdims=True)


def _flatten_features(x: jax.Array) -> jax.Array:
    """[B, ...] activations -> [n, d]. For token sequences [B,S,D] each
    (batch, position) pair is an example (standard minibatch CKA usage)."""
    if x.ndim == 2:
        return x
    if x.ndim == 3:  # [B, S, D] -> [B*S, D]
        return x.reshape(-1, x.shape[-1])
    return x.reshape(x.shape[0], -1)  # conv maps: flatten all features


def cka_feature_form(x: jax.Array, y: jax.Array, use_kernel: bool = False) -> jax.Array:
    if use_kernel:
        from repro.kernels.cka import ops as cka_ops

        num, nx, ny = cka_ops.cka_terms(x, y)
    else:
        xty = y.T @ x
        num = jnp.sum(xty * xty)
        xtx = x.T @ x
        yty = y.T @ y
        nx = jnp.sqrt(jnp.sum(xtx * xtx))
        ny = jnp.sqrt(jnp.sum(yty * yty))
    return num / jnp.maximum(nx * ny, 1e-12)


def cka_example_form(x: jax.Array, y: jax.Array) -> jax.Array:
    k = x @ x.T
    l = y @ y.T
    num = jnp.sum(k * l)
    return num / jnp.maximum(
        jnp.sqrt(jnp.sum(k * k)) * jnp.sqrt(jnp.sum(l * l)), 1e-12)


def cka(x: jax.Array, y: jax.Array, use_kernel: bool = False) -> jax.Array:
    """Linear CKA between two activation tensors (any matching leading
    shape). Returns a scalar in [0, 1]."""
    x = _center(_flatten_features(x))
    y = _center(_flatten_features(y))
    n, dx = x.shape
    dy = y.shape[1]
    if n < min(dx, dy) and not use_kernel:
        return cka_example_form(x, y)
    return cka_feature_form(x, y, use_kernel=use_kernel)


@jax.jit
def cka_jit(x: jax.Array, y: jax.Array) -> jax.Array:
    return cka(x, y)


def layerwise_cka(feats_a, feats_b, use_kernel: bool = False):
    """CKA per layer between two lists of activations (same model probed at
    two points in time, same probe batch)."""
    return [cka(a, b, use_kernel=use_kernel) for a, b in zip(feats_a, feats_b)]
