"""Composable scheduling policies (DESIGN.md §11).

EdgeOL's Algorithm 1 makes four orthogonal decisions — when to fine-tune
(`TriggerPolicy`), what to train (`FreezePolicy`), when the scenario
changed (`DriftPolicy`) and when to publish trained params
(`PublishPolicy`). This package gives each its own protocol and
implementations, a `PolicyStack` that composes one of each back into a
full `repro.core.ControllerProtocol` controller, declarative
`PolicySpec`/`PolicyStackSpec` descriptions (the per-slot policy entries
of `repro.runtime.config.RuntimeConfig`), and the legacy adapter that
keeps pre-stack monolithic controllers working.
"""
from repro.core.policies.base import (DriftPolicy, FreezePolicy,
                                      PublishPolicy, ThrottlePolicy,
                                      TriggerPolicy)
from repro.core.policies.drift import EnergyDriftPolicy, NoDriftPolicy
from repro.core.policies.freeze import (NoFreezePolicy, SimFreezePolicy,
                                        empty_plan)
from repro.core.policies.publish import ImmediatePublish, RoundEndPublish
from repro.core.policies.spec import (DRIFT_POLICIES, FREEZE_POLICIES,
                                      PUBLISH_POLICIES, THROTTLE_POLICIES,
                                      TRIGGER_POLICIES, PolicySpec,
                                      PolicyStackSpec, build_drift,
                                      build_freeze, build_publish,
                                      build_throttle, build_trigger,
                                      etuner_stack_spec)
from repro.core.policies.stack import (LegacyControllerAdapter, PolicyStack,
                                       adapt_controller)
from repro.core.policies.throttle import (BudgetThrottle, NullThrottle,
                                          ThermalThrottle)
from repro.core.policies.trigger import (ImmediateTrigger, LazyTuneTrigger,
                                         PriorityWeightedTrigger,
                                         StalenessGuard)

__all__ = [
    "TriggerPolicy", "FreezePolicy", "DriftPolicy", "PublishPolicy",
    "ThrottlePolicy",
    "ImmediateTrigger", "LazyTuneTrigger", "StalenessGuard",
    "PriorityWeightedTrigger",
    "NoFreezePolicy", "SimFreezePolicy", "empty_plan",
    "NoDriftPolicy", "EnergyDriftPolicy",
    "ImmediatePublish", "RoundEndPublish",
    "NullThrottle", "BudgetThrottle", "ThermalThrottle",
    "PolicyStack", "LegacyControllerAdapter", "adapt_controller",
    "PolicySpec", "PolicyStackSpec", "etuner_stack_spec",
    "build_trigger", "build_freeze", "build_drift", "build_publish",
    "build_throttle",
    "TRIGGER_POLICIES", "FREEZE_POLICIES", "DRIFT_POLICIES",
    "PUBLISH_POLICIES", "THROTTLE_POLICIES",
]
