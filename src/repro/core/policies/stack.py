"""PolicyStack — one trigger + freeze + drift + publish policy composed
back into a full `repro.core.ControllerProtocol` object, plus the legacy
adapter that lets pre-stack monolithic controllers keep working through
the runtime's `controller_factory` seam.
"""
from __future__ import annotations

import inspect
from typing import Optional

from repro.core.policies.drift import NoDriftPolicy
from repro.core.policies.freeze import NoFreezePolicy
from repro.core.policies.publish import ImmediatePublish
from repro.core.policies.throttle import NullThrottle
from repro.core.policies.trigger import ImmediateTrigger


class PolicyStack:
    """The runtime-facing controller as a composition of four policies
    (DESIGN.md §11). Each facet is independently swappable:

        PolicyStack(trigger=LazyTuneTrigger(), freeze=SimFreezePolicy(m),
                    drift=NoDriftPolicy(), publish=RoundEndPublish())

    Omitted facets default to the inert implementations (immediate
    trigger, no freezing, no detection, bug-compat publish); `model` is
    only needed when `freeze` is omitted (the default plan's shape).
    Call-order through the facets exactly mirrors the pre-stack
    `ETunerController` monolith — the golden regression suite pins it.
    """

    def __init__(self, model=None, *, trigger=None, freeze=None, drift=None,
                 publish=None, throttle=None):
        if freeze is None and model is None:
            raise ValueError("PolicyStack needs either a freeze policy or "
                             "a model to derive the default plan from")
        self.trigger = trigger if trigger is not None else ImmediateTrigger()
        self.freeze = freeze if freeze is not None else NoFreezePolicy(model)
        self.drift = drift if drift is not None else NoDriftPolicy()
        self.publish_policy = publish if publish is not None \
            else ImmediatePublish()
        # fifth facet (DESIGN.md §15): env-aware round gating, consulted
        # by the runtime only on devices carrying a live env — the
        # default NullThrottle keeps every other path untouched
        self.throttle = throttle if throttle is not None else NullThrottle()

    # ---- plan (owned by the freeze policy) -------------------------------
    @property
    def plan(self):
        return self.freeze.plan

    @property
    def plan_changes(self) -> int:
        return self.freeze.plan_changes

    # ---- events ----------------------------------------------------------
    def start_scenario(self, reference_params, probe_batch) -> None:
        self.freeze.start_scenario(reference_params, probe_batch)

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool:
        return self.trigger.should_trigger(batches_available,
                                           staleness=staleness,
                                           priority=priority)

    def round_finished(self, iters: int, val_acc: float, params) -> None:
        self.trigger.round_finished(iters, val_acc)
        self.freeze.round_finished(iters, params)

    def inference_served(self, logits) -> bool:
        """Returns True when a scenario change was detected."""
        self.trigger.inference_arrived()
        return self.drift.observe(logits)

    def probe_served(self, logits) -> bool:
        """Dedicated drift-confirmation pass (DESIGN.md §10)."""
        return self.drift.confirm(logits)

    def scenario_changed(self, params, new_probe_batch) -> None:
        """External or detected scenario boundary (Alg. 1 l.19-26)."""
        self.trigger.scenario_changed()
        self.freeze.scenario_changed(params, new_probe_batch)

    # ---- reporting -------------------------------------------------------
    def stats(self) -> dict:
        out = dict(self.trigger.stats())
        out.update(self.freeze.stats())
        out.update(self.drift.stats())
        out.update(self.throttle.stats())
        return out

    # ---- compat surfaces (state machines owned by the facets) ------------
    @property
    def lazytune(self):
        """The trigger's LazyTune state machine (LazyTune-based triggers
        only — AttributeError otherwise, like any absent attribute)."""
        return self.trigger.lazytune

    @property
    def simfreeze(self):
        """The freeze policy's SimFreeze state machine (the runtime
        charges its CKA probe FLOPs when present)."""
        return self.freeze.simfreeze

    @property
    def detector(self):
        """The drift policy's energy-score detector, when it has one."""
        return self.drift.detector


def _accepts(callable_, name: str) -> Optional[bool]:
    """Does `callable_` accept keyword `name`? None = unknown (builtins,
    C callables — treat as legacy)."""
    try:
        params = inspect.signature(callable_).parameters
    except (TypeError, ValueError):
        return None
    return name in params or any(p.kind is p.VAR_KEYWORD
                                 for p in params.values())


class LegacyControllerAdapter:
    """Presents a pre-stack monolithic controller through the current
    protocol surface: `should_trigger` grew `staleness` (PR 3) then
    `priority` (PolicyStack) keywords, and third-party controllers
    written against the older contracts must keep working through
    `controller_factory`. The adapter drops the keywords the wrapped
    controller does not understand and forwards everything else
    untouched (same objects, same state)."""

    def __init__(self, controller):
        self._controller = controller
        self._staleness = bool(_accepts(controller.should_trigger,
                                        "staleness"))

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool:
        if self._staleness:
            return self._controller.should_trigger(batches_available,
                                                   staleness=staleness)
        return self._controller.should_trigger(batches_available)

    def __getattr__(self, name):
        return getattr(self._controller, name)


def adapt_controller(controller):
    """Return `controller` itself when it already speaks the full
    protocol (`should_trigger` accepts `priority`), else wrap it in a
    `LegacyControllerAdapter`. The runtime applies this to every
    controller it drives, so monolithic policies predating the stack —
    and the staleness/priority keywords — plug in unchanged."""
    if _accepts(controller.should_trigger, "priority"):
        return controller
    return LegacyControllerAdapter(controller)
