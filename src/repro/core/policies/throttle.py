"""ThrottlePolicy implementations — the fifth PolicyStack facet: should
the device spend a fine-tuning round's time and energy *right now*, given
its physical environment (DESIGN.md §15)?

The policy sees an `repro.env.EnvState` snapshot (battery state of
charge, joules remaining, temperature, DVFS level) plus the runtime's
modeled estimate of the round about to launch, and answers allow/defer.
Deferred rounds are not dropped: the buffered batches stay queued and the
next arrival re-asks, so a recovering battery or a cooling device picks
the work back up. Policies are duck-typed against `EnvState`'s attribute
names — no import of `repro.env` — so the policy layer stays decoupled
from the physics.

`NullThrottle` ("none") is the default on every stack and always allows:
with no env configured the consultation path is short-circuited entirely
and the run is bit-exact with the pre-env runtime (golden-pinned).
"""
from __future__ import annotations


class NullThrottle:
    """Always allow — the inert default facet (bit-exact legacy path)."""

    name = "none"

    def allow_round(self, state, *, time_s: float = 0.0,
                    energy_j: float = 0.0) -> bool:
        return True

    def stats(self) -> dict:
        return {}


class BudgetThrottle:
    """Battery-budget gating: a round launches only while the battery
    can afford its estimated energy *above* the dead-reserve (so the
    un-gateable small charges — probes, CKA, sync participation — have
    headroom), and state of charge sits above `min_soc`. A dead battery
    always defers (the fleet evicts the device anyway)."""

    name = "battery"

    def __init__(self, min_soc: float = 0.0):
        if not 0.0 <= min_soc < 1.0:
            raise ValueError(f"min_soc must be in [0, 1) (got {min_soc!r})")
        self.min_soc = float(min_soc)
        self.deferred = 0

    def allow_round(self, state, *, time_s: float = 0.0,
                    energy_j: float = 0.0) -> bool:
        if state.charge_j is None:  # mains-powered: nothing to conserve
            return True
        ok = (not state.battery_dead
              and state.soc > self.min_soc
              and state.charge_j - state.reserve_j >= energy_j)
        if not ok:
            self.deferred += 1
        return ok

    def stats(self) -> dict:
        return {"throttle_deferred": self.deferred}


class ThermalThrottle:
    """Thermal gating: defer rounds while the device sits at or above
    `max_temp_c`. Complements the DVFS governor (which merely slows the
    clock): under a sustained overload the governor bottoms out and this
    policy sheds the *work* until the RC node cools."""

    name = "thermal"

    def __init__(self, max_temp_c: float = 80.0):
        if max_temp_c <= 0:
            raise ValueError(f"max_temp_c must be > 0 (got {max_temp_c!r})")
        self.max_temp_c = float(max_temp_c)
        self.deferred = 0

    def allow_round(self, state, *, time_s: float = 0.0,
                    energy_j: float = 0.0) -> bool:
        ok = state.temperature_c < self.max_temp_c
        if not ok:
            self.deferred += 1
        return ok

    def stats(self) -> dict:
        return {"throttle_deferred": self.deferred}
