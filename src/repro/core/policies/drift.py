"""Drift policies — *when the scenario changed*, inferred from serving.

`observe` feeds each served request's logits (honored by the runtime in
boundaries='detector' mode); `confirm` is the side-effect-free check a
dedicated probe pass runs before the change is latched (DESIGN.md §10).
"""
from __future__ import annotations

from typing import Optional

from repro.core.ood import EnergyOODConfig, EnergyOODDetector


class NoDriftPolicy:
    """Scenario changes come only from oracle boundaries. `confirm`
    returns True so an externally-fired probe (e.g. a spy controller in
    tests) still latches — matching the pre-stack monolith with
    `detect_scenario_changes=False`."""

    def observe(self, logits) -> bool:
        return False

    def confirm(self, logits) -> bool:
        return True

    def stats(self) -> dict:
        return {"ood_detections": 0}


class EnergyDriftPolicy:
    """Energy-score OOD detection (paper §IV-A3): flag a change when a
    window of served requests' energies drifts above the z-threshold;
    confirm probes z-test against the baseline snapshotted at the
    triggering detection (`EnergyOODDetector.confirm`)."""

    def __init__(self, config: Optional[EnergyOODConfig] = None):
        self.detector = EnergyOODDetector(config if config is not None
                                          else EnergyOODConfig())

    def observe(self, logits) -> bool:
        return self.detector.observe(logits)

    def confirm(self, logits) -> bool:
        return self.detector.confirm(logits)

    def stats(self) -> dict:
        return {"ood_detections": self.detector.detections}
