"""Trigger policies — *when* to launch a fine-tuning round.

`LazyTuneTrigger` is the paper's inter-tuning policy (Alg. 1); the rest
cover the ablation baseline (`ImmediateTrigger`), the QoS starvation
guard (`StalenessGuard`, previously `ETunerConfig.max_staleness`) and the
ROADMAP's priority-aware variant (`PriorityWeightedTrigger`), which
scales LazyTune's accumulation target by the stream's QoS priority.
"""
from __future__ import annotations

from typing import Optional

from repro.core.lazytune import LazyTune, LazyTuneConfig


class ImmediateTrigger:
    """Fine-tune as soon as any batch is buffered (the paper's Immed.
    baseline). `batches_needed` mirrors what the pre-stack monolith
    reported for a disabled LazyTune (its untouched initial target), so
    `stats()` stays key- and value-compatible."""

    def __init__(self, batches_needed: float = 1.0):
        self.batches_needed = float(batches_needed)

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool:
        return batches_available >= 1

    def round_finished(self, iters: int, val_acc: float) -> None:
        pass

    def inference_arrived(self) -> None:
        pass

    def scenario_changed(self) -> None:
        pass

    def stats(self) -> dict:
        return {"rounds_triggered": 0, "batches_needed": self.batches_needed}


class LazyTuneTrigger:
    """The paper's LazyTune accumulation target (Alg. 1 l.1-2, 10-21),
    unchanged — this class only gives the existing `repro.core.lazytune`
    state machine the TriggerPolicy surface."""

    def __init__(self, config: Optional[LazyTuneConfig] = None):
        self.lazytune = LazyTune(config if config is not None
                                 else LazyTuneConfig())

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool:
        return self.lazytune.should_trigger(batches_available)

    def round_finished(self, iters: int, val_acc: float) -> None:
        self.lazytune.round_finished(iters, val_acc)

    def inference_arrived(self) -> None:
        self.lazytune.inference_arrived()

    def scenario_changed(self) -> None:
        self.lazytune.scenario_changed()

    def stats(self) -> dict:
        st = self.lazytune.state
        return {"rounds_triggered": st.rounds_triggered,
                "batches_needed": st.batches_needed}


class StalenessGuard:
    """TriggerPolicy decorator: force a round once the stream has gone
    `max_staleness` timeline-seconds without one (and has data buffered),
    otherwise defer to the wrapped policy. This is the QoS starvation
    guard previously baked into `ETunerConfig.max_staleness` (DESIGN.md
    §8) — now composable around any trigger."""

    def __init__(self, inner, max_staleness: float):
        if max_staleness <= 0:
            raise ValueError(f"max_staleness must be positive "
                             f"(got {max_staleness!r})")
        self.inner = inner
        self.max_staleness = float(max_staleness)

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool:
        if batches_available and staleness >= self.max_staleness:
            return True
        return self.inner.should_trigger(batches_available,
                                         staleness=staleness,
                                         priority=priority)

    def round_finished(self, iters: int, val_acc: float) -> None:
        self.inner.round_finished(iters, val_acc)

    def inference_arrived(self) -> None:
        self.inner.inference_arrived()

    def scenario_changed(self) -> None:
        self.inner.scenario_changed()

    def stats(self) -> dict:
        return self.inner.stats()

    def __getattr__(self, name):
        # decorator transparency: `.lazytune` etc. reach the wrapped policy
        return getattr(self.inner, name)


class PriorityWeightedTrigger:
    """LazyTune whose accumulation target is scaled by the stream's QoS
    priority (ROADMAP: priority-weighted LazyTune targets).

    A priority-`p` stream triggers only once `batches_available >=
    batches_needed * (1 + priority_weight * p)`: latency-critical
    streams *defer* fine-tuning — accumulating more batches per round
    keeps the one shared device free for their many requests (each round
    the stream skips is occupancy its own queries never wait out), which
    is exactly LazyTune's bet that tuning less often costs little
    accuracy. Priority-0 bulk streams keep the paper's plain LazyTune
    behaviour, as does every stream at `priority_weight=0`. Compose with
    a `StalenessGuard` — the spec builder does, via the `max_staleness`
    param — for the *joint* priority/staleness decision: the unscaled
    guard force-triggers a deferred stream before its model goes stale,
    so priority buys serving latency only up to that freshness bound."""

    def __init__(self, config: Optional[LazyTuneConfig] = None,
                 priority_weight: float = 0.5):
        if priority_weight < 0:
            raise ValueError(f"priority_weight must be >= 0 "
                             f"(got {priority_weight!r})")
        self.lazytune = LazyTune(config if config is not None
                                 else LazyTuneConfig())
        self.priority_weight = float(priority_weight)

    def _boost(self, priority: int) -> float:
        return 1.0 + self.priority_weight * max(int(priority), 0)

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool:
        st = self.lazytune.state
        trig = batches_available >= st.batches_needed * self._boost(priority)
        if not trig and batches_available > 0:
            # LazyTune.should_trigger's delay bookkeeping, kept in step
            # (we cannot call it directly: its predicate has no boost)
            st.rounds_delayed += 1
        return trig

    def round_finished(self, iters: int, val_acc: float) -> None:
        self.lazytune.round_finished(iters, val_acc)

    def inference_arrived(self) -> None:
        self.lazytune.inference_arrived()

    def scenario_changed(self) -> None:
        self.lazytune.scenario_changed()

    def stats(self) -> dict:
        st = self.lazytune.state
        return {"rounds_triggered": st.rounds_triggered,
                "batches_needed": st.batches_needed}
