"""Freeze policies — *what* to train each round (the freeze plan).

The plan is a hashable static jit argument: a change implies a recompile
charge, so the policy caches it and counts `plan_changes` exactly like
the pre-stack monolith did (the golden regression pins the sequence).
"""
from __future__ import annotations

from typing import Optional

from repro.core.freeze_plan import LayerFreezePlan, all_active
from repro.core.simfreeze import SimFreeze, SimFreezeConfig


def empty_plan(model):
    """The everything-trains plan for `model` (scanned LMs use group
    plans, the unrolled paper models per-layer plans)."""
    if getattr(model.cfg, "is_lm", False) and model.cfg.scan_layers:
        return all_active(model.num_freeze_units)
    return LayerFreezePlan(layers=(False,) * model.num_freeze_units)


class NoFreezePolicy:
    """Every layer trains every round (the paper's non-SimFreeze arms)."""

    def __init__(self, model):
        self._plan = empty_plan(model)
        self.plan_changes = 0

    @property
    def plan(self):
        return self._plan

    def start_scenario(self, reference_params, probe_batch) -> None:
        pass

    def round_finished(self, iters: int, params) -> None:
        pass

    def scenario_changed(self, params, probe_batch) -> None:
        pass

    def stats(self) -> dict:
        return {"frozen_fraction": 0.0, "freezes": 0, "unfreezes": 0,
                "plan_changes": self.plan_changes}


class SimFreezePolicy:
    """The paper's SimFreeze intra-tuning policy (Alg. 1 l.4-9, 22-26):
    CKA-guided freeze/unfreeze against the per-scenario reference model.
    Wraps the existing `repro.core.simfreeze` state machine with the plan
    cache + change counter the runtime charges recompiles from."""

    def __init__(self, model, config: Optional[SimFreezeConfig] = None):
        scan_mode = getattr(model.cfg, "is_lm", False) and \
            model.cfg.scan_layers
        self.simfreeze = SimFreeze(
            model.num_freeze_units, model.features,
            config if config is not None else SimFreezeConfig(),
            scan_mode=scan_mode)
        self._plan = empty_plan(model)
        self.plan_changes = 0

    @property
    def plan(self):
        return self._plan

    def _refresh_plan(self) -> None:
        new = self.simfreeze.plan()
        if new != self._plan:
            self.plan_changes += 1
        self._plan = new

    def start_scenario(self, reference_params, probe_batch) -> None:
        self.simfreeze.start_scenario(reference_params, probe_batch)

    def round_finished(self, iters: int, params) -> None:
        if self.simfreeze.probe_batch is not None and \
                self.simfreeze.maybe_freeze(params, iters):
            self._refresh_plan()

    def scenario_changed(self, params, probe_batch) -> None:
        if self.simfreeze.reference_params is not None and \
                self.simfreeze.scenario_changed(params, probe_batch):
            self._refresh_plan()

    def stats(self) -> dict:
        return {"frozen_fraction": self.simfreeze.frozen_fraction(),
                "freezes": self.simfreeze.state.freezes,
                "unfreezes": self.simfreeze.state.unfreezes,
                "plan_changes": self.plan_changes}
