"""Declarative policy specs — named, parameterized, JSON-round-trippable
descriptions of a `PolicyStack`, the per-slot policy entries of
`repro.runtime.config.RuntimeConfig` (DESIGN.md §11).

A `PolicySpec` is `{"name": <registered name>, **params}`; params are the
flattened fields of the underlying config dataclass (e.g. the trigger
spec `{"name": "lazytune", "max_batches_needed": 6}` builds
`LazyTuneTrigger(LazyTuneConfig(max_batches_needed=6))`). Unknown names
and unknown params raise with the valid alternatives spelled out.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core.lazytune import LazyTuneConfig
from repro.core.ood import EnergyOODConfig
from repro.core.policies.drift import EnergyDriftPolicy, NoDriftPolicy
from repro.core.policies.freeze import NoFreezePolicy, SimFreezePolicy
from repro.core.policies.publish import ImmediatePublish, RoundEndPublish
from repro.core.policies.stack import PolicyStack
from repro.core.policies.throttle import (BudgetThrottle, NullThrottle,
                                          ThermalThrottle)
from repro.core.policies.trigger import (ImmediateTrigger, LazyTuneTrigger,
                                         PriorityWeightedTrigger,
                                         StalenessGuard)
from repro.core.simfreeze import SimFreezeConfig


@dataclass(frozen=True)
class PolicySpec:
    """One named policy + its parameters. Serializes flat:
    ``{"name": "lazytune", "max_batches_needed": 6}``."""
    name: str
    params: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        if "name" in self.params:
            raise ValueError("PolicySpec params cannot shadow 'name'")
        return {"name": self.name, **self.params}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PolicySpec":
        if not isinstance(d, dict) or "name" not in d:
            raise ValueError(f"a policy spec must be a dict with a 'name' "
                             f"key (got {d!r})")
        d = dict(d)
        return cls(name=d.pop("name"), params=d)


# ---------------------------------------------------------------------------
# builders: spec name -> policy instance


def _cfg_build(cfg_cls, params: Dict[str, Any], *, context: str):
    known = {f.name for f in dataclasses.fields(cfg_cls)}
    unknown = set(params) - known
    if unknown:
        raise ValueError(f"{context}: unknown parameter(s) "
                         f"{sorted(unknown)}; valid: {sorted(known)}")
    return cfg_cls(**params)


def _build_lazytune_cfg(params: Dict[str, Any], *, context: str,
                        extra=()) -> tuple:
    """Split `params` into (LazyTuneConfig, leftover-dict of `extra`)."""
    params = dict(params)
    leftovers = {k: params.pop(k) for k in extra if k in params}
    return _cfg_build(LazyTuneConfig, params, context=context), leftovers


def _trigger_immediate(params, context):
    if set(params) - {"batches_needed", "max_staleness"}:
        raise ValueError(f"{context}: valid parameters: "
                         f"['batches_needed', 'max_staleness']")
    ms = params.pop("max_staleness", None)
    trig = ImmediateTrigger(**params)
    return trig if ms is None else StalenessGuard(trig, ms)


def _trigger_lazytune(params, context):
    cfg, kw = _build_lazytune_cfg(params, context=context,
                                  extra=("max_staleness",))
    trig = LazyTuneTrigger(cfg)
    ms = kw.get("max_staleness")
    return trig if ms is None else StalenessGuard(trig, ms)


def _trigger_priority_weighted(params, context):
    cfg, kw = _build_lazytune_cfg(
        params, context=context, extra=("max_staleness", "priority_weight"))
    trig = PriorityWeightedTrigger(
        cfg, priority_weight=kw.get("priority_weight", 0.5))
    ms = kw.get("max_staleness")
    return trig if ms is None else StalenessGuard(trig, ms)


TRIGGER_POLICIES = {
    "immediate": _trigger_immediate,
    "lazytune": _trigger_lazytune,
    "priority-weighted": _trigger_priority_weighted,
}

FREEZE_POLICIES = {
    "none": lambda model, params, context: NoFreezePolicy(model)
    if not params else _raise_params(context, []),
    "simfreeze": lambda model, params, context: SimFreezePolicy(
        model, _cfg_build(SimFreezeConfig, params, context=context)),
}

DRIFT_POLICIES = {
    "none": lambda params, context: NoDriftPolicy()
    if not params else _raise_params(context, []),
    "energy": lambda params, context: EnergyDriftPolicy(
        _cfg_build(EnergyOODConfig, params, context=context)),
}

PUBLISH_POLICIES = {
    "immediate": lambda params, context: ImmediatePublish()
    if not params else _raise_params(context, []),
    "round-end": lambda params, context: RoundEndPublish()
    if not params else _raise_params(context, []),
}


def _throttle_build(cls_, params, context, valid):
    unknown = set(params) - valid
    if unknown:
        raise ValueError(f"{context}: unknown parameter(s) "
                         f"{sorted(unknown)}; valid: {sorted(valid)}")
    return cls_(**params)


THROTTLE_POLICIES = {
    "none": lambda params, context: NullThrottle()
    if not params else _raise_params(context, []),
    "battery": lambda params, context: _throttle_build(
        BudgetThrottle, params, context, {"min_soc"}),
    "thermal": lambda params, context: _throttle_build(
        ThermalThrottle, params, context, {"max_temp_c"}),
}


def _raise_params(context, valid):
    raise ValueError(f"{context}: takes no parameters" if not valid
                     else f"{context}: valid parameters: {valid}")


def _lookup(registry, kind: str, spec: PolicySpec):
    if spec.name not in registry:
        raise ValueError(
            f"unknown {kind} policy {spec.name!r}; known {kind} policies: "
            f"{sorted(registry)}")
    return registry[spec.name]


def build_trigger(spec: PolicySpec):
    return _lookup(TRIGGER_POLICIES, "trigger", spec)(
        dict(spec.params), f"trigger policy {spec.name!r}")


def build_freeze(spec: PolicySpec, model):
    return _lookup(FREEZE_POLICIES, "freeze", spec)(
        model, dict(spec.params), f"freeze policy {spec.name!r}")


def build_drift(spec: PolicySpec):
    return _lookup(DRIFT_POLICIES, "drift", spec)(
        dict(spec.params), f"drift policy {spec.name!r}")


def build_publish(spec: PolicySpec):
    return _lookup(PUBLISH_POLICIES, "publish", spec)(
        dict(spec.params), f"publish policy {spec.name!r}")


def build_throttle(spec: PolicySpec):
    return _lookup(THROTTLE_POLICIES, "throttle", spec)(
        dict(spec.params), f"throttle policy {spec.name!r}")


# ---------------------------------------------------------------------------
# a full stack spec


@dataclass(frozen=True)
class PolicyStackSpec:
    """Declarative description of one `PolicyStack` (one runtime slot's
    policy entry). Defaults mirror `ETunerConfig` defaults: LazyTune +
    SimFreeze + energy-score detection + bug-compat publish."""
    trigger: PolicySpec = field(default_factory=lambda: PolicySpec("lazytune"))
    freeze: PolicySpec = field(default_factory=lambda: PolicySpec("simfreeze"))
    drift: PolicySpec = field(default_factory=lambda: PolicySpec("energy"))
    publish: PolicySpec = field(
        default_factory=lambda: PolicySpec("immediate"))
    # the fifth facet (DESIGN.md §15): env-aware round gating. "none"
    # (the default) is inert and serialized away, so every pre-env
    # stack spec round-trips byte-identically.
    throttle: PolicySpec = field(default_factory=lambda: PolicySpec("none"))

    def validate(self) -> "PolicyStackSpec":
        """Check every name/param against the registries (builds throw-
        away instances for the model-free kinds; freeze params are
        checked against the config fields without a model)."""
        build_trigger(self.trigger)
        _lookup(FREEZE_POLICIES, "freeze", self.freeze)
        if self.freeze.name == "simfreeze":
            _cfg_build(SimFreezeConfig, dict(self.freeze.params),
                       context=f"freeze policy {self.freeze.name!r}")
        elif self.freeze.params:
            raise ValueError(f"freeze policy {self.freeze.name!r}: takes "
                             f"no parameters")
        build_drift(self.drift)
        build_publish(self.publish)
        build_throttle(self.throttle)
        return self

    def build(self, model) -> PolicyStack:
        """Materialize the stack for `model`."""
        return PolicyStack(model,
                           trigger=build_trigger(self.trigger),
                           freeze=build_freeze(self.freeze, model),
                           drift=build_drift(self.drift),
                           publish=build_publish(self.publish),
                           throttle=build_throttle(self.throttle))

    def to_dict(self) -> Dict[str, Any]:
        out = {"trigger": self.trigger.to_dict(),
               "freeze": self.freeze.to_dict(),
               "drift": self.drift.to_dict(),
               "publish": self.publish.to_dict()}
        if self.throttle != PolicySpec("none"):
            out["throttle"] = self.throttle.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PolicyStackSpec":
        if not isinstance(d, dict):
            raise ValueError(f"a policy-stack spec must be a dict "
                             f"(got {d!r})")
        unknown = set(d) - {"trigger", "freeze", "drift", "publish",
                            "throttle"}
        if unknown:
            raise ValueError(
                f"policy-stack spec: unknown key(s) {sorted(unknown)}; "
                f"valid: ['trigger', 'freeze', 'drift', 'publish', "
                f"'throttle']")
        kw = {k: PolicySpec.from_dict(v) for k, v in d.items()}
        return cls(**kw)


def etuner_stack_spec(*, lazytune: bool = True, simfreeze: bool = True,
                      detect_scenario_changes: bool = True,
                      lazytune_params: Optional[Dict[str, Any]] = None,
                      simfreeze_params: Optional[Dict[str, Any]] = None,
                      max_staleness: Optional[float] = None,
                      publish: str = "immediate") -> PolicyStackSpec:
    """The four paper ablations as stack specs (Immed. / LazyTune /
    SimFreeze / ETuner), mirroring the `ETunerConfig` switches."""
    tparams = dict(lazytune_params or {})
    if not lazytune:
        # mirror ETunerConfig(lazytune=False): only the initial target
        # survives (it is what a disabled LazyTune's stats report);
        # anything else supplied for a disabled facet is a
        # misconfiguration, not something to drop silently
        extra = set(tparams) - {"initial_batches_needed"}
        if extra:
            raise ValueError(
                f"lazytune=False: lazytune_params {sorted(extra)} have no "
                f"effect (only 'initial_batches_needed' maps to the "
                f"immediate trigger's reported batches_needed)")
        tparams = {"batches_needed": tparams["initial_batches_needed"]} \
            if tparams else {}
    if max_staleness is not None:
        tparams["max_staleness"] = max_staleness
    return PolicyStackSpec(
        trigger=PolicySpec("lazytune" if lazytune else "immediate", tparams),
        freeze=PolicySpec("simfreeze", dict(simfreeze_params or {}))
        if simfreeze else PolicySpec("none"),
        drift=PolicySpec("energy") if detect_scenario_changes
        else PolicySpec("none"),
        publish=PolicySpec(publish))
