"""Publish policies — *when* a round's trained params reach serving.

Both built-ins make the new params resolvable from the round's device-
occupancy end; they differ in what requests arriving *mid-round* see
(the `visible_params`/`latest_params` seam, DESIGN.md §5):

- `ImmediatePublish` keeps the bug-compat monolith behaviour: publish
  overwrites both sides of the seam, so a mid-round arrival is served by
  the round's freshly trained params. The golden regression pins this
  as the default.
- `RoundEndPublish` is the genuinely-delayed seam the async-publish
  ROADMAP item needs: arrivals before `visible_at` keep resolving the
  *pre-round* params (the paper §III-A "outdated model" effect).

A future async policy can subclass and shift `visible_at` past the round
end to model a real transfer/validation delay.
"""
from __future__ import annotations


class ImmediatePublish:
    """Bug-compat §5 seam: latest == visible (mid-round arrivals get the
    new params)."""

    delayed = False

    def visible_at(self, round_end: float) -> float:
        return round_end


class RoundEndPublish:
    """Genuinely delayed publication: params flip over only at the
    round's occupancy end; earlier arrivals resolve the pre-round
    params."""

    delayed = True

    def visible_at(self, round_end: float) -> float:
        return round_end
