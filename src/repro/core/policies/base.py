"""Policy protocols — the four orthogonal decisions of EdgeOL's
Algorithm 1, each behind its own small contract (DESIGN.md §11).

The pre-PolicyStack `ControllerProtocol` (core/controller.py) fused four
independent questions into one grab-bag object:

- **when to fine-tune** (`TriggerPolicy` — LazyTune's accumulation
  target, Alg. 1 l.1-2/10-21),
- **what to train** (`FreezePolicy` — SimFreeze's CKA-guided freeze
  plan, Alg. 1 l.4-9/22-26),
- **when the scenario changed** (`DriftPolicy` — energy-score detection
  from served logits + dedicated probe confirmation, §IV-A3),
- **when to publish** trained params to serving (`PublishPolicy` — the
  DESIGN.md §5 visibility seam).

A fifth, orthogonal to the paper's four: **whether the device can
afford it** (`ThrottlePolicy` — battery/thermal gating against the
`repro.env` device environment, DESIGN.md §15; inert unless the device
carries an `EnvSpec`).

`PolicyStack` (policies/stack.py) composes one of each back into a full
`ControllerProtocol` object, so the runtime keeps driving a single
controller while every axis stays independently swappable, testable and
declaratively constructible (`repro.runtime.config.RuntimeConfig`).

Policies are pure-Python state machines (no jax): they *schedule* jitted
work, they never sit inside it.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable


@runtime_checkable
class TriggerPolicy(Protocol):
    """When to launch a fine-tuning round (inter-tuning frequency).

    - `should_trigger(batches_available, staleness=0.0, priority=0)`:
      called on every buffered data batch. `staleness` is the seconds
      since this stream's last round completed; `priority` is the
      stream's QoS priority (`StreamSpec.priority`) so a priority-aware
      policy can weigh round timing against serving (e.g.
      `PriorityWeightedTrigger` *defers* a latency-critical stream's
      rounds — occupancy its requests never wait out — bounded by the
      staleness signal).
    - `round_finished(iters, val_acc)`: accuracy feedback after a round.
    - `inference_arrived()`: one served request (LazyTune's decay signal).
    - `scenario_changed()`: drift reset.
    - `stats()`: reporting dict.
    """

    def should_trigger(self, batches_available: int, staleness: float = 0.0,
                       priority: int = 0) -> bool: ...

    def round_finished(self, iters: int, val_acc: float) -> None: ...

    def inference_arrived(self) -> None: ...

    def scenario_changed(self) -> None: ...

    def stats(self) -> dict: ...


@runtime_checkable
class FreezePolicy(Protocol):
    """Which layers train (intra-tuning plan). Owns the freeze plan — a
    hashable static jit argument; a changed plan implies a recompile
    charge (the stack counts changes in `plan_changes`).

    - `start_scenario(reference_params, probe_batch)`: offered once per
      scenario for reference-similarity tracking.
    - `round_finished(iters, params)`: post-round freeze pass.
    - `scenario_changed(params, probe_batch)`: unfreeze re-evaluation.
    """

    @property
    def plan(self) -> Any: ...

    plan_changes: int

    def start_scenario(self, reference_params, probe_batch) -> None: ...

    def round_finished(self, iters: int, params) -> None: ...

    def scenario_changed(self, params, probe_batch) -> None: ...

    def stats(self) -> dict: ...


@runtime_checkable
class DriftPolicy(Protocol):
    """When the scenario changed, inferred from serving.

    - `observe(logits) -> bool`: one served request's logits; True flags
      a suspected scenario change (honored in boundaries='detector').
    - `confirm(logits) -> bool`: side-effect-free check for a dedicated
      confirmation probe pass (DESIGN.md §10).
    """

    def observe(self, logits) -> bool: ...

    def confirm(self, logits) -> bool: ...

    def stats(self) -> dict: ...


@runtime_checkable
class ThrottlePolicy(Protocol):
    """Whether to spend a fine-tuning round *now*, given the device's
    physical environment (DESIGN.md §15 — the fifth facet; the other
    four decide on data/accuracy, this one on joules and kelvin).

    - `allow_round(state, time_s=..., energy_j=...) -> bool`: consulted
      after the trigger fires and the device is idle. `state` is an
      `repro.env.EnvState` snapshot (soc / charge_j / reserve_j /
      temperature_c / level / battery_dead); `time_s`/`energy_j` are the
      runtime's modeled estimate of the round about to launch
      (`FineTuneExecutor.estimate_round` — replay batch and worst-case
      recompile included). False defers: batches stay buffered and the
      next arrival re-asks. Devices without an env never consult.
    - `stats()`: reporting dict (merged into the stack's stats).
    """

    def allow_round(self, state, *, time_s: float = 0.0,
                    energy_j: float = 0.0) -> bool: ...

    def stats(self) -> dict: ...


@runtime_checkable
class PublishPolicy(Protocol):
    """When a round's freshly trained params become visible to serving.

    - `visible_at(round_end) -> float`: the timestamp requests start
      resolving the new params (the round's device-occupancy end for
      both built-ins; an async policy may add a transfer delay).
    - `delayed`: False keeps the §5 bug-compat seam (mid-round arrivals
      see the new params: latest == visible); True retains the pre-round
      params for arrivals before `visible_at` — genuinely delayed
      publication.
    """

    delayed: bool

    def visible_at(self, round_end: float) -> float: ...
