"""ETuner core: the paper's contribution as composable JAX-adjacent modules.

- cka: layer self-representational similarity (Eq. 1)
- curvefit: NNLS accuracy-curve estimator (Optimus-style)
- lazytune: inter-tuning round scheduler (Alg. 1 l.1-2, 10-21)
- simfreeze: intra-tuning CKA-guided freeze/unfreeze (Alg. 1 l.4-9, 22-26)
- ood: energy-score scenario-change detection
- freeze_plan: plan -> stop_gradient segments / grad masks / allreduce skips
- policies: the four policy protocols (trigger/freeze/drift/publish),
  PolicyStack, declarative PolicySpec/PolicyStackSpec + legacy adapter
- controller: ETunerController — the combined paper policy as a thin
  PolicyStack composition
- semi: SimSiam objective for unlabeled data (§IV-C)
"""
from repro.core.cka import cka, layerwise_cka
from repro.core.controller import (ControllerProtocol, ETunerConfig,
                                   ETunerController)
from repro.core.curvefit import AccuracyCurve, fit_accuracy_curve
from repro.core.freeze_plan import (FreezePlan, LayerFreezePlan, all_active,
                                    lm_segments)
from repro.core.lazytune import LazyTune, LazyTuneConfig
from repro.core.ood import EnergyOODConfig, EnergyOODDetector
from repro.core.policies import (PolicySpec, PolicyStack, PolicyStackSpec,
                                 adapt_controller, etuner_stack_spec)
from repro.core.simfreeze import SimFreeze, SimFreezeConfig

__all__ = [
    "cka", "layerwise_cka", "ControllerProtocol", "ETunerConfig",
    "ETunerController",
    "AccuracyCurve", "fit_accuracy_curve", "FreezePlan", "LayerFreezePlan",
    "all_active", "lm_segments", "LazyTune", "LazyTuneConfig",
    "EnergyOODConfig", "EnergyOODDetector", "SimFreeze", "SimFreezeConfig",
    "PolicyStack", "PolicySpec", "PolicyStackSpec", "etuner_stack_spec",
    "adapt_controller",
]
