"""SimFreeze — the intra-tuning optimization (paper §IV-B, Algorithm 1).

Tracks per-layer CKA between the model under fine-tuning and the frozen
*reference* (initial) model, on a fixed per-scenario probe batch (the first
training batch of the scenario):

- every ``freeze_interval`` training iterations, recompute CKA for each
  *active* layer; a layer whose CKA variation rate is below ``cka_threshold``
  (default 1%) is converged -> freeze (Alg. 1 l.4-9);
- on a scenario change, recompute CKA for each *frozen* layer on the new
  scenario's probe batch; if it moved by more than the threshold, unfreeze
  (Alg. 1 l.22-26).

The output is a FreezePlan / LayerFreezePlan consumed by the execution
engine (core/freeze_plan.py) and the optimizer, so freezing translates
into skipped backward FLOPs, skipped gradient all-reduce chunks, and
skipped optimizer updates (DESIGN.md §2)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List

import numpy as np

from repro.core.cka import cka as _cka
from repro.core.freeze_plan import FreezePlan, LayerFreezePlan


@dataclass
class SimFreezeConfig:
    cka_threshold: float = 0.01      # 1% variation rate (paper default)
    freeze_interval: int = 200       # iterations between freezing passes
    min_history: int = 2             # CKA points before a freeze decision
    never_freeze_head: bool = True   # classifier/lm head keeps training
    use_kernel: bool = False         # route CKA through the Pallas kernel


@dataclass
class SimFreezeState:
    frozen: List[bool]
    cka_history: List[List[float]]   # per layer
    iters_since_pass: int = 0
    freezes: int = 0
    unfreezes: int = 0
    cka_flops: float = 0.0           # bookkeeping for the overhead account


class SimFreeze:
    """`features_fn(params, probe_batch) -> [acts per layer]` must present
    layers in execution order; layer i here is freeze-unit i of the model
    (groups for scanned LMs, layers for unrolled paper models)."""

    def __init__(self, num_units: int, features_fn: Callable,
                 config: SimFreezeConfig = SimFreezeConfig(),
                 scan_mode: bool = False):
        self.cfg = config
        self.num_units = num_units
        self.features_fn = features_fn
        self.scan_mode = scan_mode
        self.state = SimFreezeState(
            frozen=[False] * num_units,
            cka_history=[[] for _ in range(num_units)])
        self.reference_params = None
        self.probe_batch = None
        self._ref_feats = None

    # -- lifecycle -----------------------------------------------------------
    def start_scenario(self, reference_params, probe_batch) -> None:
        """Set the reference model and per-scenario CKA probe data
        (paper: 'the first arrived training data batch')."""
        self.reference_params = reference_params
        self.probe_batch = probe_batch
        self._ref_feats = [np.asarray(f, np.float32)
                           for f in self.features_fn(reference_params, probe_batch)]
        for h in self.state.cka_history:
            h.clear()

    # -- Alg.1 l.4-9: periodic freezing pass ----------------------------------
    def maybe_freeze(self, params, iters_elapsed: int) -> bool:
        """Returns True if the plan changed."""
        st = self.state
        st.iters_since_pass += iters_elapsed
        if st.iters_since_pass < self.cfg.freeze_interval:
            return False
        st.iters_since_pass = 0
        return self._freeze_pass(params)

    def _layer_cka(self, params, unit: int) -> float:
        feats = self.features_fn(params, self.probe_batch)
        return float(_cka(feats[unit], self._ref_feats[unit],
                                 use_kernel=self.cfg.use_kernel))

    def _all_cka(self, params) -> List[float]:
        feats = self.features_fn(params, self.probe_batch)
        vals = []
        for f, rf in zip(feats, self._ref_feats):
            vals.append(float(_cka(f, rf, use_kernel=self.cfg.use_kernel)))
            self.state.cka_flops += 2.0 * np.prod(np.shape(f)) * min(
                np.shape(np.asarray(f).reshape(-1, np.shape(f)[-1]))[0],
                np.shape(f)[-1])
        return vals

    def _freeze_pass(self, params) -> bool:
        st, cfg = self.state, self.cfg
        vals = self._all_cka(params)
        changed = False
        for i, v in enumerate(vals):
            st.cka_history[i].append(v)
            if st.frozen[i]:
                continue  # paper §III-B: stay frozen within a scenario
            h = st.cka_history[i]
            if len(h) < cfg.min_history:
                continue
            prev = h[-2]
            variation = abs(v - prev) / max(abs(prev), 1e-8)
            if variation <= cfg.cka_threshold:
                st.frozen[i] = True
                st.freezes += 1
                changed = True
        return changed

    # -- Alg.1 l.22-26: unfreezing on scenario change -------------------------
    def scenario_changed(self, params, new_probe_batch) -> bool:
        """Re-evaluate frozen layers on the new scenario's probe data."""
        st, cfg = self.state, self.cfg
        old_vals = {i: st.cka_history[i][-1]
                    for i in range(self.num_units)
                    if st.frozen[i] and st.cka_history[i]}
        self.probe_batch = new_probe_batch
        self._ref_feats = [np.asarray(f, np.float32) for f in
                           self.features_fn(self.reference_params, new_probe_batch)]
        vals = self._all_cka(params)
        changed = False
        for i in range(self.num_units):
            if not st.frozen[i]:
                continue
            old = old_vals.get(i)
            if old is None:
                continue
            variation = abs(vals[i] - old) / max(abs(old), 1e-8)
            if variation > cfg.cka_threshold:
                st.frozen[i] = False
                st.unfreezes += 1
                changed = True
        for h in st.cka_history:
            h.clear()
        for i, v in enumerate(vals):
            st.cka_history[i].append(v)
        return changed

    # -- plan export -----------------------------------------------------------
    def plan(self):
        if self.scan_mode:
            return FreezePlan(groups=tuple(self.state.frozen))
        flags = list(self.state.frozen)
        if self.cfg.never_freeze_head:
            flags = flags[:-1] + [False] if len(flags) == self.num_units else flags
        return LayerFreezePlan(layers=tuple(flags))

    def frozen_fraction(self) -> float:
        return sum(self.state.frozen) / max(self.num_units, 1)
