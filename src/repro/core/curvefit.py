"""Optimus-style non-linear accuracy-curve model fit with NNLS
(paper §IV-A1, following Peng et al., EuroSys'18 and the Ekya estimator).

We model validation accuracy after k cumulative training iterations as

    acc(k) = c0 - c1 / (k + 1) - c2 / (k + 1)^2 ,   c1, c2 >= 0

which is linear in (c0, c1, c2) over the basis [1, -1/(k+1), -1/(k+1)^2];
the non-negativity of (c1, c2) makes the curve monotonically increasing
and saturating — exactly the "improves quickly early, saturates late"
shape of paper Fig. 4. Fitting uses ``scipy.optimize.nnls`` (the solver
the paper cites). The fitted curve extrapolates the accuracy gain of
fine-tuning with a given amount of additional data, which LazyTune inverts
to size the next round (``batches_needed``)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np
from scipy.optimize import nnls


@dataclass
class AccuracyCurve:
    c0: float
    c1: float
    c2: float

    def predict(self, k) -> np.ndarray:
        k = np.asarray(k, np.float64)
        return self.c0 - self.c1 / (k + 1.0) - self.c2 / (k + 1.0) ** 2

    def gain(self, k_from: float, k_to: float) -> float:
        return float(self.predict(k_to) - self.predict(k_from))

    def iters_for_gain(self, k_now: float, target_gain: float,
                       k_max: float = 1e7) -> float:
        """Smallest k' > k_now with predict(k') - predict(k_now) >= gain,
        found by bisection on the monotone curve; returns k_max if the
        asymptote can't deliver the gain."""
        base = float(self.predict(k_now))
        if float(self.predict(k_max)) - base < target_gain:
            return k_max
        lo, hi = k_now, k_max
        for _ in range(64):
            mid = 0.5 * (lo + hi)
            if float(self.predict(mid)) - base >= target_gain:
                hi = mid
            else:
                lo = mid
        return hi


def fit_accuracy_curve(iters: Sequence[float],
                       accs: Sequence[float]) -> Optional[AccuracyCurve]:
    """NNLS fit. Needs >= 2 points; returns None when underdetermined."""
    iters = np.asarray(iters, np.float64)
    accs = np.asarray(accs, np.float64)
    if iters.size < 2:
        return None
    k1 = 1.0 / (iters + 1.0)
    # Basis chosen so all three coefficients are constrained >= 0.
    A = np.stack([np.ones_like(iters), -k1, -k1 ** 2], axis=1)
    # nnls constrains x >= 0; c0 >= 0 is natural for accuracy.
    try:
        x, _ = nnls(A, accs)
    except Exception:
        return None
    return AccuracyCurve(c0=float(x[0]), c1=float(x[1]), c2=float(x[2]))
