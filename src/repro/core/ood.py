"""Energy-score scenario-change detection (paper §IV-A3, following
Liu et al., NeurIPS'20 "Energy-based Out-of-distribution Detection").

E(x) = -logsumexp(logits(x)): in-distribution inputs score low, OOD inputs
score high. We keep a running mean/std of energies of served inference
requests and flag a scenario change when a window of recent requests drifts
above a z-score threshold. The scenario boundary therefore "comes with and
is determined by the inference data" exactly as in the paper."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np


@dataclass
class EnergyOODConfig:
    window: int = 8            # recent requests considered
    warmup: int = 16           # energies before detection activates
    z_threshold: float = 3.0   # window-mean z-score that flags a change
    cooldown: int = 16         # requests to ignore after a detection


class EnergyOODDetector:
    def __init__(self, config: EnergyOODConfig = EnergyOODConfig()):
        self.cfg = config
        self._recent = deque(maxlen=config.window)
        self._count = 0
        self._mean = 0.0
        self._m2 = 0.0
        self._cooldown = 0
        self.detections = 0
        # (mean, std) snapshotted at the last detection, *before* the
        # stats reset — the baseline a dedicated confirmation probe is
        # z-tested against (detector-driven probes, DESIGN.md)
        self._baseline = None

    @staticmethod
    def energy(logits: np.ndarray) -> float:
        """Mean energy score of a batch of logits [B, C]."""
        logits = np.asarray(logits, np.float64)
        m = logits.max(axis=-1, keepdims=True)
        lse = m[..., 0] + np.log(np.exp(logits - m).sum(axis=-1))
        return float(np.mean(-lse))

    def observe(self, logits: np.ndarray) -> bool:
        """Feed logits of one served request; True => scenario change."""
        e = self.energy(logits)
        self._recent.append(e)
        if self._cooldown > 0:
            self._cooldown -= 1
            self._update_stats(e)
            return False
        if self._count < self.cfg.warmup or len(self._recent) < self.cfg.window:
            self._update_stats(e)
            return False
        std = max(np.sqrt(self._m2 / max(self._count - 1, 1)), 1e-6)
        z = (np.mean(self._recent) - self._mean) / std
        if z > self.cfg.z_threshold:
            self.detections += 1
            self._baseline = (self._mean, std)
            self._reset_stats()
            self._cooldown = self.cfg.cooldown
            return True
        self._update_stats(e)
        return False

    def confirm(self, logits: np.ndarray) -> bool:
        """Side-effect-free drift check for a *dedicated* confirmation
        probe (detector-driven probes): z-test the probe pass's energy
        against the baseline snapshotted at the triggering detection.
        Never perturbs the running request statistics; True before any
        detection happened (nothing to refute the trigger with)."""
        if self._baseline is None:
            return True
        mean, std = self._baseline
        return (self.energy(logits) - mean) / std > self.cfg.z_threshold

    def _update_stats(self, e: float) -> None:
        self._count += 1
        delta = e - self._mean
        self._mean += delta / self._count
        self._m2 += delta * (e - self._mean)

    def _reset_stats(self) -> None:
        self._count = 0
        self._mean = 0.0
        self._m2 = 0.0
        self._recent.clear()
