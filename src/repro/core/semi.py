"""Semi-supervised continual learning (paper §IV-C): SimSiam-style
self-supervised objective on unlabeled data, followed by supervised
fine-tuning on the labeled portion.

SimSiam (Chen & He, CVPR'21): two augmented views, a projector + predictor
head, negative-cosine loss with a stop-gradient on the target branch. Our
augmentations are jax-native (random crop-shift + flip + channel jitter)
so the whole objective jits."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import common


def init_simsiam_head(key, feat_dim: int, proj_dim: int = 64) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "proj_w": common.dense_init(k1, feat_dim, (feat_dim, proj_dim), jnp.float32),
        "proj_b": jnp.zeros((proj_dim,), jnp.float32),
        "pred_w": common.dense_init(k2, proj_dim, (proj_dim, proj_dim), jnp.float32),
        "pred_b": jnp.zeros((proj_dim,), jnp.float32),
    }


def augment(rng, images: jax.Array) -> jax.Array:
    """Random shift + horizontal flip + brightness jitter. [B,H,W,C]."""
    k1, k2, k3 = jax.random.split(rng, 3)
    B, H, W, C = images.shape
    # shift by up to 12.5% via pad+dynamic crop
    pad = max(H // 8, 1)
    padded = jnp.pad(images, ((0, 0), (pad, pad), (pad, pad), (0, 0)), "edge")
    off = jax.random.randint(k1, (2,), 0, 2 * pad)
    imgs = jax.lax.dynamic_slice(padded, (0, off[0], off[1], 0), (B, H, W, C))
    flip = jax.random.bernoulli(k2)
    imgs = jnp.where(flip, imgs[:, :, ::-1, :], imgs)
    bright = 1.0 + 0.2 * jax.random.uniform(k3, (B, 1, 1, 1), minval=-1.0)
    return imgs * bright


def _neg_cosine(p: jax.Array, z: jax.Array) -> jax.Array:
    p = p / (jnp.linalg.norm(p, axis=-1, keepdims=True) + 1e-8)
    z = z / (jnp.linalg.norm(z, axis=-1, keepdims=True) + 1e-8)
    return -jnp.mean(jnp.sum(p * jax.lax.stop_gradient(z), axis=-1))


def simsiam_loss(backbone_feats_fn: Callable, head: dict, params,
                 rng, images: jax.Array) -> jax.Array:
    """backbone_feats_fn(params, images) -> pooled features [B, F]."""
    k1, k2 = jax.random.split(rng)
    v1, v2 = augment(k1, images), augment(k2, images)
    f1 = backbone_feats_fn(params, v1)
    f2 = backbone_feats_fn(params, v2)
    z1 = f1 @ head["proj_w"] + head["proj_b"]
    z2 = f2 @ head["proj_w"] + head["proj_b"]
    p1 = z1 @ head["pred_w"] + head["pred_b"]
    p2 = z2 @ head["pred_w"] + head["pred_b"]
    return 0.5 * (_neg_cosine(p1, z2) + _neg_cosine(p2, z1))
