"""LazyTune — the inter-tuning optimization (paper §IV-A, Algorithm 1).

State machine over three signals:

1. *Per-round accuracy trend* (Alg. 1 l.10-12): after each fine-tuning
   round, record (cumulative iterations, validation accuracy), refit the
   NNLS accuracy curve, and set ``batches_needed`` so the *next* round is
   predicted to gain as much accuracy as the current round did.
2. *Inference arrival pattern* (Alg. 1 l.13-18): every inference request
   decays ``batches_needed`` via the logarithmic backoff
   d <- d * (1 - 1/log(d)) so request bursts force frequent updates.
3. *Scenario change* (Alg. 1 l.19-21): reset ``batches_needed`` to 1
   (immediate fine-tuning) for fast adaptation.

The controller is pure-Python bookkeeping (no jax) — it *schedules* jitted
work, it never sits inside it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.curvefit import AccuracyCurve, fit_accuracy_curve


@dataclass
class LazyTuneConfig:
    initial_batches_needed: float = 1.0
    max_batches_needed: float = 64.0
    iters_per_batch: int = 1          # training iterations per data batch
    min_gain_floor: float = 1e-4      # treat gains below this as saturation


@dataclass
class LazyTuneState:
    batches_needed: float = 1.0
    cum_iters: float = 0.0
    history_iters: List[float] = field(default_factory=list)
    history_accs: List[float] = field(default_factory=list)
    last_gain: Optional[float] = None
    curve: Optional[AccuracyCurve] = None
    rounds_triggered: int = 0
    rounds_delayed: int = 0


class LazyTune:
    def __init__(self, config: LazyTuneConfig = LazyTuneConfig()):
        self.cfg = config
        self.state = LazyTuneState(batches_needed=config.initial_batches_needed)

    # -- Alg.1 line 2: trigger predicate ------------------------------------
    def should_trigger(self, batches_available: int) -> bool:
        trig = batches_available >= self.state.batches_needed
        if not trig and batches_available > 0:
            self.state.rounds_delayed += 1
        return trig

    # -- Alg.1 lines 10-12: after a round, re-estimate batches_needed -------
    def round_finished(self, iters_this_round: int, val_acc: float) -> None:
        st = self.state
        st.rounds_triggered += 1
        prev_acc = st.history_accs[-1] if st.history_accs else None
        st.cum_iters += iters_this_round
        st.history_iters.append(st.cum_iters)
        st.history_accs.append(val_acc)
        if prev_acc is not None:
            st.last_gain = val_acc - prev_acc
        st.curve = fit_accuracy_curve(st.history_iters, st.history_accs)
        st.batches_needed = self._estimate_batches_needed()

    def _estimate_batches_needed(self) -> float:
        st, cfg = self.state, self.cfg
        if st.curve is None or st.last_gain is None:
            return st.batches_needed  # not enough data yet
        target_gain = max(st.last_gain, cfg.min_gain_floor)
        k_next = st.curve.iters_for_gain(st.cum_iters, target_gain)
        need = (k_next - st.cum_iters) / max(cfg.iters_per_batch, 1)
        return float(min(max(need, 1.0), cfg.max_batches_needed))

    # -- Alg.1 lines 15-18: logarithmic decay on inference arrival ----------
    def inference_arrived(self) -> None:
        d = self.state.batches_needed
        if d > math.e:  # log(d) > 1 required for a positive decrease
            d = d * (1.0 - 1.0 / math.log(d))
        else:
            d = 1.0
        self.state.batches_needed = max(1.0, d)

    # -- Alg.1 lines 20-21: scenario change reset ----------------------------
    def scenario_changed(self) -> None:
        self.state.batches_needed = self.cfg.initial_batches_needed
        # accuracy history restarts: the curve of the old scenario does not
        # predict the new one (paper Fig. 4 shows the post-change drop).
        self.state.history_iters.clear()
        self.state.history_accs.clear()
        self.state.curve = None
        self.state.last_gain = None
