"""FineTuneExecutor — round execution for the continual-learning loop.

Owns the training state (params/optimizer), the pending-batch buffer, the
anti-forgetting replay buffer, and the per-round mechanics: plan-aware
jitted steps (via TrainStepCache), XLA-measured FLOPs, cost-model
calibration and the `CostLedger` charge. Orthogonal training behaviours —
the semi-supervised SimSiam pass on unlabeled batches (paper §IV-C) and
simulated quantization-aware training (paper §V-G) — are composable
`RoundHook`s rather than special cases inlined in the event loop.

The executor is timeline-agnostic: it receives `now` and an
`EventScheduler` to reserve device time on, and reports what it did via
`RoundReport`; publishing the new params to serving, validation and
controller notification stay in the composition root
(runtime/continual.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.costmodel import EdgeCostModel
from repro.runtime.ledger import CostLedger
from repro.runtime.train_loop import TrainStepCache, as_jnp


# ---------------------------------------------------------------------------
# replay buffer (documented substitution for CORe50's CWR; DESIGN.md §4)


class ReplayBuffer:
    """Small reservoir of past batches mixed into each round (one sampled
    batch per round) so new-scenario tuning does not erase old scenarios."""

    def __init__(self, batches: Sequence[dict] = (), capacity: int = 6):
        self._items: List[dict] = list(batches)
        self.capacity = capacity

    def add(self, batch: dict) -> None:
        if len(self._items) < self.capacity:
            self._items.append(batch)

    def sample(self, rng: np.random.Generator) -> dict:
        return self._items[rng.integers(len(self._items))]

    def __len__(self) -> int:
        return len(self._items)


# ---------------------------------------------------------------------------
# round hooks


class RoundHook:
    """Composable per-round behaviour. Lifecycle:

    - `bind(model)` once at construction time; may return a *wrapped*
      model (the executor and serving path then use the wrapped one);
    - `on_round_start(round_index)` before each round's batch loop;
    - `process_batch(params, batch, jnp_batch)` per batch: return updated
      params to claim the batch (the supervised step is skipped), or None
      to pass.
    """

    def bind(self, model):
        return model

    def on_round_start(self, round_index: int) -> None:
        pass

    def process_batch(self, params, batch: dict, jnp_batch: dict):
        return None


class SimSiamHook(RoundHook):
    """Semi-supervised rounds (paper §IV-C): with probability
    `unlabeled_fraction`, an image batch is treated as unlabeled and gets a
    SimSiam self-supervised update instead of the supervised step."""

    def __init__(self, unlabeled_fraction: float):
        self.unlabeled_fraction = unlabeled_fraction
        self.model = None
        self._head = None
        self._step = None
        self._rng = np.random.default_rng(17)

    def bind(self, model):
        self.model = model
        return model

    def on_round_start(self, round_index: int) -> None:
        # deterministic per-round labeled/unlabeled split
        self._rng = np.random.default_rng(round_index + 17)

    def process_batch(self, params, batch, jnp_batch):
        if self.unlabeled_fraction and "images" in batch and \
                self._rng.random() < self.unlabeled_fraction:
            return self._semi_update(params, jnp_batch)
        return None

    def _semi_update(self, params, batch):
        from repro.core import semi

        if self._head is None:
            feats = self.model.features(params, batch)
            fdim = int(np.asarray(feats[-1]).reshape(
                np.asarray(feats[-1]).shape[0], -1).shape[-1])
            self._feat_dim = min(fdim, 256)
            self._head = semi.init_simsiam_head(
                jax.random.PRNGKey(1), self._feat_dim)
            model = self.model

            def pooled(p, images):
                fs = model.features(p, {"images": images})
                f = fs[-1]
                f = f.reshape(f.shape[0], -1)
                return f[:, :self._feat_dim].astype(jnp.float32)

            def semi_step(p, head, rng, images):
                def lf(q):
                    return semi.simsiam_loss(pooled, head, q, rng, images)

                g = jax.grad(lf)(p)
                return jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - 1e-3 * b.astype(jnp.float32)).astype(a.dtype),
                    p, g)

            self._step = jax.jit(semi_step)
        rng = jax.random.PRNGKey(int(np.random.default_rng(0).integers(1 << 30)))
        return self._step(params, self._head, rng, batch["images"])


class FakeQuantHook(RoundHook):
    """Simulated quantization-aware training (paper §V-G, Table VIII): the
    model's loss/predict see fake-quantized params (straight-through
    estimator keeps gradients alive). Purely a model wrap — no per-batch
    work."""

    def __init__(self, bits: int):
        self.bits = bits

    def bind(self, model):
        return quantized_model(model, self.bits)


# ---------------------------------------------------------------------------
# executor


@dataclass
class RoundReport:
    iters: int
    flops: float
    time_s: float
    energy_j: float
    recompiled: bool
    start: float
    end: float


class FineTuneExecutor:
    def __init__(self, steps: TrainStepCache, cost: EdgeCostModel,
                 ledger: CostLedger, replay: ReplayBuffer, *,
                 rng: np.random.Generator,
                 hooks: Sequence[RoundHook] = (),
                 calibrate_cost: bool = True):
        self.steps = steps
        self.cost = cost
        self.ledger = ledger
        self.replay = replay
        self.rng = rng
        self.hooks = list(hooks)
        self.calibrate_cost = calibrate_cost
        # pending batches, bucketed by arrival stream: a round drains one
        # stream's bucket (multi-stream workloads share the device and the
        # params, but trigger and account per stream)
        self.buffers: Dict[int, List[dict]] = {}
        self.compiled_plans = set()
        self.params = None
        self.opt_state = None

    # ---- state -----------------------------------------------------------
    def load(self, params, opt_state) -> None:
        self.params = params
        self.opt_state = opt_state

    def enqueue(self, batch: dict, stream: int = 0) -> None:
        self.buffers.setdefault(stream, []).append(batch)

    @property
    def pending(self) -> int:
        """Total buffered batches across all streams."""
        return sum(len(b) for b in self.buffers.values())

    def pending_for(self, stream: int) -> int:
        return len(self.buffers.get(stream, ()))

    @property
    def pending_streams(self) -> List[int]:
        return sorted(s for s, b in self.buffers.items() if b)

    # ---- round -----------------------------------------------------------
    def execute_round(self, plan, now: float, scheduler,
                      stream: int = 0) -> Optional[RoundReport]:
        """Train one round on everything buffered for `stream` (plus one
        replay batch), charge the ledger (attributed to that stream), and
        reserve device time on the scheduler. Returns None when nothing is
        buffered."""
        if not self.buffers.get(stream):
            return None
        recompile = 0
        if plan not in self.compiled_plans:
            self.compiled_plans.add(plan)
            recompile = 1
        step = self.steps.get(plan)
        batches = self.buffers.pop(stream)
        if self.replay:
            batches.append(self.replay.sample(self.rng))
        for h in self.hooks:
            h.on_round_start(self.ledger.rounds)
        for b in batches:
            jb = as_jnp(b)
            handled = None
            for h in self.hooks:
                handled = h.process_batch(self.params, b, jb)
                if handled is not None:
                    self.params = handled
                    break
            if handled is None:
                self.params, self.opt_state, _ = step(self.params,
                                                      self.opt_state, jb)
        flops = self.steps.flops(plan, as_jnp(batches[0])) * len(batches)
        if self.calibrate_cost:
            # Preserve the paper's compute/overhead balance (Fig. 3) at
            # reduced model scale: scale the device throughput so a
            # 2-iteration immediate round spends ~0.8 s in compute vs the
            # 1.1 s fixed overheads (58%/42% split). DESIGN.md §3.
            per_iter = flops / max(len(batches), 1)
            self.cost = dataclasses.replace(
                self.cost, flops_per_sec=max(per_iter * 2 / 0.8, 1.0))
            self.calibrate_cost = False
        t, e, parts = self.cost.round_cost(flops, recompiles=recompile)
        self.ledger.charge_round(flops=flops, time_s=t, energy_j=e,
                                 parts=parts, stream=stream)
        start, end = scheduler.occupy(now, t)
        return RoundReport(iters=len(batches), flops=flops, time_s=t,
                           energy_j=e, recompiled=bool(recompile),
                           start=start, end=end)


# ---------------------------------------------------------------------------
# simulated quantization-aware training (paper §V-G, Table VIII)


def fake_quant(x, bits: int):
    if x.dtype not in (jnp.float32, jnp.bfloat16):
        return x
    xf = x.astype(jnp.float32)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8) / qmax
    q = jnp.round(xf / scale) * scale
    return (xf + jax.lax.stop_gradient(q - xf)).astype(x.dtype)  # STE


def quantized_model(model, bits: int):
    def loss(params, batch, plan=None):
        qp = jax.tree.map(lambda p: fake_quant(p, bits), params)
        return model.loss(qp, batch, plan)

    def predict(params, batch):
        qp = jax.tree.map(lambda p: fake_quant(p, bits), params)
        return model.predict(qp, batch)

    return dataclasses.replace(model, loss=loss, predict=predict)
