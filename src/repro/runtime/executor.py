"""FineTuneExecutor — round execution for the continual-learning loop.

Owns the training state (params/optimizer), the pending-batch buffer, the
anti-forgetting replay buffer, and the per-round mechanics: plan-aware
jitted steps (via TrainStepCache), XLA-measured FLOPs, cost-model
calibration and the `CostLedger` charge. Orthogonal training behaviours —
the semi-supervised SimSiam pass on unlabeled batches (paper §IV-C) and
simulated quantization-aware training (paper §V-G) — are composable
`RoundHook`s rather than special cases inlined in the event loop.

The executor is timeline-agnostic: it receives `now` and an
`EventScheduler` to reserve device time on, and reports what it did via
`RoundReport`; publishing the new params to serving, validation and
controller notification stay in the composition root
(runtime/continual.py).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.runtime.costmodel import EdgeCostModel
from repro.runtime.ledger import DEFAULT_DEVICE, DEFAULT_MODEL, CostLedger
from repro.runtime.train_loop import (TrainStepCache, as_jnp,
                                      same_shape_runs)


# ---------------------------------------------------------------------------
# replay buffer (documented substitution for CORe50's CWR; DESIGN.md §4)


class ReplayBuffer:
    """Small reservoir of past batches mixed into each round (one sampled
    batch per round) so new-scenario tuning does not erase old scenarios."""

    def __init__(self, batches: Sequence[dict] = (), capacity: int = 6):
        self._items: List[dict] = list(batches)
        self.capacity = capacity

    def add(self, batch: dict) -> None:
        if len(self._items) < self.capacity:
            self._items.append(batch)

    def sample(self, rng: np.random.Generator) -> dict:
        return self._items[rng.integers(len(self._items))]

    def __len__(self) -> int:
        return len(self._items)


# ---------------------------------------------------------------------------
# round hooks


class RoundHook:
    """Composable per-round behaviour. Lifecycle:

    - `bind(model)` once at construction time; may return a *wrapped*
      model (the executor and serving path then use the wrapped one);
    - `on_round_start(round_index)` before each round's batch loop;
    - `process_batch(params, batch, jnp_batch)` per batch: return updated
      params to claim the batch (the supervised step is skipped), or None
      to pass.
    """

    def bind(self, model):
        return model

    def on_round_start(self, round_index: int) -> None:
        pass

    def process_batch(self, params, batch: dict, jnp_batch: dict):
        return None


class SimSiamHook(RoundHook):
    """Semi-supervised rounds (paper §IV-C): with probability
    `unlabeled_fraction`, an image batch is treated as unlabeled and gets a
    SimSiam self-supervised update instead of the supervised step."""

    def __init__(self, unlabeled_fraction: float, *, donate: bool = True):
        self.unlabeled_fraction = unlabeled_fraction
        self.donate = donate  # donate params in the jitted semi step
        self.model = None
        self._head = None
        self._step = None
        self._rng = np.random.default_rng(17)

    def bind(self, model):
        self.model = model
        return model

    def on_round_start(self, round_index: int) -> None:
        # deterministic per-round labeled/unlabeled split
        self._rng = np.random.default_rng(round_index + 17)

    def process_batch(self, params, batch, jnp_batch):
        if self.unlabeled_fraction and "images" in batch and \
                self._rng.random() < self.unlabeled_fraction:
            return self._semi_update(params, jnp_batch)
        return None

    def _semi_update(self, params, batch):
        from repro.core import semi

        if self._head is None:
            feats = self.model.features(params, batch)
            fdim = int(np.asarray(feats[-1]).reshape(
                np.asarray(feats[-1]).shape[0], -1).shape[-1])
            self._feat_dim = min(fdim, 256)
            self._head = semi.init_simsiam_head(
                jax.random.PRNGKey(1), self._feat_dim)
            model = self.model

            def pooled(p, images):
                fs = model.features(p, {"images": images})
                f = fs[-1]
                f = f.reshape(f.shape[0], -1)
                return f[:, :self._feat_dim].astype(jnp.float32)

            def semi_step(p, head, rng, images):
                def lf(q):
                    return semi.simsiam_loss(pooled, head, q, rng, images)

                g = jax.grad(lf)(p)
                return jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - 1e-3 * b.astype(jnp.float32)).astype(a.dtype),
                    p, g)

            # params are rebound by the caller right after the call, so
            # the input buffer is dead on return — donate it (no-op on CPU)
            self._step = jax.jit(
                semi_step, donate_argnums=(0,) if self.donate else ())
        rng = jax.random.PRNGKey(int(np.random.default_rng(0).integers(1 << 30)))
        return self._step(params, self._head, rng, batch["images"])


class FakeQuantHook(RoundHook):
    """Simulated quantization-aware training (paper §V-G, Table VIII): the
    model's loss/predict see fake-quantized params (straight-through
    estimator keeps gradients alive). Purely a model wrap — no per-batch
    work."""

    def __init__(self, bits: int):
        self.bits = bits

    def bind(self, model):
        return quantized_model(model, self.bits)


# ---------------------------------------------------------------------------
# executor


@dataclass
class RoundReport:
    iters: int
    flops: float
    time_s: float
    energy_j: float
    recompiled: bool
    start: float
    end: float
    stream: int = 0      # arrival stream whose buffer the round drained
    segments: int = 1    # occupancy segments (1 unless preempted)
    preemptions: int = 0  # higher-priority splits the round absorbed


class ActiveRound:
    """Checkpointed state of an in-flight *preemptible* round.

    The round's full cost (time/energy/FLOPs/parts) is fixed when it
    launches — preemption changes *when* the work runs, never how much —
    and is charged to the ledger in per-segment slices as occupancy
    elapses. `trained` is the checkpointed batch-iterator position:
    batches train lazily as the modeled timeline covers their completion
    point, so a preemption observes exactly the params the device would
    hold at that instant. The final segment charges the exact remainder
    of every cost component, so segments always sum to the unpreempted
    round's charge (a property test pins this)."""

    def __init__(self, step, plan, stream: int, batches, flops: float,
                 time_s: float, energy_j: float, parts, recompiled: bool,
                 reservation):
        self.step = step
        self.plan = plan
        self.stream = stream
        self.batches = batches
        self.trained = 0
        self.flops = flops
        self.time_s = time_s
        self.energy_j = energy_j
        self.parts = dict(parts)
        self.recompiled = recompiled
        self.reservation = reservation
        self.first_start = reservation.start
        self.seg_start = reservation.start
        self.segments = 0
        self.preemptions = 0
        self.charged = {"time_s": 0.0, "energy_j": 0.0, "flops": 0.0}
        self.charged_parts = {k: 0.0 for k in self.parts}

    @property
    def end(self) -> float:
        return self.reservation.end


class FineTuneExecutor:
    def __init__(self, steps: TrainStepCache, cost: EdgeCostModel,
                 ledger: CostLedger, replay: ReplayBuffer, *,
                 rng: np.random.Generator,
                 hooks: Sequence[RoundHook] = (),
                 calibrate_cost: bool = True,
                 model_name: str = DEFAULT_MODEL,
                 device_name: str = DEFAULT_DEVICE,
                 speed_scale: float = 1.0,
                 preempt_resume_cost_s: float = 0.0,
                 compiled: bool = False,
                 fuse: bool = True,
                 tracer=NULL_TRACER):
        self.steps = steps
        self.cost = cost
        self.ledger = ledger
        self.replay = replay
        self.rng = rng
        # observability (DESIGN.md §14): a live Tracer records round /
        # segment / resume spans on the modeled timeline, annotated with
        # wall-clock training time and recompiles; the falsy NULL_TRACER
        # default keeps every guarded site allocation-free.
        self.tracer = tracer
        self.hooks = list(hooks)
        self.calibrate_cost = calibrate_cost
        # compiled hot path (DESIGN.md §12): every supervised update goes
        # through the scan-based fused step — `fuse` additionally batches
        # each maximal same-shape run of a round into one dispatch, and
        # can be dropped per-run (segment-split fallback) without moving
        # a single bit, since both are the same scan program
        self.compiled = bool(compiled)
        self.fuse = bool(fuse)
        # model-slot attribution key for every ledger charge this executor
        # makes (ModelPool runs one executor per slot; single-model runs
        # keep the "default" slot)
        self.model_name = model_name
        # fleet-device attribution key + relative throughput: every ledger
        # charge and scheduler occupancy lands on this device, and cost
        # calibration multiplies flops_per_sec by `speed_scale` so a fast
        # device finishes the same round sooner (DESIGN.md §13). The
        # defaults ("dev0", 1.0) are a bitwise no-op for seed-era runs.
        self.device_name = device_name
        self.speed_scale = float(speed_scale)
        # modeled checkpoint-resume overhead paid on each preemption split
        # (0.0 = the legacy free split; see `preempt`)
        self.preempt_resume_cost_s = float(preempt_resume_cost_s)
        # pending batches, bucketed by arrival stream: a round drains one
        # stream's bucket (multi-stream workloads share the device and the
        # params, but trigger and account per stream)
        self.buffers: Dict[int, List[dict]] = {}
        self.compiled_plans = set()
        self.params = None
        self.opt_state = None
        # in-flight preemptible round (at most one: the device is single)
        self.active_round: Optional[ActiveRound] = None

    # ---- state -----------------------------------------------------------
    def load(self, params, opt_state) -> None:
        self.params = params
        self.opt_state = opt_state

    def enqueue(self, batch: dict, stream: int = 0) -> None:
        self.buffers.setdefault(stream, []).append(batch)

    @property
    def pending(self) -> int:
        """Total buffered batches across all streams."""
        return sum(len(b) for b in self.buffers.values())

    def pending_for(self, stream: int) -> int:
        return len(self.buffers.get(stream, ()))

    @property
    def pending_streams(self) -> List[int]:
        return sorted(s for s, b in self.buffers.items() if b)

    # ---- round -----------------------------------------------------------
    def _own_buffers(self) -> None:
        """Donating steps consume their inputs. Params escape the
        executor between rounds — serving lanes hold the published
        object, `reference_params` is the pretrain result — so before a
        round's first donating dispatch we take exclusive copies; the
        escaped aliases stay live and every later dispatch in the round
        already owns its (freshly produced) buffers. One device copy per
        round, bitwise identical."""
        if getattr(self.steps, "donate", False):
            self.params = jax.tree.map(jnp.copy, self.params)
            self.opt_state = jax.tree.map(jnp.copy, self.opt_state)

    def _train_batch(self, step, plan, b: dict) -> None:
        """One training iteration: the first hook that claims the batch
        updates the params; otherwise the plan-aware supervised step
        (the trip-count-1 fused scan in compiled mode, so per-batch and
        segment-batched execution are the same program)."""
        jb = as_jnp(b)
        for h in self.hooks:
            handled = h.process_batch(self.params, b, jb)
            if handled is not None:
                self.params = handled
                return
        if self.compiled:
            self.params, self.opt_state, _ = self.steps.fused_call(
                plan, self.params, self.opt_state, [b])
            return
        self.params, self.opt_state, _ = step(self.params,
                                              self.opt_state, jb)

    def _run_batches(self, step, plan, batches: Sequence[dict]) -> None:
        """Train a round's batches. Compiled hook-free rounds batch each
        maximal run of same-shape batches into one fused scan dispatch;
        hooks claim batches one at a time (their RNG draws are order-
        dependent), so hook-bearing rounds stay per-batch."""
        if not (self.compiled and self.fuse) or self.hooks:
            for b in batches:
                self._train_batch(step, plan, b)
            return
        for run in same_shape_runs(batches):
            self.params, self.opt_state, _ = self.steps.fused_call(
                plan, self.params, self.opt_state, run)

    def _round_cost(self, plan, batches, recompile: int):
        """XLA-measured round FLOPs + (one-shot calibrated) modeled cost."""
        flops = self.steps.flops(plan, as_jnp(batches[0])) * len(batches)
        if self.calibrate_cost:
            # Preserve the paper's compute/overhead balance (Fig. 3) at
            # reduced model scale: scale the device throughput so a
            # 2-iteration immediate round spends ~0.8 s in compute vs the
            # 1.1 s fixed overheads (58%/42% split). DESIGN.md §3.
            per_iter = flops / max(len(batches), 1)
            self.cost = dataclasses.replace(
                self.cost,
                flops_per_sec=max(per_iter * 2 / 0.8, 1.0) * self.speed_scale)
            self.calibrate_cost = False
        t, e, parts = self.cost.round_cost(flops, recompiles=recompile)
        return flops, t, e, parts

    def estimate_round(self, plan, stream: int = 0):
        """Modeled ``(time_s, energy_j)`` the round `stream`'s buffer
        would cost if triggered now — replay batch and worst-case
        recompile included — without mutating any state (the one-shot
        cost calibration is mirrored, not applied). This is the
        `ThrottlePolicy`'s decision input (DESIGN.md §15); assuming the
        recompile makes the estimate a safe upper bound."""
        batches = self.buffers.get(stream)
        if not batches:
            return 0.0, 0.0
        n = len(batches) + (1 if self.replay else 0)
        flops = self.steps.flops(plan, as_jnp(batches[0])) * n
        cost = self.cost
        if self.calibrate_cost:
            per_iter = flops / max(n, 1)
            cost = dataclasses.replace(
                cost,
                flops_per_sec=max(per_iter * 2 / 0.8, 1.0) * self.speed_scale)
        recompile = 0 if plan in self.compiled_plans else 1
        t, e, _ = cost.round_cost(flops, recompiles=recompile)
        return t, e

    def execute_round(self, plan, now: float, scheduler, stream: int = 0,
                      *, priority: int = 0,
                      preemptible: bool = False) -> Optional[RoundReport]:
        """Train one round on everything buffered for `stream` (plus one
        replay batch), charge the ledger (attributed to that stream), and
        reserve device time on the scheduler. Returns None when nothing is
        buffered.

        With ``preemptible=True`` the round *launches* instead of running
        to completion: its cost is fixed and the device reserved up front
        (at the stream's `priority`), but batches train lazily as the
        timeline covers them, so a higher-priority arrival can split the
        occupancy (`preempt`) and the round completes only when
        `finalize_round` is called at/after its reservation's end. In
        that mode this method returns None and the caller polls
        `active_round` / `finalize_round`."""
        if not self.buffers.get(stream):
            return None
        assert self.active_round is None, "previous round not finalized"
        recompile = 0
        if plan not in self.compiled_plans:
            self.compiled_plans.add(plan)
            recompile = 1
        step = self.steps.get(plan)
        self._own_buffers()
        batches = self.buffers.pop(stream)
        if self.replay:
            batches.append(self.replay.sample(self.rng))
        for h in self.hooks:
            h.on_round_start(self.ledger.rounds)
        if not preemptible:
            # legacy synchronous path — bit-exact with the pre-QoS runtime
            wall = time.perf_counter() if self.tracer else 0.0
            self._run_batches(step, plan, batches)
            if self.tracer:
                wall = time.perf_counter() - wall
            flops, t, e, parts = self._round_cost(plan, batches, recompile)
            self.ledger.charge_round(flops=flops, time_s=t, energy_j=e,
                                     parts=parts, stream=stream,
                                     model=self.model_name,
                                     device=self.device_name)
            start, end = scheduler.occupy(now, t, stream=stream,
                                          priority=priority,
                                          device=self.device_name)
            if self.tracer:
                self.tracer.span("round", f"round/{self.model_name}",
                                 start, t, stream=stream,
                                 device=self.device_name,
                                 slot=self.model_name, iters=len(batches),
                                 recompiled=bool(recompile),
                                 wall_ms=round(wall * 1e3, 3))
            return RoundReport(iters=len(batches), flops=flops, time_s=t,
                               energy_j=e, recompiled=bool(recompile),
                               start=start, end=end, stream=stream)
        flops, t, e, parts = self._round_cost(plan, batches, recompile)
        res = scheduler.occupy(now, t, stream=stream, priority=priority,
                               preemptible=True, device=self.device_name)
        self.active_round = ActiveRound(step, plan, stream, batches, flops,
                                        t, e, parts, bool(recompile), res)
        return None

    def _advance_training(self, ar: ActiveRound, elapsed: float) -> None:
        """Train every batch whose modeled completion point lies within
        the first `elapsed` seconds of the round (uniform per-batch
        spread; mid-batch progress is carried by the time accounting, not
        re-done)."""
        n = len(ar.batches)
        target = min(n, int(n * elapsed / max(ar.time_s, 1e-12)))
        while ar.trained < target:
            self._train_batch(ar.step, ar.plan, ar.batches[ar.trained])
            ar.trained += 1

    def _charge_segment(self, ar: ActiveRound, seg_dur: float,
                        final: bool) -> None:
        """Charge one occupancy segment: proportional slices of every cost
        component, except the final segment which charges the exact
        remainder (so segments sum to the unpreempted round's charge with
        no float drift)."""
        if final:
            time_s = ar.time_s - ar.charged["time_s"]
            energy_j = ar.energy_j - ar.charged["energy_j"]
            flops = ar.flops - ar.charged["flops"]
            parts = {k: v - ar.charged_parts[k] for k, v in ar.parts.items()}
        else:
            f = seg_dur / max(ar.time_s, 1e-12)
            time_s, energy_j, flops = (ar.time_s * f, ar.energy_j * f,
                                       ar.flops * f)
            parts = {k: v * f for k, v in ar.parts.items()}
        self.ledger.charge_round_segment(flops=flops, time_s=time_s,
                                         energy_j=energy_j, parts=parts,
                                         stream=ar.stream,
                                         model=self.model_name,
                                         device=self.device_name,
                                         final=final)
        if self.tracer:
            # span duration = the *charged* time slice (not the raw
            # occupancy delta), so per-device span sums reconcile with the
            # ledger bit-for-bit even on the exact-remainder final segment
            self.tracer.span("segment", f"round/{self.model_name}",
                             ar.seg_start, time_s, stream=ar.stream,
                             device=self.device_name, slot=self.model_name,
                             seg=ar.segments, final=final,
                             recompiled=ar.recompiled)
        ar.charged["time_s"] += time_s
        ar.charged["energy_j"] += energy_j
        ar.charged["flops"] += flops
        for k, v in parts.items():
            ar.charged_parts[k] += v
        ar.segments += 1

    def preempt(self, t: float, scheduler, *,
                preempting_stream: Optional[int] = None) -> None:
        """A higher-priority arrival at time `t` splits the in-flight
        round: train the batches the device completed by `t`, charge the
        elapsed segment to the round's stream, and immediately re-occupy
        the remainder (the arrival only claims the preemption *point* —
        serving is instantaneous in this cost model). With the default
        `preempt_resume_cost_s == 0` a split is free and the round's end
        time is unchanged; a positive value models the checkpoint-resume
        overhead of a real split — the device pays it (occupied,
        non-preemptible) before the remainder resumes, the charge lands
        on the *preempting* stream (it caused the split) under
        `t_resume`/`e_resume`, and the round's end shifts by that much.
        Callers gate on `scheduler.can_preempt`."""
        ar = self.active_round
        assert ar is not None, "no active round to preempt"
        if t == ar.seg_start:
            # same-instant arrival: the round is already split at exactly
            # `t` (or has not yet run at all) — zero occupancy elapsed, so
            # there is no segment to charge and physically only one split;
            # the arrival is simply served at the existing preemption point
            return
        self._advance_training(ar, ar.charged["time_s"] + (t - ar.seg_start))
        self._charge_segment(ar, t - ar.seg_start, final=False)
        self.ledger.note_preemption(ar.stream)
        ar.preemptions += 1
        if self.tracer:
            self.tracer.instant("preempt", f"preempt/{self.model_name}", t,
                                stream=preempting_stream,
                                device=self.device_name,
                                slot=self.model_name,
                                preempted_stream=ar.stream)
        remaining = scheduler.preempt(t, self.device_name)
        resume = self.preempt_resume_cost_s
        if resume > 0.0:
            # the resume overhead is a separate charge (the round's own
            # cost stays conserved across however many splits it absorbs)
            # billed to whoever forced the split
            payer = ar.stream if preempting_stream is None \
                else preempting_stream
            self.ledger.charge_probe(
                "resume", resume, resume * self.cost.overhead_power_w,
                stream=payer, model=self.model_name,
                device=self.device_name)
            r = scheduler.occupy(t, resume, stream=payer,
                                 priority=ar.reservation.priority,
                                 device=self.device_name)
            if self.tracer:
                self.tracer.span("resume", f"resume/{self.model_name}",
                                 r.start, resume, stream=payer,
                                 device=self.device_name,
                                 slot=self.model_name)
        ar.reservation = scheduler.occupy(
            t, remaining, stream=ar.stream,
            priority=ar.reservation.priority, preemptible=True,
            device=self.device_name)
        # segment bookkeeping resumes where the round's work does (after
        # any resume overhead), so segment durations stay pure round time
        ar.seg_start = ar.reservation.start

    def finalize_round(self, now: Optional[float] = None
                       ) -> Optional[RoundReport]:
        """Complete the in-flight preemptible round: train the remaining
        batches, charge the final segment (exact remainder), and report.
        No-op (None) when no round is active or, if `now` is given, while
        the reservation has not yet elapsed (``now < end``)."""
        ar = self.active_round
        if ar is None or (now is not None and now < ar.end):
            return None
        # preemption boundaries fall back to per-batch (trip-count-1)
        # execution of the same scan program — QoS semantics untouched
        while ar.trained < len(ar.batches):
            self._train_batch(ar.step, ar.plan, ar.batches[ar.trained])
            ar.trained += 1
        self._charge_segment(ar, ar.end - ar.seg_start, final=True)
        self.active_round = None
        return RoundReport(iters=len(ar.batches), flops=ar.flops,
                           time_s=ar.time_s, energy_j=ar.energy_j,
                           recompiled=ar.recompiled, start=ar.first_start,
                           end=ar.end, stream=ar.stream,
                           segments=ar.segments, preemptions=ar.preemptions)


# ---------------------------------------------------------------------------
# simulated quantization-aware training (paper §V-G, Table VIII)


def fake_quant(x, bits: int):
    if x.dtype not in (jnp.float32, jnp.bfloat16):
        return x
    xf = x.astype(jnp.float32)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8) / qmax
    q = jnp.round(xf / scale) * scale
    return (xf + jax.lax.stop_gradient(q - xf)).astype(x.dtype)  # STE


def quantized_model(model, bits: int):
    def loss(params, batch, plan=None):
        qp = jax.tree.map(lambda p: fake_quant(p, bits), params)
        return model.loss(qp, batch, plan)

    def predict(params, batch):
        qp = jax.tree.map(lambda p: fake_quant(p, bits), params)
        return model.predict(qp, batch)

    return dataclasses.replace(model, loss=loss, predict=predict)
