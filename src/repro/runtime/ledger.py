"""CostLedger — the single accumulation point for modeled execution costs.

Every time/energy/FLOPs figure a run reports flows through one ledger
instance: per-round charges (compute + fixed overheads, from
``EdgeCostModel.round_cost``), auxiliary probe charges (e.g. SimFreeze's
CKA similarity computations) and ModelPool swap charges (loading/saving a
model slot across the device memory budget). Centralizing the arithmetic
keeps the breakdown keys consistent across the runtime, benchmarks and
tests, and makes "where did the joules go" auditable instead of being
smeared across the event loop (DESIGN.md §3).

Attribution is three-dimensional: every charge lands in the global totals,
in ``per_stream[stream]`` (which arrival stream caused it), in
``per_model[model]`` (which model slot executed it — DESIGN.md §9) and in
``per_device[device]`` (which fleet device ran it — DESIGN.md §13). All
three attributions independently sum back to the totals; single-model
single-device runs put everything under the ``"default"`` slot and the
``"dev0"`` device so the invariant is universal.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

#: Breakdown keys every `RunResult.breakdown` carries. `t_`/`e_` prefix =
#: seconds / joules; `compute`/`overhead` follow the paper's Fig. 3 split;
#: `cka` is SimFreeze's similarity-probe cost (charged as pure compute).
#: ModelPool swap charges (`t_swap`/`e_swap`) and preemption-resume
#: charges (`t_resume`/`e_resume`) appear lazily, only when a run
#: actually incurs them — keeping legacy breakdowns byte-identical.
BREAKDOWN_KEYS = ("t_compute", "t_overhead", "e_compute", "e_overhead",
                  "t_cka", "e_cka")


#: Per-stream attribution keys: every charge lands both in the global
#: totals and in `per_stream[stream]` under these names, so a multi-stream
#: run can answer "which stream spent the joules" (and tests can assert the
#: attributions always sum back to the totals). `preemptions` counts how
#: many times the stream's in-flight round was split by a higher-priority
#: arrival (QoS preemption; it is a counter, not a cost — excluded from
#: the sums-to-totals contract, which covers the first four keys).
STREAM_KEYS = ("time_s", "energy_j", "flops", "rounds", "preemptions")

#: Per-model-slot attribution keys (ModelPool, DESIGN.md §9). The cost
#: keys mirror STREAM_KEYS and sum to the totals the same way; `swaps`
#: counts how many times the slot was loaded back into device memory
#: after an eviction (a counter, like `preemptions`).
MODEL_KEYS = ("time_s", "energy_j", "flops", "rounds", "swaps")

#: Model-slot key used when the runtime runs a single model (no pool).
DEFAULT_MODEL = "default"

#: Per-device attribution keys (DeviceFleet, DESIGN.md §13). The cost keys
#: mirror STREAM_KEYS and sum to the totals the same way; `swaps` counts
#: the device's ModelPool reloads and `syncs` its participations in
#: cross-device delta merges (counters, like `preemptions`).
DEVICE_KEYS = ("time_s", "energy_j", "flops", "rounds", "swaps", "syncs")

#: Device key used when the runtime runs a fleet of size 1 (the legacy
#: single-device case — every seed-era run).
DEFAULT_DEVICE = "dev0"


@dataclass
class CostLedger:
    total_time_s: float = 0.0
    total_energy_j: float = 0.0
    total_flops: float = 0.0
    rounds: int = 0
    breakdown: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in BREAKDOWN_KEYS})
    per_stream: Dict[int, Dict[str, float]] = field(default_factory=dict)
    per_model: Dict[str, Dict[str, float]] = field(default_factory=dict)
    per_device: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # optional observer (`repro.obs.Telemetry`): every charge is mirrored
    # into its MetricsRegistry so metrics reconcile with the ledger
    # exactly. None (the default) is the zero-overhead legacy path.
    telemetry: Optional[object] = field(default=None, repr=False,
                                        compare=False)

    def _stream(self, stream: int) -> Dict[str, float]:
        return self.per_stream.setdefault(
            stream, {k: 0.0 for k in STREAM_KEYS})

    def _model(self, model: str) -> Dict[str, float]:
        return self.per_model.setdefault(
            model, {k: 0.0 for k in MODEL_KEYS})

    def _device(self, device: str) -> Dict[str, float]:
        return self.per_device.setdefault(
            device, {k: 0.0 for k in DEVICE_KEYS})

    def charge_round(self, *, flops: float, time_s: float, energy_j: float,
                     parts: Dict[str, float], stream: int = 0,
                     model: str = DEFAULT_MODEL,
                     device: str = DEFAULT_DEVICE) -> None:
        """One fine-tuning round: `parts` is EdgeCostModel's breakdown dict
        (t_compute/t_overhead/e_compute/e_overhead); `stream` is the
        arrival stream whose buffered batches the round trained; `model`
        the slot that executed it; `device` the fleet device it ran on."""
        self.charge_round_segment(flops=flops, time_s=time_s,
                                  energy_j=energy_j, parts=parts,
                                  stream=stream, model=model, device=device,
                                  final=True)

    def charge_round_segment(self, *, flops: float, time_s: float,
                             energy_j: float, parts: Dict[str, float],
                             stream: int = 0, model: str = DEFAULT_MODEL,
                             device: str = DEFAULT_DEVICE,
                             final: bool = True) -> None:
        """One *segment* of a (possibly preempted) round. A preemptible
        round charges each occupancy segment as it completes; the caller
        splits the round's total cost across segments so they sum exactly
        to the unpreempted round's charge. `final=True` on the last (or
        only) segment counts the round itself."""
        self.total_time_s += time_s
        self.total_energy_j += energy_j
        self.total_flops += flops
        for k in ("t_compute", "t_overhead", "e_compute", "e_overhead"):
            self.breakdown[k] += parts[k]
        per = self._stream(stream)
        per["time_s"] += time_s
        per["energy_j"] += energy_j
        per["flops"] += flops
        pm = self._model(model)
        pm["time_s"] += time_s
        pm["energy_j"] += energy_j
        pm["flops"] += flops
        pd = self._device(device)
        pd["time_s"] += time_s
        pd["energy_j"] += energy_j
        pd["flops"] += flops
        if final:
            self.rounds += 1
            per["rounds"] += 1
            pm["rounds"] += 1
            pd["rounds"] += 1
        if self.telemetry is not None:
            self.telemetry.on_charge(time_s=time_s, energy_j=energy_j,
                                     flops=flops, stream=stream,
                                     model=model, device=device,
                                     kind="round")
            if final:
                self.telemetry.on_round(stream=stream, model=model,
                                        device=device)

    def note_preemption(self, stream: int = 0) -> None:
        """A higher-priority arrival split `stream`'s in-flight round."""
        self._stream(stream)["preemptions"] += 1
        if self.telemetry is not None:
            self.telemetry.on_preemption(stream=stream)

    @property
    def preemptions(self) -> int:
        return int(sum(v.get("preemptions", 0)
                       for v in self.per_stream.values()))

    def charge_probe(self, key: str, time_s: float, energy_j: float,
                     stream: int = 0, model: str = DEFAULT_MODEL,
                     device: str = DEFAULT_DEVICE) -> None:
        """An auxiliary compute charge outside the round proper (e.g. `key`
        = 'cka'). Adds to the totals and to `t_<key>` / `e_<key>`."""
        time_s, energy_j = float(time_s), float(energy_j)
        self.breakdown[f"t_{key}"] = self.breakdown.get(f"t_{key}", 0.0) + time_s
        self.breakdown[f"e_{key}"] = self.breakdown.get(f"e_{key}", 0.0) + energy_j
        self.total_time_s += time_s
        self.total_energy_j += energy_j
        per = self._stream(stream)
        per["time_s"] += time_s
        per["energy_j"] += energy_j
        pm = self._model(model)
        pm["time_s"] += time_s
        pm["energy_j"] += energy_j
        pd = self._device(device)
        pd["time_s"] += time_s
        pd["energy_j"] += energy_j
        if self.telemetry is not None:
            self.telemetry.on_charge(time_s=time_s, energy_j=energy_j,
                                     flops=0.0, stream=stream, model=model,
                                     device=device, kind=key)

    def charge_swap(self, *, time_s: float, energy_j: float, model: str,
                    stream: int = 0, device: str = DEFAULT_DEVICE) -> None:
        """A ModelPool residency swap: `model` was loaded back into device
        memory (evicted peers saved out first). Lands in the totals, the
        `t_swap`/`e_swap` breakdown, all attributions, and bumps the
        slot's and device's `swaps` counters."""
        self.charge_probe("swap", time_s, energy_j, stream=stream,
                          model=model, device=device)
        self._model(model)["swaps"] += 1
        self._device(device)["swaps"] += 1
        if self.telemetry is not None:
            self.telemetry.on_swap(model=model, device=device)

    def charge_sync(self, *, time_s: float, energy_j: float, device: str,
                    stream: int = 0, model: str = DEFAULT_MODEL) -> None:
        """One device's participation in a cross-device delta merge
        (DeviceFleet aggregation, DESIGN.md §13): serializing its unfrozen
        params out and loading the merged result back. Lands in the totals,
        the `t_sync`/`e_sync` breakdown, all attributions, and bumps the
        device's `syncs` counter."""
        self.charge_probe("sync", time_s, energy_j, stream=stream,
                          model=model, device=device)
        self._device(device)["syncs"] += 1
        if self.telemetry is not None:
            self.telemetry.on_sync(device=device)

    @property
    def swaps(self) -> int:
        return int(sum(v.get("swaps", 0) for v in self.per_model.values()))

    @property
    def syncs(self) -> int:
        return int(sum(v.get("syncs", 0) for v in self.per_device.values()))

    @property
    def compute_tflops(self) -> float:
        return self.total_flops / 1e12
