"""ModelPool — the mixed-modality multi-model runtime (DESIGN.md §9).

EdgeOL's target deployments mix modalities: the paper evaluates CV
(CORe50/CIFAR) *and* NLP (20News) workloads, and a real edge box serves
both from one device. A `ModelPool` owns N independent model **slots** —
one per modality, each with its own params, optimizer state, compiled
train steps (`TrainStepCache`), replay buffer, freeze-plan controller and
per-model cost calibration — all multiplexed over the single shared
device timeline (`EventScheduler.busy_until`).

The pool's own job is **residency** under a device memory budget:

- each slot's footprint is its params + optimizer state (measured from
  the live pytrees at run start, or pinned via `ModelSlot.memory_mb`);
- `memory_budget_mb` caps how many footprints fit at once (0 = unlimited,
  every slot stays resident and no swap is ever charged);
- touching a **cold** slot — a fine-tuning round *or* an inference
  request — first swaps it in: least-recently-used resident slots are
  evicted (paying their cost model's `t_save_s`; training dirties a slot,
  so eviction always saves) until the incoming slot (paying `t_load_s`)
  fits. The swap is real device occupancy *and* a real ledger charge
  (`CostLedger.charge_swap` → `t_swap`/`e_swap` breakdown, attributed to
  the touching stream and the loaded slot, whose `swaps` counter bumps).

The pool is deliberately runtime-state-free beyond residency: the
composition root (`runtime/continual.py`) owns one `FineTuneExecutor` and
one serving lane per slot and asks the pool only "is this slot hot, and
what does making it hot cost" — so the swap-charging policy is testable
without a model in sight.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.runtime.costmodel import EdgeCostModel


def tree_mb(*trees: Any) -> float:
    """Total array bytes of the given pytrees, in MB (the footprint a
    resident slot pins in device memory)."""
    import jax
    import numpy as np

    total = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            total += getattr(leaf, "nbytes", None) \
                or np.asarray(leaf).nbytes
    return total / 2**20


@dataclass
class ModelSlot:
    """One modality's model binding. `name` is the modality key streams
    bind to (`StreamSpec.modality` → `Event.modality` → this slot);
    `benchmark` provides the slot's pretraining scenario 0 and its
    replay/validation data; `cost` is calibrated per slot (different
    architectures sustain different modeled throughput); `controller` may
    be pre-built, else the runtime builds one via its `controller_factory`
    seam; `memory_mb` overrides the measured params+optimizer footprint
    (useful for tests and what-if budget sweeps)."""
    name: str
    model: Any
    benchmark: Any
    cost: EdgeCostModel = field(default_factory=EdgeCostModel)
    controller: Any = None
    memory_mb: Optional[float] = None


class ModelPool:
    """N model slots sharing one device memory budget (LRU residency)."""

    def __init__(self, slots: Sequence[ModelSlot],
                 memory_budget_mb: float = 0.0):
        if not slots:
            raise ValueError("ModelPool needs at least one slot")
        names = [s.name for s in slots]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate slot names: {names}")
        self.slots: Dict[str, ModelSlot] = {s.name: s for s in slots}
        self.memory_budget_mb = float(memory_budget_mb)
        self._memory: Dict[str, float] = {
            s.name: float(s.memory_mb) for s in slots
            if s.memory_mb is not None}
        self._resident: List[str] = []   # LRU order, most-recent last

    # ---- introspection ---------------------------------------------------
    def slot(self, name: str) -> ModelSlot:
        try:
            return self.slots[name]
        except KeyError:
            raise KeyError(
                f"no model slot for modality {name!r}; pool has "
                f"{sorted(self.slots)}") from None

    def __len__(self) -> int:
        return len(self.slots)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self.slots)

    @property
    def resident(self) -> Tuple[str, ...]:
        return tuple(self._resident)

    def is_resident(self, name: str) -> bool:
        return name in self._resident

    def memory_of(self, name: str) -> float:
        """Footprint of one slot, MB (0.0 until measured/pinned)."""
        return self._memory.get(name, 0.0)

    @property
    def resident_mb(self) -> float:
        return sum(self.memory_of(n) for n in self._resident)

    def describe(self) -> Dict:
        """JSON-ready summary for benchmark manifests."""
        return {
            "memory_budget_mb": self.memory_budget_mb,
            "slots": {n: {"memory_mb": round(self.memory_of(n), 3),
                          "model": getattr(getattr(s.model, "cfg", None),
                                           "name", "?"),
                          "benchmark": getattr(s.benchmark, "name", "?")}
                      for n, s in self.slots.items()},
        }

    # ---- residency -------------------------------------------------------
    def set_memory(self, name: str, mb: float) -> None:
        """Pin a slot's measured footprint (the runtime calls this once
        its params/optimizer pytrees exist). An explicit
        `ModelSlot.memory_mb` wins over the measurement."""
        if self.slot(name).memory_mb is None:
            self._memory[name] = float(mb)
        if self.memory_budget_mb > 0.0 \
                and self._memory[name] > self.memory_budget_mb:
            raise ValueError(
                f"slot {name!r} ({self._memory[name]:.1f} MB) can never "
                f"fit the {self.memory_budget_mb:.1f} MB device budget")

    def warm(self) -> Tuple[str, ...]:
        """Initial residency at timeline start: slots become resident in
        declaration order until the budget is full (pretraining happens
        off-timeline, so these initial loads are not cost-accounted —
        paper §V-A's "originally well-trained" premise). Returns the
        resident set."""
        self._resident = []
        for name in self.slots:
            mem = self.memory_of(name)
            if self.memory_budget_mb <= 0.0 \
                    or self.resident_mb + mem <= self.memory_budget_mb:
                self._resident.append(name)
        return self.resident

    def ensure_resident(self, name: str) -> Tuple[float, float, List[str]]:
        """Make `name` resident. Returns ``(swap_time_s, swap_energy_j,
        evicted)`` — all-zero/empty when the slot was already hot (its LRU
        position is refreshed). A cold slot evicts least-recently-used
        residents until it fits, paying each eviction's `t_save_s` plus
        its own `t_load_s`, at the respective cost models' overhead power
        (swaps are IO, not compute). The caller charges the ledger and
        occupies the device timeline with the returned figures."""
        slot = self.slot(name)
        if name in self._resident:
            self._resident.remove(name)
            self._resident.append(name)
            return 0.0, 0.0, []
        mem = self.memory_of(name)
        evicted: List[str] = []
        if self.memory_budget_mb > 0.0:
            while self._resident \
                    and self.resident_mb + mem > self.memory_budget_mb:
                evicted.append(self._resident.pop(0))
            if self.resident_mb + mem > self.memory_budget_mb:
                raise ValueError(
                    f"slot {name!r} ({mem:.1f} MB) cannot fit the "
                    f"{self.memory_budget_mb:.1f} MB budget even alone")
        time_s = slot.cost.t_load_s
        energy_j = slot.cost.t_load_s * slot.cost.overhead_power_w
        for ev in evicted:
            c = self.slot(ev).cost
            time_s += c.t_save_s
            energy_j += c.t_save_s * c.overhead_power_w
        self._resident.append(name)
        return time_s, energy_j, evicted
