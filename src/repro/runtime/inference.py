"""InferenceServer — the serving half of the continual-learning loop.

Owns the request path: which params serve a request (the
`visible_params`/`visible_at` seam — resolved at *arrival* time), per-
request accuracy recording, and opt-in **micro-batched serving**:
requests that land within `batch_window` seconds of each other and
resolve to the same params are coalesced into a single forward pass. On
the paper's workloads (many small requests, §V-D sweeps
`inferences_total`) this turns N model invocations into ~N/k while
leaving every recorded per-request accuracy unchanged (a regression test
asserts the equivalence). Controller signals fed by `on_served`
(LazyTune's inference-arrival decay, scenario detection) are delivered at
flush time; the composition root bounds that lag to one window via
`expire`, so stateful controllers may see signal timing shift by at most
`batch_window` timeline seconds relative to per-request serving.

Multi-model serving (ModelPool, DESIGN.md §9): the server holds one
params-visibility lane per model *slot* (`register`/`publish(slot=...)`),
each with its own `visible_params`/`visible_at` pair and model, and
records accuracies per slot (`accs_by_slot`) alongside the per-stream
view. The single-model runtime only ever touches the ``"default"`` slot,
created in the constructor — its request path is byte-identical to the
pre-pool server.

Visibility caveat (kept bug-compatible with the pre-decomposition
monolith; DESIGN.md §5): by default `publish` sets `visible_params` and
`latest_params` to the *same* object, so requests landing mid-round are
served by the round's freshly trained params. `publish(delayed=True)` —
driven by a `RoundEndPublish` policy (repro.core.policies) — retains the
pre-round params as `latest`, so mid-round arrivals genuinely resolve the
outdated model; the request path (`_resolve`, the per-group
params-identity split) is unchanged either way.

`batch_window=0` (the default) reproduces the legacy per-request path
exactly — bit-for-bit, including the shared RNG consumption order — which
is what the fixed-seed parity test in tests/test_regression_runtime.py
pins down.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.runtime.ledger import DEFAULT_MODEL
from repro.runtime.train_loop import as_jnp, evaluate

# Process-global serving programs: jit(vmap(predict)) keyed on the
# predict closure itself plus (concat-signature, stack bucket), so every
# server over the same (memoized) model shares one XLA program — a sweep
# doesn't re-pay the serving compile per cell.
_VMAPPED: Dict[Any, Callable] = {}


@dataclass
class _SlotLane:
    """Per-model-slot serving state: the model that answers the slot's
    requests and the params-visibility pair (DESIGN.md §5 seam)."""
    model: Any
    visible_params: Any = None
    visible_at: float = 0.0
    latest_params: Any = None


@dataclass
class _Pending:
    time: float
    request: Dict[str, np.ndarray]
    params: Any  # resolved at submit time (arrival-time visibility policy)
    stream: int = 0  # arrival stream (multi-stream workloads)
    slot: str = DEFAULT_MODEL  # model slot that serves it (ModelPool)
    model: Any = field(default=None, repr=False)


class InferenceServer:
    """Request queue + params-visibility policy + optional micro-batching.

    `on_served(logits, stream) -> bool` is invoked once per request, in
    arrival order, with that request's logits and arrival-stream id (so a
    multi-stream composition root can route the signal to that stream's
    controller). A True return is additionally latched into
    `change_detected` / `poll_change` — a stream-agnostic convenience
    latch for embedders that don't track per-stream state themselves
    (runtime/continual.py latches per stream inside its own callback
    instead).
    """

    def __init__(self, model, *, batch_window: float = 0.0,
                 on_served: Optional[Callable[[np.ndarray, int], bool]] = None,
                 fused: bool = False, tracer=NULL_TRACER,
                 track: Optional[str] = None):
        self.batch_window = float(batch_window)
        self.on_served = on_served
        # observability (DESIGN.md §14): request spans (per-stream latency
        # on the modeled timeline — no device tag, so they never enter
        # device-time reconciliation) plus serve/publish instants tagged
        # with `track`, the owning device's lane. NULL_TRACER = free.
        self.tracer = tracer
        self.track = track
        # compiled hot path (DESIGN.md §12): defer closed groups to a FIFO
        # and execute them in `drain()` as padded vmapped forwards —
        # same-shape groups for one (slot, params) stack into a single
        # dispatch. Recording and `on_served` delivery stay in arrival
        # order; the composition root drains at every event boundary, so
        # controller signal timing matches the eager path.
        self.fused = bool(fused)
        self._ready: List[List[_Pending]] = []
        # model slots: the single-model path lives entirely in "default";
        # a ModelPool runtime registers one extra lane per slot.
        self._lanes: Dict[str, _SlotLane] = {DEFAULT_MODEL: _SlotLane(model)}
        # recorded outcomes (global, plus per-stream and per-slot views)
        self.accs: List[float] = []
        self.accs_by_stream: Dict[int, List[float]] = {}
        self.accs_by_slot: Dict[str, List[float]] = {}
        # recorded serving latency (request arrival -> modeled service
        # time, seconds) per arrival stream; purely observational — the
        # composition root computes it from device occupancy (QoS
        # preemption drives a high-priority request's latency to 0)
        self.latencies_by_stream: Dict[int, List[float]] = {}
        self.served = 0
        self.eval_calls = 0
        self.change_detected = False
        self._queue: List[_Pending] = []

    # ---- slot lifecycle --------------------------------------------------
    def register(self, slot: str, model) -> None:
        """Add a serving lane for model slot `slot` (ModelPool). Re-
        registering an existing slot swaps its model but keeps its
        published params (the pool owns params continuity)."""
        lane = self._lanes.get(slot)
        if lane is None:
            self._lanes[slot] = _SlotLane(model)
        else:
            lane.model = model

    @property
    def model(self):
        """The default slot's model (legacy single-model accessor)."""
        return self._lanes[DEFAULT_MODEL].model

    @property
    def visible_params(self):
        return self._lanes[DEFAULT_MODEL].visible_params

    @property
    def visible_at(self) -> float:
        return self._lanes[DEFAULT_MODEL].visible_at

    @property
    def latest_params(self):
        return self._lanes[DEFAULT_MODEL].latest_params

    # ---- params lifecycle ------------------------------------------------
    def publish(self, params, visible_at: float,
                slot: str = DEFAULT_MODEL, *, delayed: bool = False) -> None:
        """A fine-tuning round finished training `params` for `slot`; they
        become visible once the round's device occupancy ends
        (`visible_at`). Queued requests arrived earlier and must be served
        first, with the params they resolved to at arrival.

        ``delayed=False`` (default) keeps the bug-compat §5 seam: `latest`
        and `visible` are the same object, so requests arriving *before*
        `visible_at` still resolve the new params. ``delayed=True``
        (`RoundEndPublish` and future async publish policies) retains the
        previously visible params as `latest`, so mid-round arrivals
        genuinely serve the pre-round model — the paper §III-A "outdated
        model" effect."""
        self.flush()
        self.drain()
        if self.tracer:
            self.tracer.instant("publish", f"publish/{slot}", visible_at,
                                device=self.track, slot=slot,
                                delayed=delayed)
        lane = self._lanes[slot]
        if delayed and lane.visible_params is not None:
            lane.latest_params = lane.visible_params
        else:
            lane.latest_params = params
        lane.visible_params = params
        lane.visible_at = visible_at

    def _resolve(self, t: float, slot: str = DEFAULT_MODEL):
        lane = self._lanes[slot]
        return lane.visible_params if t >= lane.visible_at \
            else lane.latest_params

    # ---- request path ----------------------------------------------------
    def submit(self, t: float, request: Dict[str, np.ndarray],
               stream: int = 0, latency: float = 0.0,
               slot: str = DEFAULT_MODEL) -> None:
        """Serve (or enqueue) one inference request arriving at time `t` on
        arrival stream `stream`, answered by model slot `slot`. The params
        are resolved *now* — arrival-time visibility — so coalescing never
        changes which model state answers a request. Requests from
        different streams may share a coalesced group (one device, one
        forward pass); accuracy recording and `on_served` routing stay
        per-request. Requests for different *slots* never coalesce (their
        params — and models — differ by construction).

        Coalescing window semantics (pinned by a boundary-value test in
        tests/test_scheduler.py): the window is **closed** — a request
        landing at *exactly* ``first.time + batch_window`` still joins the
        open group; only a strictly later one starts a new group. `expire`
        uses the same closed-boundary rule, so the two paths can never
        disagree about a group's fate.

        `latency` is the caller-computed serving latency (arrival ->
        modeled service time); it is recorded per stream and reported via
        `RunResult.per_stream` percentiles, never acted on here."""
        self.latencies_by_stream.setdefault(stream, []).append(float(latency))
        if self.tracer:
            self.tracer.span("request", f"s{stream}", t, float(latency),
                             stream=stream, slot=slot)
        params = self._resolve(t, slot)
        pending = _Pending(t, request, params, stream, slot,
                           self._lanes[slot].model)
        if self.batch_window <= 0.0:
            self._serve([pending])
            return
        if self._queue and (t - self._queue[0].time > self.batch_window
                            or self._queue[0].params is not params
                            or self._queue[0].slot != slot):
            self.flush()
        self._queue.append(pending)

    def flush(self) -> None:
        if self._queue:
            group, self._queue = self._queue, []
            self._serve(group)

    def expire(self, now: float) -> None:
        """Flush any queued group whose window has elapsed by time `now`.
        The composition root calls this as the timeline advances so a
        coalesced group (and anything latched by its `on_served`
        callbacks, e.g. scenario-change detection) is never deferred past
        its window just because no further request arrived. Boundary rule
        matches `submit` (closed window): at ``now == first.time +
        batch_window`` the group is still open — a request landing at
        that exact instant must coalesce — and it expires only strictly
        after."""
        if self._queue and now - self._queue[0].time > self.batch_window:
            self.flush()

    def poll_change(self) -> bool:
        changed, self.change_detected = self.change_detected, False
        return changed

    # ---- execution -------------------------------------------------------
    def _serve(self, group: List[_Pending]) -> None:
        if self.fused:
            self._ready.append(group)
            return
        if self.tracer:
            self.tracer.instant("serve", f"serve/{group[0].slot}",
                                group[0].time, device=self.track,
                                slot=group[0].slot, requests=len(group))
        self.eval_calls += 1
        if len(group) == 1:
            p = group[0]
            acc, logits = evaluate(p.model, p.params, as_jnp(p.request))
            self._record(p, acc, logits)
            return
        # one forward pass over the concatenated group, then per-request
        # slicing — identical math to per-request serving because every
        # request in a group shares the same params (and hence model).
        batch = {k: np.concatenate([p.request[k] for p in group])
                 for k in group[0].request}
        _, logits = evaluate(group[0].model, group[0].params, as_jnp(batch))
        offset = 0
        for p in group:
            n = len(p.request["labels"])
            lg = logits[offset:offset + n]
            offset += n
            acc = float(np.mean((np.argmax(lg, -1) ==
                                 np.asarray(p.request["labels"]))
                                .astype(np.float32)))
            self._record(p, acc, lg)

    def drain(self) -> None:
        """Execute every deferred group (fused mode; no-op otherwise).

        Groups are concatenated exactly like the eager multi-request path,
        then same-(slot, params, shape) concats are stacked and run as one
        `jit(vmap(predict))` dispatch, padded up to a power-of-two group
        count by repeating the first concat (vmap output is per-example
        independent, so padding rows slice away without moving a bit).
        Results are recorded strictly in arrival order."""
        if not self._ready:
            return
        ready, self._ready = self._ready, []
        concats: List[Dict[str, np.ndarray]] = []
        stacks: Dict[Any, List[int]] = {}
        for gi, group in enumerate(ready):
            if len(group) == 1:
                batch = {k: np.asarray(v) for k, v in group[0].request.items()}
            else:
                batch = {k: np.concatenate([p.request[k] for p in group])
                         for k in group[0].request}
            concats.append(batch)
            sig = tuple(sorted((k, v.shape, str(v.dtype))
                               for k, v in batch.items()))
            key = (group[0].slot, id(group[0].params), sig)
            stacks.setdefault(key, []).append(gi)
        logits_by_group: Dict[int, np.ndarray] = {}
        for (slot, _, sig), idxs in stacks.items():
            first = ready[idxs[0]][0]
            if self.tracer:
                self.tracer.instant("serve", f"vmap/{slot}", first.time,
                                    device=self.track, slot=slot,
                                    groups=len(idxs),
                                    requests=sum(len(ready[i])
                                                 for i in idxs))
            out = self._forward_stack(first.model, first.params, slot, sig,
                                      [concats[i] for i in idxs])
            for row, gi in enumerate(idxs):
                logits_by_group[gi] = out[row]
        for gi, group in enumerate(ready):
            self.eval_calls += 1
            logits = logits_by_group[gi]
            offset = 0
            for p in group:
                n = len(p.request["labels"])
                lg = logits[offset:offset + n]
                offset += n
                acc = float(np.mean((np.argmax(lg, -1) ==
                                     np.asarray(p.request["labels"]))
                                    .astype(np.float32)))
                self._record(p, acc, lg)

    def _forward_stack(self, model, params, slot, sig,
                       concats: List[Dict[str, np.ndarray]]) -> np.ndarray:
        import jax
        import jax.numpy as jnp

        n = len(concats)
        bucket = 1 << max(n - 1, 0).bit_length()
        key = (model.predict, sig, bucket)
        fwd = _VMAPPED.get(key)
        if fwd is None:
            fwd = _VMAPPED[key] = jax.jit(
                jax.vmap(model.predict, in_axes=(None, 0)))
        stacked = {k: jnp.stack([jnp.asarray(c[k]) for c in concats]
                                + [jnp.asarray(concats[0][k])] * (bucket - n))
                   for k in concats[0]}
        return np.asarray(fwd(params, stacked))[:n]

    def _record(self, p: _Pending, acc: float, logits) -> None:
        self.accs.append(acc)
        self.accs_by_stream.setdefault(p.stream, []).append(acc)
        self.accs_by_slot.setdefault(p.slot, []).append(acc)
        self.served += 1
        if self.on_served is not None and self.on_served(logits, p.stream):
            self.change_detected = True

    # ---- reporting -------------------------------------------------------
    @property
    def avg_acc(self) -> float:
        return float(np.mean(self.accs)) if self.accs else 0.0
