from repro.runtime.config import (HookSpec, RuntimeConfig, SlotConfig,
                                  build_hook, materialize_stream_benchmarks)
from repro.runtime.continual import (ContinualRuntime, RunResult,
                                     edgeol_session)
from repro.runtime.costmodel import EdgeCostModel, PodCostModel
from repro.runtime.executor import (FakeQuantHook, FineTuneExecutor,
                                    ReplayBuffer, RoundHook, RoundReport,
                                    SimSiamHook)
from repro.runtime.inference import InferenceServer
from repro.runtime.ledger import (BREAKDOWN_KEYS, DEFAULT_MODEL, MODEL_KEYS,
                                  STREAM_KEYS, CostLedger)
from repro.runtime.modelpool import ModelPool, ModelSlot
from repro.runtime.scheduler import EventScheduler
from repro.runtime.train_loop import TrainStepCache, evaluate

__all__ = ["EdgeCostModel", "PodCostModel", "ContinualRuntime", "RunResult",
           "TrainStepCache", "evaluate", "EventScheduler", "InferenceServer",
           "FineTuneExecutor", "ReplayBuffer", "RoundHook", "RoundReport",
           "SimSiamHook", "FakeQuantHook", "CostLedger", "BREAKDOWN_KEYS",
           "STREAM_KEYS", "MODEL_KEYS", "DEFAULT_MODEL", "ModelPool",
           "ModelSlot", "RuntimeConfig", "SlotConfig", "HookSpec",
           "edgeol_session", "build_hook", "materialize_stream_benchmarks"]
