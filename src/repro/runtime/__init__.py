from repro.env.spec import EnvSpec
from repro.obs.spec import TelemetrySpec
from repro.runtime.config import (DeviceConfig, HookSpec, RuntimeConfig,
                                  SlotConfig, build_hook,
                                  materialize_stream_benchmarks)
from repro.runtime.continual import (ContinualRuntime, RunResult,
                                     edgeol_session)
from repro.runtime.costmodel import EdgeCostModel, PodCostModel, scale_cost
from repro.runtime.device import DeviceRuntime
from repro.runtime.executor import (FakeQuantHook, FineTuneExecutor,
                                    ReplayBuffer, RoundHook, RoundReport,
                                    SimSiamHook)
from repro.runtime.fleet import (FLEET_STREAM, ROUTING_POLICIES, DeviceFleet,
                                 LeastLoaded, RoutingPolicy, StaticAffinity,
                                 fleet_devices)
from repro.runtime.inference import InferenceServer
from repro.runtime.ledger import (BREAKDOWN_KEYS, DEFAULT_DEVICE,
                                  DEFAULT_MODEL, DEVICE_KEYS, MODEL_KEYS,
                                  STREAM_KEYS, CostLedger)
from repro.runtime.modelpool import ModelPool, ModelSlot
from repro.runtime.scheduler import EventScheduler
from repro.runtime.train_loop import TrainStepCache, evaluate

__all__ = ["EdgeCostModel", "PodCostModel", "ContinualRuntime", "RunResult",
           "TrainStepCache", "evaluate", "EventScheduler", "InferenceServer",
           "FineTuneExecutor", "ReplayBuffer", "RoundHook", "RoundReport",
           "SimSiamHook", "FakeQuantHook", "CostLedger", "BREAKDOWN_KEYS",
           "STREAM_KEYS", "MODEL_KEYS", "DEVICE_KEYS", "DEFAULT_MODEL",
           "DEFAULT_DEVICE", "ModelPool", "ModelSlot", "RuntimeConfig",
           "SlotConfig", "HookSpec", "DeviceConfig", "edgeol_session",
           "build_hook", "materialize_stream_benchmarks", "scale_cost",
           "DeviceRuntime", "DeviceFleet", "RoutingPolicy", "StaticAffinity",
           "LeastLoaded", "ROUTING_POLICIES", "FLEET_STREAM", "fleet_devices",
           "TelemetrySpec", "EnvSpec"]
