from repro.runtime.costmodel import EdgeCostModel, PodCostModel
from repro.runtime.continual import ContinualRuntime, RunResult
from repro.runtime.train_loop import TrainStepCache, evaluate

__all__ = ["EdgeCostModel", "PodCostModel", "ContinualRuntime", "RunResult",
           "TrainStepCache", "evaluate"]
