"""ContinualRuntime — composition root of the event-driven continual-
learning loop of the paper (Fig. 1): training batches and inference
requests arrive on a shared timeline; a controller (ETuner or a baseline)
decides when to launch fine-tuning rounds and which layers are frozen; the
cost model charges per-round overheads (system init / load / save),
per-plan recompiles and XLA-*measured* compute FLOPs.

The runtime itself is deliberately thin. It wires four owned subsystems
(DESIGN.md §1):

- `EventScheduler` (runtime/scheduler.py) — the priority-ordered timeline,
  wall-clock/`busy_until` device occupancy, scenario boundaries;
- `InferenceServer` (runtime/inference.py) — request serving, the
  arrival-time params-visibility seam, opt-in micro-batched serving;
- `FineTuneExecutor` (runtime/executor.py) — round execution, the replay
  buffer, and `RoundHook`s (SimSiam semi-supervised pass, fake-quant QAT);
- `CostLedger` (runtime/ledger.py) — all time/energy/FLOPs accounting;

plus, optionally, a **`ModelPool`** (runtime/modelpool.py, DESIGN.md §9):
one model slot per modality — its own params/optimizer/steps/replay/
controller and per-slot cost calibration — multiplexed over the one
shared device timeline under a device memory budget (cold slots pay a
real load/save swap charge). Without a pool the runtime runs its single
model under the reserved "default" slot, byte-identical to the pre-pool
behaviour (the golden regression suite pins this).

Controllers implement the `ControllerProtocol` documented in
core/controller.py; the runtime drives them from scheduler callbacks and
never reaches into their internals. Monolithic controllers predating the
policy decomposition are adapted transparently
(`repro.core.policies.adapt_controller`), and a controller's optional
`publish_policy` decides when a round's params reach serving.

Construction (DESIGN.md §11): the front door is the declarative
`RuntimeConfig` — `ContinualRuntime.from_config(cfg, ...)` or
`edgeol_session(cfg)` — with live objects (a custom benchmark, a
pre-built controller/pool, a cost model) injected alongside the config.
The legacy ~18-kwarg constructor still works but is deprecated: it
delegates to the same resolution path and emits a `DeprecationWarning`.

Faithfulness notes:
- the model is pre-trained on scenario 0 ("originally well-trained in the
  first scenario"); costs are accounted from scenario 1 on;
- a small replay buffer stands in for the CWR anti-forgetting technique of
  the CORe50 paper (documented substitution, DESIGN.md);
- inference requests resolve their params at *arrival* time via the
  InferenceServer's visibility seam; a round occupies wall-clock, which is
  the "outdated model" effect LazyTune must balance (paper §III-A). Note
  the pre-decomposition monolith served mid-round requests by the round's
  freshly trained params (visible == latest); that behaviour is kept
  bug-compatible and the seam documented in DESIGN.md §5;
- validation accuracy (5% split) drives LazyTune; inference accuracy is
  only recorded, never used by the controller (paper §IV-A).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import adapt_controller
from repro.data.arrivals import Event, build_timeline
from repro.data.streams import ContinualBenchmark
from repro.optim import AdamWConfig
from repro.runtime.config import (HookSpec, RuntimeConfig, SlotConfig,
                                  resolve_session)
from repro.runtime.costmodel import EdgeCostModel
from repro.runtime.executor import (FineTuneExecutor, ReplayBuffer,
                                    RoundHook, fake_quant, quantized_model)
from repro.runtime.inference import InferenceServer
from repro.runtime.ledger import (DEFAULT_MODEL, MODEL_KEYS, STREAM_KEYS,
                                  CostLedger)
from repro.runtime.modelpool import ModelPool, tree_mb
from repro.runtime.scheduler import EventScheduler
from repro.runtime.train_loop import (TrainStepCache, as_jnp, evaluate,
                                     make_optimizer_state, same_shape_runs)

# legacy aliases (pre-decomposition import sites)
_fake_quant = fake_quant
_quantized_model = quantized_model


@dataclass
class RunResult:
    avg_inference_acc: float
    total_time_s: float
    total_energy_j: float
    compute_tflops: float
    rounds: int
    recompiles: int
    inference_accs: List[float] = field(default_factory=list)
    breakdown: Dict[str, float] = field(default_factory=dict)
    controller_stats: Dict[str, Any] = field(default_factory=dict)
    val_curve: List[float] = field(default_factory=list)
    # per-arrival-stream attribution (multi-stream workloads): stream id ->
    # {time_s, energy_j, flops, rounds, preemptions, avg_inference_acc,
    #  inferences, latency_p50, latency_p95}
    per_stream: Dict[int, Dict[str, float]] = field(default_factory=dict)
    # per-model-slot attribution (ModelPool; single-model runs report one
    # "default" slot): slot -> {time_s, energy_j, flops, rounds, swaps,
    # avg_inference_acc, inferences}
    per_model: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # QoS: total round splits absorbed by lower-priority streams' rounds
    preemptions: int = 0
    # ModelPool: total cold-slot swap-ins charged to the run
    swaps: int = 0
    # detector mode: drift-confirmation probe passes fired
    probes: int = 0

    def summary(self) -> str:
        return (f"acc={self.avg_inference_acc*100:.2f}% "
                f"time={self.total_time_s:.1f}s energy={self.total_energy_j:.1f}J "
                f"rounds={self.rounds} recompiles={self.recompiles} "
                f"tflops={self.compute_tflops:.2f}")


@dataclass
class _SlotState:
    """Per-model-slot runtime state assembled by `run()`: the single-model
    path has exactly one ("default"); a ModelPool run has one per slot."""
    name: str
    model: Any
    bench: ContinualBenchmark
    controller: Any
    steps: TrainStepCache
    executor: FineTuneExecutor
    reference_params: Any = None


class ContinualRuntime:
    def __init__(self, model, benchmark: Optional[ContinualBenchmark],
                 controller,
                 cost_model: Optional[EdgeCostModel] = None,
                 opt_cfg=None, seed: int = 0,
                 boundaries: str = "oracle",       # 'oracle' | 'detector'
                 replay_batches: int = 2,
                 pretrain_epochs: int = 3,
                 inference_batch: int = 16,
                 quant_bits: int = 0,
                 unlabeled_fraction: float = 0.0,
                 calibrate_cost: bool = True,
                 inference_window: float = 0.0,
                 extra_hooks: Optional[List[RoundHook]] = None,
                 stream_benchmarks: Optional[Dict[int, ContinualBenchmark]] = None,
                 controller_factory: Optional[Callable[[Any], Any]] = None,
                 preemptible: bool = False,
                 preempt_resume_cost_s: float = 0.0,
                 model_pool: Optional[ModelPool] = None):
        """Deprecated legacy kwarg constructor. It builds the equivalent
        `RuntimeConfig` (quant_bits/unlabeled_fraction become per-slot
        `HookSpec`s) and delegates to the same resolution path as
        `from_config`, replaying bit-exact — the golden regression pins
        this — while steering callers to the declarative API."""
        warnings.warn(
            "ContinualRuntime legacy kwarg construction is deprecated; "
            "build a RuntimeConfig and use "
            "ContinualRuntime.from_config(cfg, ...) or edgeol_session(cfg) "
            "(DESIGN.md §11)", DeprecationWarning, stacklevel=2)
        hook_specs = []
        if quant_bits:
            hook_specs.append(HookSpec("fake-quant", {"bits": quant_bits}))
        if unlabeled_fraction:
            hook_specs.append(HookSpec("simsiam",
                                       {"fraction": unlabeled_fraction}))
        cfg = RuntimeConfig(
            slots={"default": SlotConfig(hooks=tuple(hook_specs))},
            seed=seed, boundaries=boundaries,
            replay_batches=replay_batches, pretrain_epochs=pretrain_epochs,
            inference_batch=inference_batch, calibrate_cost=calibrate_cost,
            inference_window=inference_window, preemptible=preemptible,
            preempt_resume_cost_s=preempt_resume_cost_s)
        self._init(**resolve_session(
            cfg, model=model, benchmark=benchmark, controller=controller,
            controller_factory=controller_factory,
            stream_benchmarks=stream_benchmarks, model_pool=model_pool,
            cost_model=cost_model, opt_cfg=opt_cfg,
            extra_hooks=extra_hooks))

    @classmethod
    def from_config(cls, cfg: RuntimeConfig, *, model=None, benchmark=None,
                    controller=None, controller_factory=None,
                    stream_benchmarks=None, model_pool=None,
                    cost_model=None, opt_cfg=None, extra_hooks=None,
                    workload_spec=None) -> "ContinualRuntime":
        """The declarative front door (DESIGN.md §11): materialize a
        session from a validated `RuntimeConfig`. Anything the config
        cannot express serializably — a custom benchmark object, a
        pre-built controller/factory/pool, a cost model, live RoundHooks,
        an already-scaled `WorkloadSpec` — is injected as a keyword and
        wins over what the config would build. When the config names a
        workload preset, the per-stream benchmarks and the compiled event
        timeline are materialized too and `run()` replays them by
        default."""
        rt = cls.__new__(cls)
        rt._init(**resolve_session(
            cfg, model=model, benchmark=benchmark, controller=controller,
            controller_factory=controller_factory,
            stream_benchmarks=stream_benchmarks, model_pool=model_pool,
            cost_model=cost_model, opt_cfg=opt_cfg,
            extra_hooks=extra_hooks, workload_spec=workload_spec))
        return rt

    def _init(self, *, model, benchmark, controller, cost_model, opt_cfg,
              seed, boundaries, replay_batches, pretrain_epochs,
              inference_batch, calibrate_cost, inference_window, hooks,
              slot_hooks, stream_benchmarks, controller_factory,
              preemptible, preempt_resume_cost_s, model_pool,
              compiled=False, use_pallas=False, session_events=None):
        # ModelPool construction path: the pool's slots carry the models,
        # benchmarks and (optionally) controllers; model/benchmark/
        # controller may be None and default to the first slot's. Slot
        # controllers missing from the pool are built through the
        # `controller_factory` seam, called with the *slot name*.
        self.pool = model_pool
        if model_pool is not None:
            first = next(iter(model_pool.slots.values()))
            model = model if model is not None else first.model
            benchmark = benchmark if benchmark is not None else first.benchmark
        self.model = model
        self.bench = benchmark
        self.controller = controller
        # multi-stream workloads: stream id -> its own benchmark (falls back
        # to `benchmark`, or to the stream's slot benchmark under a pool);
        # streams > 0 get controllers from `controller_factory(stream)` when
        # given, else share `controller` (one policy object observing every
        # stream). Under a pool the same factory seam builds *per-slot*
        # controllers instead, called with the slot name.
        self.stream_benchmarks = dict(stream_benchmarks or {})
        self.controller_factory = controller_factory
        self.cost = cost_model if cost_model is not None else EdgeCostModel()
        self.opt_cfg = opt_cfg or AdamWConfig(lr=1e-3)
        self.seed = seed
        self.boundaries = boundaries
        self.replay_batches = replay_batches
        self.pretrain_epochs = pretrain_epochs
        self.inference_batch = inference_batch
        self.calibrate_cost = calibrate_cost
        self.inference_window = inference_window
        # QoS: when True, fine-tuning rounds run as preemptible
        # reservations — a strictly-higher-priority inference arrival
        # splits the in-flight round (served at its arrival instant
        # instead of waiting for the round's end) and the round resumes,
        # its cost charged in segments that sum to the unpreempted charge.
        # Default False keeps the golden single-stream regression
        # bit-exact (rounds complete synchronously at trigger time).
        self.preemptible = preemptible
        # QoS: modeled checkpoint-resume overhead paid on each round split
        # (charged to the preempting stream; 0.0 = legacy free splits)
        self.preempt_resume_cost_s = preempt_resume_cost_s
        # compiled hot path (DESIGN.md §12): all training goes through the
        # donated fused-scan step, serving through deferred vmapped
        # dispatch, and the event loop through segment slicing. Default
        # False keeps the golden regression on the legacy eager path.
        # `segment` (overridable before run(); the equivalence property
        # test forces it off) additionally fuses whole same-shape runs —
        # per-event compiled execution is the same scan program at trip
        # count 1, so toggling it never moves a bit.
        self.compiled = bool(compiled)
        self.use_pallas = bool(use_pallas)
        self.segment = True
        # round hooks: model-wrapping ones bind first so every later
        # consumer (train steps, serving, SimSiam features) sees the
        # wrapped model. `hooks` wrap the single model; `slot_hooks` bind
        # per pool slot (a quantized CV slot next to an fp32 NLP slot) and
        # wrap that slot's model in _build_slots.
        self.hooks: List[RoundHook] = list(hooks or [])
        self.slot_hooks: Dict[str, List[RoundHook]] = {
            k: list(v) for k, v in (slot_hooks or {}).items()}
        for h in self.hooks:
            self.model = h.bind(self.model)
        # a config-built session may carry its workload's compiled event
        # timeline; run() replays it when no explicit events are passed
        self._session_events: Optional[List[Event]] = session_events
        # single-model step cache lives on the runtime (reused across
        # run() calls); pool slots build their own caches per run
        self.steps = None if model_pool is not None else \
            TrainStepCache(model=self.model, opt_cfg=self.opt_cfg)

    @property
    def session_events(self) -> Optional[List[Event]]:
        """The workload timeline a config-built session will replay when
        `run()` is called without explicit events (None otherwise)."""
        return self._session_events

    # -------------------------------------------------------------------
    def _build_slots(self, ledger: CostLedger,
                     rng: np.random.Generator) -> Dict[str, _SlotState]:
        """Assemble per-slot runtime state. The single-model path builds
        exactly one "default" slot wired to the runtime's own
        model/steps/cost and the *shared* rng — preserving the legacy RNG
        consumption order bit-for-bit."""
        slots: Dict[str, _SlotState] = {}
        if self.pool is None:
            replay = ReplayBuffer(
                self.bench.scenarios[0].train_batches[:self.replay_batches])
            executor = FineTuneExecutor(
                self.steps, self.cost, ledger, replay, rng=rng,
                hooks=self.hooks, calibrate_cost=self.calibrate_cost,
                preempt_resume_cost_s=self.preempt_resume_cost_s,
                compiled=self.compiled, fuse=self.segment)
            slots[DEFAULT_MODEL] = _SlotState(
                DEFAULT_MODEL, self.model, self.bench, self.controller,
                self.steps, executor)
            return slots
        for i, slot in enumerate(self.pool.slots.values()):
            # per-slot RoundHooks (RuntimeConfig SlotConfig.hooks): wrap
            # this slot's model only — its train steps, serving lane and
            # pretraining all see the wrapped model, other slots stay
            # untouched (a quantized CV slot next to an fp32 NLP slot)
            hooks = self.slot_hooks.get(slot.name, [])
            model = slot.model
            for h in hooks:
                model = h.bind(model)
            ctrl = slot.controller
            if ctrl is None and self.controller_factory is not None:
                ctrl = self.controller_factory(slot.name)
            if ctrl is None:
                ctrl = self.controller
            if ctrl is None:
                raise ValueError(
                    f"slot {slot.name!r} has no controller: set "
                    f"ModelSlot.controller or pass controller_factory")
            steps = TrainStepCache(model=model, opt_cfg=self.opt_cfg)
            replay = ReplayBuffer(
                slot.benchmark.scenarios[0].train_batches[:self.replay_batches])
            executor = FineTuneExecutor(
                steps, slot.cost, ledger, replay,
                rng=np.random.default_rng([self.seed, i]),
                hooks=hooks, calibrate_cost=self.calibrate_cost,
                model_name=slot.name,
                preempt_resume_cost_s=self.preempt_resume_cost_s,
                compiled=self.compiled, fuse=self.segment)
            slots[slot.name] = _SlotState(slot.name, model,
                                          slot.benchmark, ctrl, steps,
                                          executor)
        return slots

    # -------------------------------------------------------------------
    def run(self, events: Optional[List[Event]] = None,
            inferences_total: Optional[int] = None,
            data_dist: Optional[str] = None,
            inf_dist: Optional[str] = None) -> RunResult:
        """Drive the full continual-learning session. The timeline comes
        from, in precedence order: explicit `events`, the config-built
        session's compiled workload (`session_events`), or a legacy
        timeline generated from `inferences_total`/`data_dist`/`inf_dist`
        (defaults 60/"poisson"/"poisson") — the generation knobs apply
        only to that last case."""
        timeline_kw = {k: v for k, v in (("inferences_total",
                                          inferences_total),
                                         ("data_dist", data_dist),
                                         ("inf_dist", inf_dist))
                       if v is not None}
        if timeline_kw and (events is not None
                            or self._session_events is not None):
            warnings.warn(
                f"run(): {sorted(timeline_kw)} only shape the generated "
                f"legacy timeline and are ignored when events are "
                f"supplied (explicit or from the session's workload "
                f"config)", UserWarning, stacklevel=2)
        bench = self.bench
        rng = np.random.default_rng(self.seed)
        ledger = CostLedger()
        slots = self._build_slots(ledger, rng)
        primary_slot = next(iter(slots.values()))
        primary_ctrl = self.controller if self.controller is not None \
            else primary_slot.controller

        # --- pretrain every slot on its scenario 0 (not cost-accounted;
        # paper §V-A) and measure slot memory footprints -----------------
        for st in slots.values():
            params = st.model.init(jax.random.PRNGKey(self.seed))
            opt_state = make_optimizer_state(st.model, self.opt_cfg, params)
            if st.steps.donate:
                # donation needs de-aliased buffers: init trees share
                # zero-filled leaves (and constant-cache hits), which a
                # donating step would otherwise donate twice
                params = jax.tree.map(jnp.copy, params)
                opt_state = jax.tree.map(jnp.copy, opt_state)
            plan0 = st.controller.plan
            pre = [b for _ in range(self.pretrain_epochs)
                   for b in st.bench.scenarios[0].train_batches]
            if self.compiled:
                # one fused scan per same-shape run of pretrain batches
                for run in same_shape_runs(pre):
                    params, opt_state, _ = st.steps.fused_call(
                        plan0, params, opt_state, run)
            else:
                step0 = st.steps.get(plan0)
                for b in pre:
                    params, opt_state, _ = step0(params, opt_state, as_jnp(b))
            st.reference_params = params  # "initial model before fine-tuning"
            st.executor.load(params, opt_state)
        if self.pool is not None:
            for name, st in slots.items():
                self.pool.set_memory(name, tree_mb(st.executor.params,
                                                   st.executor.opt_state))
            self.pool.warm()

        if events is None and self._session_events is not None:
            # config-built session: replay the workload's compiled timeline
            events = list(self._session_events)
        if events is None:
            events = build_timeline(
                num_scenarios=bench.num_scenarios - 1,
                batches_per_scenario=len(bench.scenarios[1].train_batches),
                inferences_total=timeline_kw.get("inferences_total", 60),
                seed=self.seed,
                data_dist=timeline_kw.get("data_dist", "poisson"),
                inf_dist=timeline_kw.get("inf_dist", "poisson"))
            # shift scenario ids by 1 (scenario 0 = pretraining)
            events = [dataclasses.replace(e, scenario=e.scenario + 1)
                      for e in events]

        # --- compose the subsystems -------------------------------------
        # per-stream policy state: stream 0 is the primary controller;
        # extra streams (multi-stream workloads) get their own controller
        # from the factory, or share the primary one. Streams *absent*
        # from the start-of-run event list (e.g. a probe Event pushed onto
        # the live scheduler mid-drain — detector-driven probes) fall back
        # to the primary controller/benchmark via the accessors below
        # instead of KeyError-ing the callbacks. Under a ModelPool a
        # stream's controller is its *slot's* (streams sharing a model
        # share the policy that owns its freeze plan).
        stream_ids = sorted({e.stream for e in events}) or [0]
        stream_slot: Dict[int, str] = {}
        if self.pool is not None:
            for e in events:
                stream_slot.setdefault(e.stream, e.modality)
            for st_id, name in stream_slot.items():
                self.pool.slot(name)  # raise early on an unknown modality

        def slot_of(st: int) -> _SlotState:
            return slots.get(stream_slot.get(st, primary_slot.name),
                             primary_slot)

        controllers: Dict[int, Any] = {}
        for st in stream_ids:
            if self.pool is not None:
                controllers[st] = slot_of(st).controller
            elif st == 0 or self.controller_factory is None:
                controllers[st] = primary_ctrl
            else:
                controllers[st] = self.controller_factory(st)
        # monolithic controllers predating the staleness/priority keywords
        # keep working: wrap them so the drive loop can always pass the
        # full signal set (same objects underneath — state is shared)
        controllers = {st: adapt_controller(c)
                       for st, c in controllers.items()}
        primary_ctrl = adapt_controller(primary_ctrl)

        def ctrl_for(st: int):
            return controllers.get(st, primary_ctrl)

        def bench_for(st: int) -> ContinualBenchmark:
            b = self.stream_benchmarks.get(st)
            return b if b is not None else slot_of(st).bench

        # QoS: a stream's priority rides on its events (StreamSpec.priority
        # -> Event.priority); a round reserves the device at its stream's
        # priority, so only strictly-higher-priority arrivals can split it.
        stream_priority: Dict[int, int] = {st: 0 for st in stream_ids}
        for e in events:
            stream_priority[e.stream] = max(stream_priority[e.stream],
                                            e.priority)
        scheduler = EventScheduler(events)
        # live handle: controller callbacks / tests may push events onto
        # the running timeline (mid-drain push is supported)
        self.scheduler = scheduler
        pending_change = {st: False for st in stream_ids}
        # probes_pushed numbers probe Events; probes_fired counts the ones
        # actually dispatched (a detection during the post-drain flush
        # pushes onto an already-drained scheduler and never runs)
        probes_pushed = [0]
        probes_fired = [0]
        # per-stream policy latches, owned by the runtime — NOT stored on
        # the controller object: streams may share one controller (no
        # controller_factory), and the first stream's start_scenario must
        # not suppress every other stream's
        scenario_started: Dict[int, bool] = {}
        # per-stream staleness: wall-clock since the stream's last round
        # completed (run start counts as "fresh"), fed to should_trigger
        # so priority-aware controllers can weigh starvation
        last_round_end: Dict[int, float] = {}
        # scenario snapshot at round launch: a lazily-finalized
        # (preemptible) round must validate against the scenario whose
        # batches it trained, not whatever the stream drifted to by the
        # time the timeline passes the reservation's end
        launch_scenario: Dict[int, int] = {}

        def served(logits, stream=0) -> bool:
            # route the request's logits to its stream's controller; a True
            # return (detected scenario change) is latched per stream — or,
            # in detector mode, schedules a dedicated drift-confirmation
            # probe on the live timeline instead (DESIGN.md: a detection
            # from noisy request logits is confirmed by a forward pass
            # over the stream's probe data before the policy reacts).
            hit = ctrl_for(stream).inference_served(logits)
            if hit:
                if self.boundaries == "detector":
                    probes_pushed[0] += 1
                    scheduler.push(Event(
                        scheduler.now, "probe",
                        scheduler.scenario_of(stream), probes_pushed[0] - 1,
                        stream=stream,
                        modality=stream_slot.get(stream, "cv")))
                else:
                    pending_change[stream] = True
            return hit

        server = InferenceServer(primary_slot.model,
                                 batch_window=self.inference_window,
                                 on_served=served, fused=self.compiled)
        for name, st in slots.items():
            server.register(name, st.model)
            server.publish(st.executor.params, 0.0, slot=name)
        val_curve: List[float] = []

        def acquire(slot: _SlotState, now: float, stream: int) -> None:
            # ModelPool residency: touching a cold slot swaps it in — a
            # real ledger charge (t_swap/e_swap, attributed to the
            # touching stream and the loaded slot) and real device
            # occupancy, so whatever triggered the touch waits it out.
            # Deliberate interaction with QoS: the swap occupancy becomes
            # the scheduler's in-flight reservation, so a preemptible
            # round with swap IO queued behind it stops being splittable
            # (`can_preempt` goes False) — splitting it would have to
            # slide the committed IO slot around, which the single-
            # reservation timeline cannot account for (DESIGN.md §9).
            if self.pool is None:
                return
            t_swap, e_swap, _ = self.pool.ensure_resident(slot.name)
            if t_swap:
                ledger.charge_swap(time_s=t_swap, energy_j=e_swap,
                                   model=slot.name, stream=stream)
                scheduler.occupy(now, t_swap, stream=stream)

        def complete(slot: _SlotState, report) -> None:
            # a round's results reach the rest of the system when it
            # completes: publish to serving, validate, notify the
            # stream's controller, charge SimFreeze's CKA probes
            stream = report.stream
            ctrl = ctrl_for(stream)
            # the stream's publish policy decides when the new params
            # reach serving (default: bug-compat immediate, DESIGN.md §5;
            # round-end keeps pre-round params for mid-round arrivals)
            pub = getattr(ctrl, "publish_policy", None)
            if pub is None:
                server.publish(slot.executor.params, report.end,
                               slot=slot.name)
            else:
                server.publish(slot.executor.params,
                               pub.visible_at(report.end), slot=slot.name,
                               delayed=pub.delayed)
            # validation accuracy (labeled 5% split) -> LazyTune; the
            # split belongs to the scenario current at round *launch*
            val = bench_for(stream).scenarios[
                launch_scenario.pop(stream,
                                    scheduler.scenario_of(stream))].val
            val_acc, _ = evaluate(slot.model, slot.executor.params,
                                  as_jnp(val))
            val_curve.append(val_acc)
            cka_before = ctrl.simfreeze.state.cka_flops \
                if hasattr(ctrl, "simfreeze") else 0.0
            ctrl.round_finished(report.iters, val_acc, slot.executor.params)
            if hasattr(ctrl, "simfreeze"):
                dcka = ctrl.simfreeze.state.cka_flops - cka_before
                if dcka:
                    tc, ec = slot.executor.cost.compute_cost(dcka)
                    ledger.charge_probe("cka", tc, ec, stream=stream,
                                        model=slot.name)
            last_round_end[stream] = report.end

        def settle(now: float) -> None:
            # preemptible rounds complete lazily: once the timeline passes
            # a reservation's end, finalize it (train the remaining
            # checkpointed batches, charge the exact-remainder segment).
            # At most one round is in flight across all slots (one device)
            for st in slots.values():
                report = st.executor.finalize_round(now)
                if report is not None:
                    complete(st, report)

        def finish_round(now: float, stream: int = 0) -> None:
            slot = slot_of(stream)
            acquire(slot, now, stream)
            launch_scenario[stream] = scheduler.scenario_of(stream)
            report = slot.executor.execute_round(
                ctrl_for(stream).plan, now, scheduler, stream=stream,
                priority=stream_priority.get(stream, 0),
                preemptible=self.preemptible)
            if report is None and slot.executor.active_round is None:
                launch_scenario.pop(stream, None)  # nothing was buffered
            elif report is not None:  # synchronous (non-preemptible) path
                complete(slot, report)

        def on_scenario_change(previous: int, ev: Event) -> None:
            # keep a replay sample of the just-entered scenario
            sc = bench_for(ev.stream).scenarios[ev.scenario]
            slot_of(ev.stream).executor.replay.add(
                sc.train_batches[ev.index % len(sc.train_batches)])

        def on_data(ev: Event, boundary: bool) -> None:
            st = ev.stream
            settle(ev.time)
            ctrl = ctrl_for(st)
            slot = slot_of(st)
            sc = bench_for(st).scenarios[ev.scenario]
            batch = sc.train_batches[ev.index % len(sc.train_batches)]
            # bound micro-batch deferral: a queued group whose window has
            # elapsed is served now, so controller signals driven by
            # inference_served (LazyTune decay, scenario detection) lag by
            # at most one window.
            server.expire(ev.time)
            server.drain()  # fused mode: deliver deferred serves now
            change = pending_change.get(st, False) \
                and self.boundaries == "detector"
            if (boundary and self.boundaries == "oracle") or change:
                pending_change[st] = False
                if ctrl.plan is not None and hasattr(ctrl, "scenario_changed"):
                    ctrl.scenario_changed(slot.executor.params, as_jnp(batch))
            if getattr(ctrl, "needs_reference", True) and \
                    hasattr(ctrl, "start_scenario") and \
                    (boundary or (scheduler.scenario_of(st)
                                  and not scenario_started.get(st, False))):
                ctrl.start_scenario(slot.reference_params, as_jnp(batch))
                scenario_started[st] = True
            slot.executor.enqueue(batch, stream=st)
            if ctrl.should_trigger(slot.executor.pending_for(st),
                                   staleness=ev.time
                                   - last_round_end.get(st, 0.0),
                                   priority=stream_priority.get(st, 0)) and \
                    scheduler.idle_at(ev.time):
                finish_round(ev.time, st)

        def on_inference(ev: Event) -> None:
            st = ev.stream
            settle(ev.time)
            b = bench_for(st)
            slot = slot_of(st)
            cur = scheduler.scenario_of(st)
            sc = b.scenarios[min(ev.scenario, cur) or ev.scenario]
            test = b.scenarios[max(cur, 1)].test \
                if ev.scenario <= cur else sc.test
            idx = rng.choice(len(test["labels"]),
                             min(self.inference_batch, len(test["labels"])),
                             replace=False)
            # QoS serving latency (arrival -> modeled service instant): an
            # idle device serves at once; a busy one makes the request
            # wait out the round's occupancy — unless the arrival outranks
            # a preemptible round, which it splits and is served at its
            # arrival time (the round resumes; with a zero resume cost its
            # end is unchanged). A request for a *cold* ModelPool slot
            # first waits out the slot's swap-in (and never preempts — the
            # swap IO would stall the split anyway).
            swap_needed = self.pool is not None \
                and not self.pool.is_resident(slot.name)
            if scheduler.idle_at(ev.time) and not swap_needed:
                latency = 0.0
            elif not swap_needed and scheduler.can_preempt(ev.time,
                                                           ev.priority):
                active = next(s.executor for s in slots.values()
                              if s.executor.active_round is not None)
                active.preempt(ev.time, scheduler, preempting_stream=st)
                latency = 0.0
            else:
                acquire(slot, ev.time, st)
                latency = scheduler.busy_until - ev.time
            server.submit(ev.time, {k: v[idx] for k, v in test.items()},
                          stream=st, latency=latency, slot=slot.name)

        def on_probe(ev: Event) -> None:
            # detector-driven probe (ROADMAP): confirm a flagged drift
            # with a dedicated forward pass over the stream's current
            # validation split before the policy reacts. The pass is
            # charged as probe compute (~1/3 of a measured train step:
            # forward only) — and, like any other touch, a probe on a
            # cold ModelPool slot first pays the swap-in; confirmation
            # latches the per-stream change flag exactly as a direct
            # detection used to.
            st = ev.stream
            settle(ev.time)
            server.drain()  # fused mode: serve anything deferred first
            probes_fired[0] += 1
            slot = slot_of(st)
            acquire(slot, ev.time, st)
            ctrl = ctrl_for(st)
            b = bench_for(st)
            sc = b.scenarios[min(max(scheduler.scenario_of(st), ev.scenario,
                                     1), len(b.scenarios) - 1)]
            _, logits = evaluate(slot.model, slot.executor.params,
                                 as_jnp(sc.val))
            flops = slot.steps.flops(ctrl.plan,
                                     as_jnp(sc.train_batches[0])) / 3.0
            tc, ec = slot.executor.cost.compute_cost(flops)
            ledger.charge_probe("probe", tc, ec, stream=st, model=slot.name)
            confirm = getattr(ctrl, "probe_served", None)
            if confirm is None or confirm(logits):
                pending_change[st] = True

        def on_inference_event(ev: Event) -> None:
            # compiled but unsegmented (detector mode, or `segment` off):
            # serve each event's deferred dispatch before the next event,
            # so detector probes are pushed at the same timeline instant
            # as on the eager path
            on_inference(ev)
            server.drain()

        def on_inference_segment(segment: List[Event]) -> None:
            # the scheduler hands over a maximal run of consecutive
            # inference events; per-event bookkeeping (params resolution,
            # latency/preemption, RNG draws) is unchanged — only the
            # device dispatch is deferred and fused into one drain
            for ev in segment:
                on_inference(ev)
            server.drain()

        # segment slicing stays off in detector mode: `served` pushes
        # probe Events at scheduler.now mid-drain, so serving must stay
        # aligned with the per-event clock
        segmented = (self.compiled and self.segment
                     and self.boundaries != "detector")
        scheduler.run(
            on_data=on_data,
            on_inference=on_inference_event if self.compiled
            else on_inference,
            on_scenario_change=on_scenario_change, on_probe=on_probe,
            on_inference_segment=on_inference_segment if segmented
            else None)
        settle(float("inf"))  # finalize a round still in flight at drain end
        server.flush()
        server.drain()
        # trailing flush: any buffered data still fine-tunes (no data dropped)
        for slot in slots.values():
            for st in slot.executor.pending_streams:
                finish_round(scheduler.busy_until, st)
                settle(float("inf"))

        stats = primary_ctrl.stats() if hasattr(primary_ctrl, "stats") else {}
        per_stream: Dict[int, Dict[str, float]] = {}
        # include streams first seen mid-run (events pushed onto the live
        # scheduler carry streams the start-of-run list never saw)
        for st in sorted(set(stream_ids) | set(ledger.per_stream)
                         | set(server.accs_by_stream)):
            cell = dict(ledger.per_stream.get(
                st, {k: 0.0 for k in STREAM_KEYS}))
            accs = server.accs_by_stream.get(st, [])
            cell["avg_inference_acc"] = float(np.mean(accs)) if accs else 0.0
            cell["inferences"] = float(len(accs))
            lats = server.latencies_by_stream.get(st, [])
            cell["latency_p50"] = float(np.percentile(lats, 50)) if lats else 0.0
            cell["latency_p95"] = float(np.percentile(lats, 95)) if lats else 0.0
            per_stream[st] = cell
        per_model: Dict[str, Dict[str, float]] = {}
        for name in sorted(set(slots) | set(ledger.per_model)
                           | set(server.accs_by_slot)):
            cell = dict(ledger.per_model.get(
                name, {k: 0.0 for k in MODEL_KEYS}))
            accs = server.accs_by_slot.get(name, [])
            cell["avg_inference_acc"] = float(np.mean(accs)) if accs else 0.0
            cell["inferences"] = float(len(accs))
            per_model[name] = cell
        return RunResult(
            avg_inference_acc=server.avg_acc,
            total_time_s=ledger.total_time_s,
            total_energy_j=ledger.total_energy_j,
            compute_tflops=ledger.compute_tflops, rounds=ledger.rounds,
            recompiles=sum(st.steps.recompiles for st in slots.values())
            if self.pool is not None else self.steps.recompiles,
            inference_accs=server.accs,
            breakdown=ledger.breakdown, controller_stats=stats,
            val_curve=val_curve, per_stream=per_stream,
            per_model=per_model, preemptions=ledger.preemptions,
            swaps=ledger.swaps, probes=probes_fired[0])


def edgeol_session(cfg: RuntimeConfig, **inject) -> ContinualRuntime:
    """Declarative session front door (DESIGN.md §11): build a ready
    `ContinualRuntime` from a `RuntimeConfig`. Keyword injections are the
    same as `ContinualRuntime.from_config` (live objects win over what
    the config would build). When the config names a workload preset,
    `session.run()` replays its compiled event timeline::

        res = edgeol_session(RuntimeConfig(workload="mixed", ...)).run()
    """
    return ContinualRuntime.from_config(cfg, **inject)
