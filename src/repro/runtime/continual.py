"""ContinualRuntime — the event-driven continual-learning loop of the paper
(Fig. 1): training batches and inference requests arrive on a shared
timeline; a controller (ETuner or a baseline) decides when to launch
fine-tuning rounds and which layers are frozen; the cost model charges
per-round overheads (system init / load / save), per-plan recompiles and
XLA-*measured* compute FLOPs.

Faithfulness notes:
- the model is pre-trained on scenario 0 ("originally well-trained in the
  first scenario"); costs are accounted from scenario 1 on;
- a small replay buffer stands in for the CWR anti-forgetting technique of
  the CORe50 paper (documented substitution, DESIGN.md);
- inference requests are served by the params *visible* at request time: a
  round occupies wall-clock, so requests landing mid-round see the previous
  params — this reproduces the "outdated model" effect LazyTune must
  balance (paper §III-A);
- validation accuracy (5% split) drives LazyTune; inference accuracy is
  only recorded, never used by the controller (paper §IV-A).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.arrivals import Event, build_timeline
from repro.data.streams import ContinualBenchmark
from repro.optim import AdamWConfig
from repro.runtime.costmodel import EdgeCostModel
from repro.runtime.train_loop import TrainStepCache, evaluate, make_optimizer_state


@dataclass
class RunResult:
    avg_inference_acc: float
    total_time_s: float
    total_energy_j: float
    compute_tflops: float
    rounds: int
    recompiles: int
    inference_accs: List[float] = field(default_factory=list)
    breakdown: Dict[str, float] = field(default_factory=dict)
    controller_stats: Dict[str, Any] = field(default_factory=dict)
    val_curve: List[float] = field(default_factory=list)

    def summary(self) -> str:
        return (f"acc={self.avg_inference_acc*100:.2f}% "
                f"time={self.total_time_s:.1f}s energy={self.total_energy_j:.1f}J "
                f"rounds={self.rounds} recompiles={self.recompiles} "
                f"tflops={self.compute_tflops:.2f}")


def _as_jnp(batch: dict) -> dict:
    return {k: jnp.asarray(v) for k, v in batch.items()}


class ContinualRuntime:
    def __init__(self, model, benchmark: ContinualBenchmark, controller,
                 cost_model: EdgeCostModel = EdgeCostModel(),
                 opt_cfg=None, seed: int = 0,
                 boundaries: str = "oracle",       # 'oracle' | 'detector'
                 replay_batches: int = 2,
                 pretrain_epochs: int = 3,
                 inference_batch: int = 16,
                 quant_bits: int = 0,
                 unlabeled_fraction: float = 0.0,
                 calibrate_cost: bool = True):
        self.model = model
        self.bench = benchmark
        self.controller = controller
        self.cost = cost_model
        self.opt_cfg = opt_cfg or AdamWConfig(lr=1e-3)
        self.seed = seed
        self.boundaries = boundaries
        self.replay_batches = replay_batches
        self.pretrain_epochs = pretrain_epochs
        self.inference_batch = inference_batch
        self.quant_bits = quant_bits
        if quant_bits:
            self.model = _quantized_model(model, quant_bits)
        self.unlabeled_fraction = unlabeled_fraction
        self.calibrate_cost = calibrate_cost
        self._semi_head = None
        self._semi_step = None
        self.steps = TrainStepCache(model=self.model, opt_cfg=self.opt_cfg)

    # -------------------------------------------------------------------
    def run(self, events: Optional[List[Event]] = None,
            inferences_total: int = 60, data_dist: str = "poisson",
            inf_dist: str = "poisson") -> RunResult:
        bench, model = self.bench, self.model
        rng = np.random.default_rng(self.seed)
        params = model.init(jax.random.PRNGKey(self.seed))
        opt_state = make_optimizer_state(model, self.opt_cfg, params)

        # --- pretrain on scenario 0 (not cost-accounted; paper §V-A) ----
        step0 = self.steps.get(self.controller.plan)
        for _ in range(self.pretrain_epochs):
            for b in bench.scenarios[0].train_batches:
                params, opt_state, _ = step0(params, opt_state, _as_jnp(b))
        reference_params = params  # "initial model before fine-tuning"

        if events is None:
            events = build_timeline(
                num_scenarios=bench.num_scenarios - 1,
                batches_per_scenario=len(bench.scenarios[1].train_batches),
                inferences_total=inferences_total, seed=self.seed,
                data_dist=data_dist, inf_dist=inf_dist)
            # shift scenario ids by 1 (scenario 0 = pretraining)
            events = [dataclasses.replace(e, scenario=e.scenario + 1)
                      for e in events]

        ctrl = self.controller
        cur_scenario = 0
        buffer: List[dict] = []
        replay: List[dict] = list(bench.scenarios[0].train_batches[:self.replay_batches])
        pending_change = False

        total_time = 0.0
        total_energy = 0.0
        total_flops = 0.0
        rounds = 0
        bd = {"t_compute": 0.0, "t_overhead": 0.0, "e_compute": 0.0,
              "e_overhead": 0.0, "t_cka": 0.0, "e_cka": 0.0}
        inference_accs: List[float] = []
        val_curve: List[float] = []
        busy_until = 0.0
        visible_params = params
        visible_at = 0.0
        compiled_plans = set()

        def run_round(now: float):
            nonlocal params, opt_state, total_time, total_energy, rounds, \
                total_flops, busy_until, visible_params, visible_at
            if not buffer:
                return
            plan = ctrl.plan
            recompile = 0
            if plan not in compiled_plans:
                compiled_plans.add(plan)
                recompile = 1
            step = self.steps.get(plan)
            batches = list(buffer)
            buffer.clear()
            if replay:
                batches.append(replay[rng.integers(len(replay))])
            prev_params = params
            rng_lab = np.random.default_rng(rounds + 17)
            for b in batches:
                jb = _as_jnp(b)
                if self.unlabeled_fraction and "images" in b and \
                        rng_lab.random() < self.unlabeled_fraction:
                    # paper §IV-C: self-supervised (SimSiam) pass on
                    # unlabeled data, then supervised passes on labeled data
                    params = self._semi_update(params, jb)
                    continue
                params, opt_state, _ = step(params, opt_state, jb)
            flops = self.steps.flops(plan, _as_jnp(batches[0])) * len(batches)
            if self.calibrate_cost:
                # Preserve the paper's compute/overhead balance (Fig. 3)
                # at reduced model scale: scale the device throughput so a
                # 2-iteration immediate round spends ~0.8 s in compute vs
                # the 1.1 s fixed overheads (58%/42% split). Documented in
                # DESIGN.md ("hardware adaptation").
                per_iter = flops / max(len(batches), 1)
                self.cost = dataclasses.replace(
                    self.cost, flops_per_sec=max(per_iter * 2 / 0.8, 1.0))
                self.calibrate_cost = False
            t, e, parts = self.cost.round_cost(flops, recompiles=recompile)
            total_time += t
            total_energy += e
            total_flops += flops
            rounds += 1
            for k in ("t_compute", "t_overhead", "e_compute", "e_overhead"):
                bd[k] += parts[k]
            start = max(now, busy_until)
            busy_until = start + t
            visible_params, visible_at = params, busy_until
            # validation accuracy (labeled 5% split) -> LazyTune
            val = bench.scenarios[cur_scenario].val
            val_acc, _ = evaluate(model, params, _as_jnp(val))
            val_curve.append(val_acc)
            cka_before = ctrl.simfreeze.state.cka_flops if hasattr(ctrl, "simfreeze") else 0.0
            ctrl.round_finished(len(batches), val_acc, params)
            if hasattr(ctrl, "simfreeze"):
                dcka = ctrl.simfreeze.state.cka_flops - cka_before
                if dcka:
                    tc, ec = self.cost.compute_cost(dcka)
                    bd["t_cka"] += tc
                    bd["e_cka"] += ec
                    total_time += tc
                    total_energy += ec

        for ev in events:
            if ev.kind == "data":
                batch = bench.scenarios[ev.scenario].train_batches[
                    ev.index % len(bench.scenarios[ev.scenario].train_batches)]
                new_scenario = ev.scenario != cur_scenario
                if new_scenario:
                    cur_scenario = ev.scenario
                    # keep a replay sample of the previous scenario
                    if len(replay) < 6:
                        replay.append(batch)
                if (new_scenario and self.boundaries == "oracle") or pending_change:
                    pending_change = False
                    if ctrl.plan is not None and hasattr(ctrl, "scenario_changed"):
                        ctrl.scenario_changed(params, _as_jnp(batch))
                if getattr(ctrl, "needs_reference", True) and \
                        hasattr(ctrl, "start_scenario") and \
                        (new_scenario or (cur_scenario and not getattr(
                            ctrl, "_scenario_started", False))):
                    ctrl.start_scenario(reference_params, _as_jnp(batch))
                    ctrl._scenario_started = True
                buffer.append(batch)
                if ctrl.should_trigger(len(buffer)) and ev.time >= busy_until:
                    run_round(ev.time)
            else:  # inference request
                sc = bench.scenarios[min(ev.scenario, cur_scenario) or ev.scenario]
                test = bench.scenarios[max(cur_scenario, 1)].test \
                    if ev.scenario <= cur_scenario else sc.test
                idx = rng.choice(len(test["labels"]),
                                 min(self.inference_batch, len(test["labels"])),
                                 replace=False)
                req = {k: v[idx] for k, v in test.items()}
                use = visible_params if ev.time >= visible_at else params
                acc, logits = evaluate(model, use, _as_jnp(req))
                inference_accs.append(acc)
                changed = ctrl.inference_served(logits)
                if changed and self.boundaries == "detector":
                    pending_change = True

        # trailing flush: any buffered data still fine-tunes (no data dropped)
        if buffer:
            run_round(busy_until)

        stats = ctrl.stats() if hasattr(ctrl, "stats") else {}
        return RunResult(
            avg_inference_acc=float(np.mean(inference_accs)) if inference_accs else 0.0,
            total_time_s=total_time, total_energy_j=total_energy,
            compute_tflops=total_flops / 1e12, rounds=rounds,
            recompiles=self.steps.recompiles, inference_accs=inference_accs,
            breakdown=bd, controller_stats=stats, val_curve=val_curve)


    # ------------------------------------------------------------------
    # semi-supervised (SimSiam) auxiliary update (paper §IV-C)

    def _semi_update(self, params, batch):
        import jax as _jax

        from repro.core import semi

        if self._semi_head is None:
            feats = self.model.features(params, batch)
            fdim = int(np.asarray(feats[-1]).reshape(
                np.asarray(feats[-1]).shape[0], -1).shape[-1])
            self._feat_dim = min(fdim, 256)
            self._semi_head = semi.init_simsiam_head(
                _jax.random.PRNGKey(1), self._feat_dim)

            def pooled(p, images):
                fs = self.model.features(p, {"images": images})
                f = fs[-1]
                f = f.reshape(f.shape[0], -1)
                return f[:, :self._feat_dim].astype(jnp.float32)

            def semi_step(p, head, rng, images):
                def lf(q):
                    return semi.simsiam_loss(pooled, head, q, rng, images)

                g = _jax.grad(lf)(p)
                return _jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - 1e-3 * b.astype(jnp.float32)).astype(a.dtype),
                    p, g)

            self._semi_step = _jax.jit(semi_step)
        rng = jax.random.PRNGKey(int(np.random.default_rng(0).integers(1 << 30)))
        return self._semi_step(params, self._semi_head, rng, batch["images"])


# ---------------------------------------------------------------------------
# simulated quantization-aware training (paper §V-G, Table VIII)


def _fake_quant(x, bits: int):
    if x.dtype not in (jnp.float32, jnp.bfloat16):
        return x
    xf = x.astype(jnp.float32)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8) / qmax
    q = jnp.round(xf / scale) * scale
    return (xf + jax.lax.stop_gradient(q - xf)).astype(x.dtype)  # STE


def _quantized_model(model, bits: int):
    def loss(params, batch, plan=None):
        qp = jax.tree.map(lambda p: _fake_quant(p, bits), params)
        return model.loss(qp, batch, plan)

    def predict(params, batch):
        qp = jax.tree.map(lambda p: _fake_quant(p, bits), params)
        return model.predict(qp, batch)

    return dataclasses.replace(model, loss=loss, predict=predict)
