"""ContinualRuntime — composition root of the event-driven continual-
learning loop of the paper (Fig. 1): training batches and inference
requests arrive on a shared timeline; a controller (ETuner or a baseline)
decides when to launch fine-tuning rounds and which layers are frozen; the
cost model charges per-round overheads (system init / load / save),
per-plan recompiles and XLA-*measured* compute FLOPs.

The runtime itself is deliberately thin. It wires four owned subsystems
(DESIGN.md §1):

- `EventScheduler` (runtime/scheduler.py) — the priority-ordered timeline,
  wall-clock/`busy_until` device occupancy, scenario boundaries;
- `InferenceServer` (runtime/inference.py) — request serving, the
  arrival-time params-visibility seam, opt-in micro-batched serving;
- `FineTuneExecutor` (runtime/executor.py) — round execution, the replay
  buffer, and `RoundHook`s (SimSiam semi-supervised pass, fake-quant QAT);
- `CostLedger` (runtime/ledger.py) — all time/energy/FLOPs accounting;

plus, optionally, a **`ModelPool`** (runtime/modelpool.py, DESIGN.md §9):
one model slot per modality — its own params/optimizer/steps/replay/
controller and per-slot cost calibration — multiplexed over the one
shared device timeline under a device memory budget (cold slots pay a
real load/save swap charge). Without a pool the runtime runs its single
model under the reserved "default" slot, byte-identical to the pre-pool
behaviour (the golden regression suite pins this).

Controllers implement the `ControllerProtocol` documented in
core/controller.py; the runtime drives them from scheduler callbacks and
never reaches into their internals. Monolithic controllers predating the
policy decomposition are adapted transparently
(`repro.core.policies.adapt_controller`), and a controller's optional
`publish_policy` decides when a round's params reach serving.

Construction (DESIGN.md §11): the front door is the declarative
`RuntimeConfig` — `ContinualRuntime.from_config(cfg, ...)` or
`edgeol_session(cfg)` — with live objects (a custom benchmark, a
pre-built controller/pool, a cost model) injected alongside the config.
The legacy ~18-kwarg constructor still works but is deprecated: it
delegates to the same resolution path and emits a `DeprecationWarning`.

Faithfulness notes:
- the model is pre-trained on scenario 0 ("originally well-trained in the
  first scenario"); costs are accounted from scenario 1 on;
- a small replay buffer stands in for the CWR anti-forgetting technique of
  the CORe50 paper (documented substitution, DESIGN.md);
- inference requests resolve their params at *arrival* time via the
  InferenceServer's visibility seam; a round occupies wall-clock, which is
  the "outdated model" effect LazyTune must balance (paper §III-A). Note
  the pre-decomposition monolith served mid-round requests by the round's
  freshly trained params (visible == latest); that behaviour is kept
  bug-compatible and the seam documented in DESIGN.md §5;
- validation accuracy (5% split) drives LazyTune; inference accuracy is
  only recorded, never used by the controller (paper §IV-A).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.data.arrivals import Event, build_timeline
from repro.data.streams import ContinualBenchmark
from repro.obs.trace import NULL_TRACER
from repro.optim import AdamWConfig
from repro.runtime.config import (DeviceConfig, HookSpec, RuntimeConfig,
                                  SlotConfig, resolve_session)
from repro.runtime.costmodel import EdgeCostModel, scale_cost
from repro.runtime.executor import (FineTuneExecutor, ReplayBuffer,
                                    RoundHook, fake_quant, quantized_model)
from repro.runtime.ledger import DEFAULT_DEVICE, DEFAULT_MODEL, CostLedger
from repro.runtime.modelpool import ModelPool
from repro.runtime.train_loop import TrainStepCache

# legacy aliases (pre-decomposition import sites)
_fake_quant = fake_quant
_quantized_model = quantized_model


@dataclass
class RunResult:
    avg_inference_acc: float
    total_time_s: float
    total_energy_j: float
    compute_tflops: float
    rounds: int
    recompiles: int
    inference_accs: List[float] = field(default_factory=list)
    breakdown: Dict[str, float] = field(default_factory=dict)
    controller_stats: Dict[str, Any] = field(default_factory=dict)
    val_curve: List[float] = field(default_factory=list)
    # per-arrival-stream attribution (multi-stream workloads): stream id ->
    # {time_s, energy_j, flops, rounds, preemptions, avg_inference_acc,
    #  inferences, latency_p50, latency_p95}
    per_stream: Dict[int, Dict[str, float]] = field(default_factory=dict)
    # per-model-slot attribution (ModelPool; single-model runs report one
    # "default" slot): slot -> {time_s, energy_j, flops, rounds, swaps,
    # avg_inference_acc, inferences}
    per_model: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # per-device attribution (DeviceFleet; single-device runs report one
    # "dev0"): device -> {time_s, energy_j, flops, rounds, swaps, syncs,
    # avg_inference_acc, inferences, streams, utilization, evicted}
    per_device: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # QoS: total round splits absorbed by lower-priority streams' rounds
    preemptions: int = 0
    # ModelPool: total cold-slot swap-ins charged to the run
    swaps: int = 0
    # DeviceFleet: per-device cross-device sync charges (federated merges)
    syncs: int = 0
    # detector mode: drift-confirmation probe passes fired
    probes: int = 0

    def summary(self) -> str:
        return (f"acc={self.avg_inference_acc*100:.2f}% "
                f"time={self.total_time_s:.1f}s energy={self.total_energy_j:.1f}J "
                f"rounds={self.rounds} recompiles={self.recompiles} "
                f"tflops={self.compute_tflops:.2f}")


@dataclass
class _SlotState:
    """Per-model-slot runtime state assembled by `run()`: the single-model
    path has exactly one ("default"); a ModelPool run has one per slot."""
    name: str
    model: Any
    bench: ContinualBenchmark
    controller: Any
    steps: TrainStepCache
    executor: FineTuneExecutor
    reference_params: Any = None


class ContinualRuntime:
    def __init__(self, model, benchmark: Optional[ContinualBenchmark],
                 controller,
                 cost_model: Optional[EdgeCostModel] = None,
                 opt_cfg=None, seed: int = 0,
                 boundaries: str = "oracle",       # 'oracle' | 'detector'
                 replay_batches: int = 2,
                 pretrain_epochs: int = 3,
                 inference_batch: int = 16,
                 quant_bits: int = 0,
                 unlabeled_fraction: float = 0.0,
                 calibrate_cost: bool = True,
                 inference_window: float = 0.0,
                 extra_hooks: Optional[List[RoundHook]] = None,
                 stream_benchmarks: Optional[Dict[int, ContinualBenchmark]] = None,
                 controller_factory: Optional[Callable[[Any], Any]] = None,
                 preemptible: bool = False,
                 preempt_resume_cost_s: float = 0.0,
                 model_pool: Optional[ModelPool] = None):
        """Deprecated legacy kwarg constructor. It builds the equivalent
        `RuntimeConfig` (quant_bits/unlabeled_fraction become per-slot
        `HookSpec`s) and delegates to the same resolution path as
        `from_config`, replaying bit-exact — the golden regression pins
        this — while steering callers to the declarative API."""
        warnings.warn(
            "ContinualRuntime legacy kwarg construction is deprecated; "
            "build a RuntimeConfig and use "
            "ContinualRuntime.from_config(cfg, ...) or edgeol_session(cfg) "
            "(DESIGN.md §11)", DeprecationWarning, stacklevel=2)
        hook_specs = []
        if quant_bits:
            hook_specs.append(HookSpec("fake-quant", {"bits": quant_bits}))
        if unlabeled_fraction:
            hook_specs.append(HookSpec("simsiam",
                                       {"fraction": unlabeled_fraction}))
        cfg = RuntimeConfig(
            slots={"default": SlotConfig(hooks=tuple(hook_specs))},
            seed=seed, boundaries=boundaries,
            replay_batches=replay_batches, pretrain_epochs=pretrain_epochs,
            inference_batch=inference_batch, calibrate_cost=calibrate_cost,
            inference_window=inference_window, preemptible=preemptible,
            preempt_resume_cost_s=preempt_resume_cost_s)
        self._init(**resolve_session(
            cfg, model=model, benchmark=benchmark, controller=controller,
            controller_factory=controller_factory,
            stream_benchmarks=stream_benchmarks, model_pool=model_pool,
            cost_model=cost_model, opt_cfg=opt_cfg,
            extra_hooks=extra_hooks))

    @classmethod
    def from_config(cls, cfg: RuntimeConfig, *, model=None, benchmark=None,
                    controller=None, controller_factory=None,
                    stream_benchmarks=None, model_pool=None,
                    cost_model=None, opt_cfg=None, extra_hooks=None,
                    workload_spec=None) -> "ContinualRuntime":
        """The declarative front door (DESIGN.md §11): materialize a
        session from a validated `RuntimeConfig`. Anything the config
        cannot express serializably — a custom benchmark object, a
        pre-built controller/factory/pool, a cost model, live RoundHooks,
        an already-scaled `WorkloadSpec` — is injected as a keyword and
        wins over what the config would build. When the config names a
        workload preset, the per-stream benchmarks and the compiled event
        timeline are materialized too and `run()` replays them by
        default."""
        rt = cls.__new__(cls)
        rt._init(**resolve_session(
            cfg, model=model, benchmark=benchmark, controller=controller,
            controller_factory=controller_factory,
            stream_benchmarks=stream_benchmarks, model_pool=model_pool,
            cost_model=cost_model, opt_cfg=opt_cfg,
            extra_hooks=extra_hooks, workload_spec=workload_spec))
        return rt

    def _init(self, *, model, benchmark, controller, cost_model, opt_cfg,
              seed, boundaries, replay_batches, pretrain_epochs,
              inference_batch, calibrate_cost, inference_window, hooks,
              slot_hooks, stream_benchmarks, controller_factory,
              preemptible, preempt_resume_cost_s, model_pool,
              compiled=False, use_pallas=False, session_events=None,
              devices=(), routing="static", aggregate_every=0.0,
              telemetry=None):
        # ModelPool construction path: the pool's slots carry the models,
        # benchmarks and (optionally) controllers; model/benchmark/
        # controller may be None and default to the first slot's. Slot
        # controllers missing from the pool are built through the
        # `controller_factory` seam, called with the *slot name*.
        self.pool = model_pool
        if model_pool is not None:
            first = next(iter(model_pool.slots.values()))
            model = model if model is not None else first.model
            benchmark = benchmark if benchmark is not None else first.benchmark
        self.model = model
        self.bench = benchmark
        self.controller = controller
        # multi-stream workloads: stream id -> its own benchmark (falls back
        # to `benchmark`, or to the stream's slot benchmark under a pool);
        # streams > 0 get controllers from `controller_factory(stream)` when
        # given, else share `controller` (one policy object observing every
        # stream). Under a pool the same factory seam builds *per-slot*
        # controllers instead, called with the slot name.
        self.stream_benchmarks = dict(stream_benchmarks or {})
        self.controller_factory = controller_factory
        self.cost = cost_model if cost_model is not None else EdgeCostModel()
        self.opt_cfg = opt_cfg or AdamWConfig(lr=1e-3)
        self.seed = seed
        self.boundaries = boundaries
        self.replay_batches = replay_batches
        self.pretrain_epochs = pretrain_epochs
        self.inference_batch = inference_batch
        self.calibrate_cost = calibrate_cost
        self.inference_window = inference_window
        # QoS: when True, fine-tuning rounds run as preemptible
        # reservations — a strictly-higher-priority inference arrival
        # splits the in-flight round (served at its arrival instant
        # instead of waiting for the round's end) and the round resumes,
        # its cost charged in segments that sum to the unpreempted charge.
        # Default False keeps the golden single-stream regression
        # bit-exact (rounds complete synchronously at trigger time).
        self.preemptible = preemptible
        # QoS: modeled checkpoint-resume overhead paid on each round split
        # (charged to the preempting stream; 0.0 = legacy free splits)
        self.preempt_resume_cost_s = preempt_resume_cost_s
        # compiled hot path (DESIGN.md §12): all training goes through the
        # donated fused-scan step, serving through deferred vmapped
        # dispatch, and the event loop through segment slicing. Default
        # False keeps the golden regression on the legacy eager path.
        # `segment` (overridable before run(); the equivalence property
        # test forces it off) additionally fuses whole same-shape runs —
        # per-event compiled execution is the same scan program at trip
        # count 1, so toggling it never moves a bit.
        self.compiled = bool(compiled)
        self.use_pallas = bool(use_pallas)
        self.segment = True
        # round hooks: model-wrapping ones bind first so every later
        # consumer (train steps, serving, SimSiam features) sees the
        # wrapped model. `hooks` wrap the single model; `slot_hooks` bind
        # per pool slot (a quantized CV slot next to an fp32 NLP slot) and
        # wrap that slot's model in _build_slots.
        self.hooks: List[RoundHook] = list(hooks or [])
        self.slot_hooks: Dict[str, List[RoundHook]] = {
            k: list(v) for k, v in (slot_hooks or {}).items()}
        for h in self.hooks:
            self.model = h.bind(self.model)
        # DeviceFleet knobs (DESIGN.md §13): device specs, initial stream
        # routing and the federated aggregation period. Empty `devices`
        # means a fleet of one reference device — the legacy session.
        self.devices = tuple(devices or ())
        self.routing = routing
        self.aggregate_every = float(aggregate_every)
        # optional straggler-mitigation config, picked up by the fleet
        # (None = StragglerConfig defaults)
        self.straggler_config = None
        # observability (DESIGN.md §14): a live `repro.obs.Telemetry`
        # bundle (tracer + metrics + sinks) built by resolve_session when
        # `RuntimeConfig.telemetry` is active; None (the default) keeps
        # every instrumented path on the falsy NULL_TRACER — bit-exact
        # and allocation-free. After a run: ``rt.telemetry.snapshot()``.
        self.telemetry = telemetry
        # the DeviceFleet the last run() drove (live handle for tests)
        self.fleet = None
        # a config-built session may carry its workload's compiled event
        # timeline; run() replays it when no explicit events are passed
        self._session_events: Optional[List[Event]] = session_events
        # single-model step cache lives on the runtime (reused across
        # run() calls); pool slots build their own caches per run
        self.steps = None if model_pool is not None else \
            TrainStepCache(model=self.model, opt_cfg=self.opt_cfg)

    @property
    def session_events(self) -> Optional[List[Event]]:
        """The workload timeline a config-built session will replay when
        `run()` is called without explicit events (None otherwise)."""
        return self._session_events

    # -------------------------------------------------------------------
    def _build_slots(self, ledger: CostLedger, rng: np.random.Generator,
                     device: Optional[DeviceConfig] = None
                     ) -> Dict[str, _SlotState]:
        """Assemble per-slot runtime state for one device (`device=None`
        means the reference "dev0" at identity cost scales — a bitwise
        no-op on every cost figure). The single-model path builds exactly
        one "default" slot wired to the runtime's own model/steps/cost
        and the *shared* rng — preserving the legacy RNG consumption
        order bit-for-bit."""
        spec = device if device is not None else DeviceConfig(DEFAULT_DEVICE)
        tracer = self.telemetry.tracer if self.telemetry is not None \
            else NULL_TRACER
        slots: Dict[str, _SlotState] = {}
        if self.pool is None:
            replay = ReplayBuffer(
                self.bench.scenarios[0].train_batches[:self.replay_batches])
            executor = FineTuneExecutor(
                self.steps,
                scale_cost(self.cost, speed=spec.speed_scale,
                           energy=spec.energy_scale),
                ledger, replay, rng=rng,
                hooks=self.hooks, calibrate_cost=self.calibrate_cost,
                device_name=spec.name, speed_scale=spec.speed_scale,
                preempt_resume_cost_s=self.preempt_resume_cost_s,
                compiled=self.compiled, fuse=self.segment, tracer=tracer)
            slots[DEFAULT_MODEL] = _SlotState(
                DEFAULT_MODEL, self.model, self.bench, self.controller,
                self.steps, executor)
            return slots
        for i, slot in enumerate(self.pool.slots.values()):
            # per-slot RoundHooks (RuntimeConfig SlotConfig.hooks): wrap
            # this slot's model only — its train steps, serving lane and
            # pretraining all see the wrapped model, other slots stay
            # untouched (a quantized CV slot next to an fp32 NLP slot)
            hooks = self.slot_hooks.get(slot.name, [])
            model = slot.model
            for h in hooks:
                model = h.bind(model)
            ctrl = slot.controller
            if ctrl is None and self.controller_factory is not None:
                ctrl = self.controller_factory(slot.name)
            if ctrl is None:
                ctrl = self.controller
            if ctrl is None:
                raise ValueError(
                    f"slot {slot.name!r} has no controller: set "
                    f"ModelSlot.controller or pass controller_factory")
            steps = TrainStepCache(model=model, opt_cfg=self.opt_cfg)
            replay = ReplayBuffer(
                slot.benchmark.scenarios[0].train_batches[:self.replay_batches])
            executor = FineTuneExecutor(
                steps,
                scale_cost(slot.cost, speed=spec.speed_scale,
                           energy=spec.energy_scale),
                ledger, replay,
                rng=np.random.default_rng([self.seed, i]),
                hooks=hooks, calibrate_cost=self.calibrate_cost,
                model_name=slot.name, device_name=spec.name,
                speed_scale=spec.speed_scale,
                preempt_resume_cost_s=self.preempt_resume_cost_s,
                compiled=self.compiled, fuse=self.segment, tracer=tracer)
            slots[slot.name] = _SlotState(slot.name, model,
                                          slot.benchmark, ctrl, steps,
                                          executor)
        return slots

    # -------------------------------------------------------------------
    def run(self, events: Optional[List[Event]] = None,
            inferences_total: Optional[int] = None,
            data_dist: Optional[str] = None,
            inf_dist: Optional[str] = None) -> RunResult:
        """Drive the full continual-learning session. The timeline comes
        from, in precedence order: explicit `events`, the config-built
        session's compiled workload (`session_events`), or a legacy
        timeline generated from `inferences_total`/`data_dist`/`inf_dist`
        (defaults 60/"poisson"/"poisson") — the generation knobs apply
        only to that last case."""
        timeline_kw = {k: v for k, v in (("inferences_total",
                                          inferences_total),
                                         ("data_dist", data_dist),
                                         ("inf_dist", inf_dist))
                       if v is not None}
        if timeline_kw and (events is not None
                            or self._session_events is not None):
            warnings.warn(
                f"run(): {sorted(timeline_kw)} only shape the generated "
                f"legacy timeline and are ignored when events are "
                f"supplied (explicit or from the session's workload "
                f"config)", UserWarning, stacklevel=2)
        bench = self.bench
        if events is None and self._session_events is not None:
            # config-built session: replay the workload's compiled timeline
            events = list(self._session_events)
        if events is None:
            events = build_timeline(
                num_scenarios=bench.num_scenarios - 1,
                batches_per_scenario=len(bench.scenarios[1].train_batches),
                inferences_total=timeline_kw.get("inferences_total", 60),
                seed=self.seed,
                data_dist=timeline_kw.get("data_dist", "poisson"),
                inf_dist=timeline_kw.get("inf_dist", "poisson"))
            # shift scenario ids by 1 (scenario 0 = pretraining)
            events = [dataclasses.replace(e, scenario=e.scenario + 1)
                      for e in events]

        # --- delegate to the fleet (DESIGN.md §13): the default session
        # is a DeviceFleet of one reference device, whose device 0 is
        # built through the exact legacy code path — the golden regression
        # pins single-device runs bit-for-bit. `RuntimeConfig.devices` /
        # `routing` / `aggregate_every` turn the same session into a
        # multi-device one.
        from repro.runtime.fleet import DeviceFleet

        self.fleet = DeviceFleet(self)
        return self.fleet.run(events)


def edgeol_session(cfg: RuntimeConfig, **inject) -> ContinualRuntime:
    """Declarative session front door (DESIGN.md §11): build a ready
    `ContinualRuntime` from a `RuntimeConfig`. Keyword injections are the
    same as `ContinualRuntime.from_config` (live objects win over what
    the config would build). When the config names a workload preset,
    `session.run()` replays its compiled event timeline::

        res = edgeol_session(RuntimeConfig(workload="mixed", ...)).run()
    """
    return ContinualRuntime.from_config(cfg, **inject)
