"""DeviceRuntime — one fleet device's slice of the continual runtime.

PR 7 (DESIGN.md §13) lifts the single-device assumption out of
`ContinualRuntime`: everything that used to live in `run()`'s closures —
the per-slot executors, the serving lane, ModelPool residency, the
event-callback bodies (data / inference / probe / settle / trailing
flush) — now lives on a `DeviceRuntime`, one instance per fleet device.
`ContinualRuntime` itself became "a fleet of size 1": its `run()` resolves
the timeline and hands it to a `DeviceFleet` (runtime/fleet.py), whose
device 0 is built through the exact legacy code path (same RNG objects,
same construction order), so the golden single-device regression and the
compiled-path exact-equality tests replay bit-for-bit.

What is *per device*: slots (params/optimizer/executor/replay), the
`InferenceServer` lane, the ModelPool clone, the occupancy lane on the
shared `EventScheduler`, and the device's numpy RNG. What stays *shared*
(fleet-level): the event timeline, the `CostLedger`, the per-stream
controllers and policy latches (`pending_change` / `scenario_started` /
`last_round_end` / `launch_scenario` — streams may re-route between
devices, their policy state must not), probe counters and the validation
curve. Device 0 of the default fleet shares the run's RNG with its
executor exactly as the legacy runtime did; clone devices draw from
`default_rng([seed, 104729, index])` (and `[..., slot]` under a pool) so
no stream collides with the legacy ones.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.arrivals import Event
from repro.runtime.costmodel import scale_cost
from repro.runtime.executor import FineTuneExecutor, ReplayBuffer
from repro.runtime.inference import InferenceServer
from repro.runtime.modelpool import ModelPool, ModelSlot, tree_mb
from repro.runtime.train_loop import as_jnp, evaluate


class DeviceRuntime:
    """Scheduler + serving + executors + pool for ONE fleet device.

    Method bodies are the former `ContinualRuntime.run()` closures,
    verbatim modulo (a) occupancy/ledger calls carrying this device's
    name and (b) fleet-level state reached through `self.fleet`. The
    fleet settles every device before dispatching an event, so unlike
    the legacy closures the `on_*` handlers do not re-settle."""

    def __init__(self, fleet, spec, index: int, slots: Dict, pool, rng):
        self.fleet = fleet
        self.host = fleet.host
        self.spec = spec
        self.name = spec.name
        self.index = index
        self.scheduler = fleet.scheduler
        self.ledger = fleet.ledger
        self.rng = rng
        self.slots = slots
        self.pool = pool
        self.primary = next(iter(slots.values()))
        # fine-tuning rounds completed since the last cross-device merge
        # (the FedAvg weight) and the interval's round times (the
        # straggler-tracker feed), reset by the fleet at each sync
        self.rounds_since_sync: Dict[str, int] = {n: 0 for n in slots}
        self.round_times: List[float] = []
        # observability (DESIGN.md §14): the fleet's tracer (NULL_TRACER
        # when telemetry is off) records this device's swap/cka/probe
        # spans; the serving lane tags its instants with the device name.
        self.tracer = fleet.tracer
        # physical environment (DESIGN.md §15): assigned by the fleet
        # when this device's DeviceConfig carries an active EnvSpec.
        # None (the default) keeps every env branch untaken — bit-exact.
        self.env = None
        self._dvfs_applied: Dict[str, float] = {}
        host = self.host
        self.server = InferenceServer(self.primary.model,
                                      batch_window=host.inference_window,
                                      on_served=self.served,
                                      fused=host.compiled,
                                      tracer=self.tracer, track=self.name)
        for name, st in slots.items():
            self.server.register(name, st.model)
            self.server.publish(st.executor.params, 0.0, slot=name)

    # ---- lookups ---------------------------------------------------------
    def slot_of(self, st: int):
        return self.slots.get(self.fleet.stream_slot.get(st,
                                                         self.primary.name),
                              self.primary)

    # ---- serving ---------------------------------------------------------
    def served(self, logits, stream=0) -> bool:
        # route the request's logits to its stream's controller; a True
        # return (detected scenario change) is latched per stream — or,
        # in detector mode, schedules a dedicated drift-confirmation
        # probe on the live timeline instead (DESIGN.md: a detection
        # from noisy request logits is confirmed by a forward pass
        # over the stream's probe data before the policy reacts).
        fleet = self.fleet
        hit = fleet.ctrl_for(stream).inference_served(logits)
        if hit:
            if self.host.boundaries == "detector":
                fleet.probes_pushed[0] += 1
                self.scheduler.push(Event(
                    self.scheduler.now, "probe",
                    self.scheduler.scenario_of(stream),
                    fleet.probes_pushed[0] - 1, stream=stream,
                    modality=fleet.stream_slot.get(stream, "cv")))
            else:
                fleet.pending_change[stream] = True
        return hit

    # ---- rounds ----------------------------------------------------------
    def acquire(self, slot, now: float, stream: int) -> None:
        # ModelPool residency: touching a cold slot swaps it in — a
        # real ledger charge (t_swap/e_swap, attributed to the
        # touching stream, the loaded slot and this device) and real
        # occupancy on this device's lane, so whatever triggered the
        # touch waits it out (QoS interaction notes: DESIGN.md §9).
        if self.pool is None:
            return
        t_swap, e_swap, _ = self.pool.ensure_resident(slot.name)
        if t_swap:
            self.ledger.charge_swap(time_s=t_swap, energy_j=e_swap,
                                    model=slot.name, stream=stream,
                                    device=self.name)
            r = self.scheduler.occupy(now, t_swap, stream=stream,
                                      device=self.name)
            if self.tracer:
                self.tracer.span("swap", f"swap/{slot.name}", r.start,
                                 t_swap, stream=stream, device=self.name,
                                 slot=slot.name)

    def complete(self, slot, report) -> None:
        # a round's results reach the rest of the system when it
        # completes: publish to serving, validate, notify the
        # stream's controller, charge SimFreeze's CKA probes
        fleet = self.fleet
        stream = report.stream
        ctrl = fleet.ctrl_for(stream)
        pub = getattr(ctrl, "publish_policy", None)
        if pub is None:
            self.server.publish(slot.executor.params, report.end,
                                slot=slot.name)
        else:
            self.server.publish(slot.executor.params,
                                pub.visible_at(report.end), slot=slot.name,
                                delayed=pub.delayed)
        # validation accuracy (labeled 5% split) -> LazyTune; the
        # split belongs to the scenario current at round *launch*
        val = fleet.bench_for(stream).scenarios[
            fleet.launch_scenario.pop(
                stream, self.scheduler.scenario_of(stream))].val
        val_acc, _ = evaluate(slot.model, slot.executor.params,
                              as_jnp(val))
        fleet.val_curve.append(val_acc)
        cka_before = ctrl.simfreeze.state.cka_flops \
            if hasattr(ctrl, "simfreeze") else 0.0
        ctrl.round_finished(report.iters, val_acc, slot.executor.params)
        if hasattr(ctrl, "simfreeze"):
            dcka = ctrl.simfreeze.state.cka_flops - cka_before
            if dcka:
                tc, ec = slot.executor.cost.compute_cost(dcka)
                self.ledger.charge_probe("cka", tc, ec, stream=stream,
                                         model=slot.name, device=self.name)
                if self.tracer:
                    self.tracer.span("cka", f"cka/{slot.name}", report.end,
                                     tc, stream=stream, device=self.name,
                                     slot=slot.name)
        fleet.last_round_end[stream] = report.end
        self.rounds_since_sync[slot.name] += 1
        self.round_times.append(report.time_s)

    def settle(self, now: float) -> None:
        # preemptible rounds complete lazily: once the timeline passes
        # a reservation's end, finalize it (train the remaining
        # checkpointed batches, charge the exact-remainder segment)
        for st in self.slots.values():
            report = st.executor.finalize_round(now)
            if report is not None:
                self.complete(st, report)

    # ---- env / throttling (DESIGN.md §15) --------------------------------
    def apply_dvfs(self) -> None:
        """Rescale this device's executor cost models to the env's
        current DVFS level. Rescaling is *relative* (new level over the
        level already applied) so the calibrated base survives repeated
        transitions; executors still awaiting their one-shot calibration
        are skipped — calibration would overwrite the scale wholesale —
        and pick the level up after their first round."""
        level = self.env.level
        exp = self.env.spec.dvfs_power_exponent
        for name, st in self.slots.items():
            ex = st.executor
            if ex.calibrate_cost:
                continue
            applied = self._dvfs_applied.get(name, 1.0)
            if level != applied:
                rel = level / applied
                ex.cost = scale_cost(ex.cost, speed=rel, energy=rel ** exp)
                self._dvfs_applied[name] = level

    def allow_round(self, now: float, stream: int) -> bool:
        """ThrottlePolicy consultation — the fifth PolicyStack facet.
        Env-less devices, and controllers without a throttle facet
        (legacy monoliths), always allow: the bit-exact default."""
        if self.env is None:
            return True
        ctrl = self.fleet.ctrl_for(stream)
        pol = getattr(ctrl, "throttle", None)
        if pol is None:
            return True
        slot = self.slot_of(stream)
        t_est, e_est = slot.executor.estimate_round(ctrl.plan, stream)
        if pol.allow_round(self.env.state(), time_s=t_est, energy_j=e_est):
            return True
        if self.tracer:
            self.tracer.instant("throttle", f"defer/{slot.name}", now,
                                stream=stream, device=self.name,
                                slot=slot.name)
        if self.fleet.telemetry is not None:
            self.fleet.telemetry.metrics.counter(
                "throttle_deferrals", device=self.name).inc()
        return False

    def finish_round(self, now: float, stream: int = 0) -> None:
        fleet = self.fleet
        slot = self.slot_of(stream)
        self.acquire(slot, now, stream)
        fleet.launch_scenario[stream] = self.scheduler.scenario_of(stream)
        report = slot.executor.execute_round(
            fleet.ctrl_for(stream).plan, now, self.scheduler, stream=stream,
            priority=fleet.stream_priority.get(stream, 0),
            preemptible=self.host.preemptible)
        if report is None and slot.executor.active_round is None:
            fleet.launch_scenario.pop(stream, None)  # nothing was buffered
        elif report is not None:  # synchronous (non-preemptible) path
            self.complete(slot, report)

    # ---- event handlers (fleet settles every device first) ---------------
    def on_scenario_change(self, previous: int, ev: Event) -> None:
        # keep a replay sample of the just-entered scenario
        sc = self.fleet.bench_for(ev.stream).scenarios[ev.scenario]
        self.slot_of(ev.stream).executor.replay.add(
            sc.train_batches[ev.index % len(sc.train_batches)])

    def on_data(self, ev: Event, boundary: bool) -> None:
        fleet = self.fleet
        st = ev.stream
        ctrl = fleet.ctrl_for(st)
        slot = self.slot_of(st)
        sc = fleet.bench_for(st).scenarios[ev.scenario]
        batch = sc.train_batches[ev.index % len(sc.train_batches)]
        # bound micro-batch deferral: a queued group whose window has
        # elapsed is served now, so controller signals driven by
        # inference_served (LazyTune decay, scenario detection) lag by
        # at most one window.
        self.server.expire(ev.time)
        self.server.drain()  # fused mode: deliver deferred serves now
        change = fleet.pending_change.get(st, False) \
            and self.host.boundaries == "detector"
        if (boundary and self.host.boundaries == "oracle") or change:
            fleet.pending_change[st] = False
            if ctrl.plan is not None and hasattr(ctrl, "scenario_changed"):
                ctrl.scenario_changed(slot.executor.params, as_jnp(batch))
        if getattr(ctrl, "needs_reference", True) and \
                hasattr(ctrl, "start_scenario") and \
                (boundary or (self.scheduler.scenario_of(st)
                              and not fleet.scenario_started.get(st, False))):
            ctrl.start_scenario(slot.reference_params, as_jnp(batch))
            fleet.scenario_started[st] = True
        slot.executor.enqueue(batch, stream=st)
        if ctrl.should_trigger(slot.executor.pending_for(st),
                               staleness=ev.time
                               - fleet.last_round_end.get(st, 0.0),
                               priority=fleet.stream_priority.get(st, 0)) \
                and self.scheduler.idle_at(ev.time, self.name) \
                and self.allow_round(ev.time, st):
            self.finish_round(ev.time, st)

    def on_inference(self, ev: Event) -> None:
        fleet = self.fleet
        st = ev.stream
        b = fleet.bench_for(st)
        slot = self.slot_of(st)
        cur = self.scheduler.scenario_of(st)
        sc = b.scenarios[min(ev.scenario, cur) or ev.scenario]
        test = b.scenarios[max(cur, 1)].test \
            if ev.scenario <= cur else sc.test
        idx = self.rng.choice(len(test["labels"]),
                              min(self.host.inference_batch,
                                  len(test["labels"])),
                              replace=False)
        # QoS serving latency (arrival -> modeled service instant): an
        # idle device serves at once; a busy one makes the request
        # wait out the round's occupancy — unless the arrival outranks
        # a preemptible round, which it splits and is served at its
        # arrival time (the round resumes; with a zero resume cost its
        # end is unchanged). A request for a *cold* ModelPool slot
        # first waits out the slot's swap-in (and never preempts — the
        # swap IO would stall the split anyway).
        swap_needed = self.pool is not None \
            and not self.pool.is_resident(slot.name)
        if self.scheduler.idle_at(ev.time, self.name) and not swap_needed:
            latency = 0.0
        elif not swap_needed and self.scheduler.can_preempt(
                ev.time, ev.priority, self.name):
            active = next(s.executor for s in self.slots.values()
                          if s.executor.active_round is not None)
            active.preempt(ev.time, self.scheduler, preempting_stream=st)
            latency = 0.0
        else:
            self.acquire(slot, ev.time, st)
            latency = self.scheduler.busy_until_of(self.name) - ev.time
        if fleet.telemetry is not None:
            fleet.telemetry.metrics.histogram(
                "latency_s", stream=st).observe(latency)
        self.server.submit(ev.time, {k: v[idx] for k, v in test.items()},
                           stream=st, latency=latency, slot=slot.name)

    def on_probe(self, ev: Event) -> None:
        # detector-driven probe: confirm a flagged drift with a
        # dedicated forward pass over the stream's current validation
        # split before the policy reacts (charged as probe compute,
        # ~1/3 of a measured train step: forward only)
        fleet = self.fleet
        st = ev.stream
        self.server.drain()  # fused mode: serve anything deferred first
        fleet.probes_fired[0] += 1
        slot = self.slot_of(st)
        self.acquire(slot, ev.time, st)
        ctrl = fleet.ctrl_for(st)
        b = fleet.bench_for(st)
        sc = b.scenarios[min(max(self.scheduler.scenario_of(st), ev.scenario,
                                 1), len(b.scenarios) - 1)]
        _, logits = evaluate(slot.model, slot.executor.params,
                             as_jnp(sc.val))
        flops = slot.steps.flops(ctrl.plan,
                                 as_jnp(sc.train_batches[0])) / 3.0
        tc, ec = slot.executor.cost.compute_cost(flops)
        self.ledger.charge_probe("probe", tc, ec, stream=st,
                                 model=slot.name, device=self.name)
        if self.tracer:
            self.tracer.span("probe", f"probe/{slot.name}", ev.time, tc,
                             stream=st, device=self.name, slot=slot.name)
        confirm = getattr(ctrl, "probe_served", None)
        if confirm is None or confirm(logits):
            fleet.pending_change[st] = True

    def trailing_flush(self) -> None:
        # any buffered data still fine-tunes (no data dropped) — unless
        # the device's ThrottlePolicy says it cannot afford the round
        # (a drained battery must not be overdrawn by the flush)
        for slot in self.slots.values():
            for st in slot.executor.pending_streams:
                now = self.scheduler.busy_until_of(self.name)
                if not self.allow_round(now, st):
                    continue
                self.finish_round(now, st)
                self.settle(float("inf"))


# ---------------------------------------------------------------------------
# clone-device construction (devices 1..N-1 of a fleet)


def clone_device_slots(fleet, spec, index: int, slots0: Dict,
                       ledger) -> Dict:
    """Per-device slot states for a clone device: same models, benchmarks,
    hook objects and (crucially) the SAME `TrainStepCache`s as device 0 —
    one compile cache fleet-wide — but its own executor (scaled cost
    model, this device's attribution keys), its own replay buffer, and a
    bitwise copy of device 0's pretrained params/optimizer state (every
    device starts from the same "originally well-trained" model; copies
    keep buffer donation per-device). Under a pool, per-device
    controllers come from the host's `controller_factory` when available
    (fresh policy state per device), else the slot controller is shared."""
    from repro.runtime.continual import _SlotState

    host = fleet.host
    slots: Dict = {}
    device_rng = np.random.default_rng([host.seed, 104729, index])
    for i, (name, src) in enumerate(slots0.items()):
        base = host.cost if host.pool is None else host.pool.slot(name).cost
        cost = scale_cost(base, speed=spec.speed_scale,
                          energy=spec.energy_scale)
        replay = ReplayBuffer(
            src.bench.scenarios[0].train_batches[:host.replay_batches])
        if host.pool is not None:
            ctrl = host.controller_factory(name) \
                if host.controller_factory is not None else src.controller
            ex_rng = np.random.default_rng([host.seed, 104729, index, i])
        else:
            ctrl = src.controller
            ex_rng = device_rng  # shared with the device's inference draws
        executor = FineTuneExecutor(
            src.steps, cost, ledger, replay, rng=ex_rng,
            hooks=src.executor.hooks, calibrate_cost=host.calibrate_cost,
            model_name=name, device_name=spec.name,
            speed_scale=spec.speed_scale,
            preempt_resume_cost_s=host.preempt_resume_cost_s,
            compiled=host.compiled, fuse=host.segment,
            tracer=fleet.tracer)
        executor.load(jax.tree.map(jnp.copy, src.executor.params),
                      jax.tree.map(jnp.copy, src.executor.opt_state))
        slots[name] = _SlotState(name, src.model, src.bench, ctrl,
                                 src.steps, executor,
                                 reference_params=src.reference_params)
    return slots, device_rng


def clone_pool(host, spec, slots):
    """A clone device's ModelPool: same slot bindings, per-device scaled
    swap costs, residency tracked against the device's own memory budget
    (`DeviceConfig.memory_budget_mb`, falling back to the session's)."""
    if host.pool is None:
        return None
    budget = spec.memory_budget_mb or host.pool.memory_budget_mb
    pslots = [ModelSlot(s.name, s.model, s.benchmark,
                        cost=scale_cost(s.cost, speed=spec.speed_scale,
                                        energy=spec.energy_scale),
                        memory_mb=s.memory_mb)
              for s in host.pool.slots.values()]
    pool = ModelPool(pslots, memory_budget_mb=budget)
    for name, st in slots.items():
        pool.set_memory(name, tree_mb(st.executor.params,
                                      st.executor.opt_state))
    pool.warm()
    return pool
