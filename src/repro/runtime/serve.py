"""Batched serving engine: continuous-batching-style loop over a prefill
step and a decode step with a shared KV cache, for the LM examples and the
decode-shape dry-runs."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_steps: int = 0


class ServeEngine:
    def __init__(self, model, max_len: int = 256, cache_dtype=jnp.bfloat16):
        self.model = model
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.stats = ServeStats()
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode)

    def generate(self, params, tokens: np.ndarray, steps: int = 16,
                 greedy: bool = True, rng=None) -> np.ndarray:
        """tokens: [B, S] prompt. Returns [B, steps] generated ids."""
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        cfg = self.model.cfg
        if cfg.frontend != "none":
            batch["frontend_embeds"] = jnp.zeros(
                (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        logits, cache = self._prefill(params, batch)
        self.stats.prefill_tokens += B * S
        out = []
        pos = S + (cfg.frontend_tokens if cfg.frontend != "none" else 0)
        # decode caches sized by prefill; attention caches grow via concat-free
        # dynamic updates, so pre-extend them to max_len once.
        cache = self._extend_cache(cache, self.max_len)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for t in range(steps):
            out.append(np.asarray(cur)[:, 0])
            logits, cache = self._decode(params, cur, cache, jnp.int32(pos + t))
            self.stats.decode_steps += 1
            if greedy or rng is None:
                cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            else:
                cur = jax.random.categorical(rng, logits)[:, None].astype(jnp.int32)
        return np.stack(out, axis=1)

    def _extend_cache(self, cache, max_len: int):
        def ext(leaf):
            # attention k/v leaves: [..., L, Hkv, hd] with L = prefill len
            if leaf.ndim >= 3 and leaf.dtype in (jnp.bfloat16, jnp.float32,
                                                 jnp.float16):
                # heuristic: the seq dim is ndim-3 for [B,L,H,hd] / [G,B,L,H,hd]
                ax = leaf.ndim - 3
                L = leaf.shape[ax]
                if 1 < L < max_len and ax >= 1:
                    pad = [(0, 0)] * leaf.ndim
                    pad[ax] = (0, max_len - L)
                    return jnp.pad(leaf, pad)
            return leaf

        def is_kv(path):
            names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
            return names and names[-1] in ("k", "v")

        return jax.tree_util.tree_map_with_path(
            lambda p, l: ext(l) if is_kv(p) else l, cache)
