"""EventScheduler — the shared timeline of the continual-learning loop.

The paper's central tension (Fig. 1) is that training-data batches and
inference requests arrive on *one* wall-clock, and fine-tuning rounds
occupy it: a request landing mid-round is served by whatever params are
visible, and a round can only launch when the device is idle. This module
owns exactly that: the priority-ordered event queue, the `now`/`busy_until`
device-occupancy semantics, and scenario-boundary bookkeeping. It knows
nothing about models, params or cost models — those live behind the typed
callbacks (`on_data` / `on_inference` / `on_scenario_change`) a composition
root (runtime/continual.py) wires up.

Controllers never see this class directly; they implement the
`ControllerProtocol` documented in core/controller.py and are driven by the
composition root in response to the callbacks emitted here.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional

# Events pop in (time, kind, -priority, insertion-order) order: `"data" <
# "inference"` (KIND_ORDER), then higher `Event.priority` first, matching
# build_timeline's sort and workloads/generators.compile_workload's, so a
# pre-built timeline replays in exactly its constructed order. Priority 0
# everywhere (the legacy case) degenerates to the original
# (time, kind, insertion) order.
from repro.data.arrivals import KIND_ORDER, Event
from repro.obs.log import get_logger
from repro.obs.trace import NULL_TRACER
from repro.runtime.ledger import DEFAULT_DEVICE

log = get_logger("scheduler")

OnData = Callable[[Event, bool], None]          # (event, scenario_boundary)
OnInference = Callable[[Event], None]
OnScenarioChange = Callable[[int, Event], None]  # (previous_scenario, event)
OnProbe = Callable[[Event], None]                # detector-driven probe
OnInferenceSegment = Callable[[list], None]      # maximal run of inferences


@dataclass
class Reservation:
    """One granted slice of device time (`occupy`'s return value).

    Iterable as ``(start, end)`` so legacy ``start, end = occupy(...)``
    call sites keep working. A *preemptible* reservation may be split by
    `EventScheduler.preempt`: its `end` is pulled back to the preemption
    instant and the caller re-occupies the returned remainder, so one
    logical fine-tuning round becomes several reservations (segments)
    whose durations sum to the original grant."""
    start: float
    end: float
    stream: int = 0
    priority: int = 0
    preemptible: bool = False
    device: str = DEFAULT_DEVICE

    @property
    def duration(self) -> float:
        return self.end - self.start

    def __iter__(self):
        return iter((self.start, self.end))


class EventScheduler:
    """Priority-ordered timeline with device-occupancy accounting.

    - `push` accepts events in any order (streams may inject new work
      mid-run, e.g. detector-driven probes); dispatch is always
      time-ordered, stable for ties.
    - `occupy(start, duration)` models the device being busy: the actual
      start is delayed past any in-flight work (`busy_until`), and the
      returned `Reservation` carries the granted interval so callers can
      timestamp visibility. A *preemptible* reservation can be split at a
      strictly-higher-priority arrival (`can_preempt`/`preempt`) — QoS
      preemption, DESIGN.md §8.
    - scenario progress is tracked **per stream** (`scenario_of(stream)`):
      a stream's counter advances when one of its data events carries a new
      scenario id; the boundary is surfaced both via `on_scenario_change`
      and the `scenario_boundary` flag on `on_data`. Streams progress
      independently — stream 1 may still be in scenario 1 while stream 0
      has drifted to scenario 3.
    - `current_scenario` keeps its legacy meaning: the scenario id of the
      most recent data-event boundary, regardless of stream. Single-stream
      timelines (every event on stream 0) see exactly the pre-multi-stream
      behaviour; multi-stream callers should use `scenario_of`.
    """

    def __init__(self, events: Iterable[Event] = ()):
        self._heap: list = []
        self._seq = 0
        self.now = 0.0
        # Occupancy is tracked per fleet device (DESIGN.md §13); the
        # legacy scalar `busy_until` / `reservation` attributes remain as
        # views of the default device, so single-device callers (every
        # seed-era call site) see exactly the original semantics.
        self._busy: Dict[str, float] = {DEFAULT_DEVICE: 0.0}
        self._resv: Dict[str, Optional[Reservation]] = {DEFAULT_DEVICE: None}
        self.current_scenario = 0
        self.stream_scenarios: Dict[int, int] = {}
        self.dispatched = 0
        # observability (DESIGN.md §14): the fleet swaps in a live Tracer
        # when telemetry is enabled; the falsy NULL_TRACER default keeps
        # the dispatch loop allocation-free. `dropped_probes` counts probe
        # events popped with no `on_probe` handler wired (logged, since a
        # silently vanishing probe is a mis-wired composition root).
        self.tracer = NULL_TRACER
        self.trace_dispatch = True
        self.dropped_probes = 0
        for e in events:
            self.push(e)

    # ---- legacy single-device views --------------------------------------
    @property
    def busy_until(self) -> float:
        return self._busy[DEFAULT_DEVICE]

    @busy_until.setter
    def busy_until(self, value: float) -> None:
        self._busy[DEFAULT_DEVICE] = value

    @property
    def reservation(self) -> Optional[Reservation]:
        """In-flight grant on the default device."""
        return self._resv[DEFAULT_DEVICE]

    @reservation.setter
    def reservation(self, value: Optional[Reservation]) -> None:
        self._resv[DEFAULT_DEVICE] = value

    def busy_until_of(self, device: str = DEFAULT_DEVICE) -> float:
        return self._busy.get(device, 0.0)

    def reservation_of(self, device: str = DEFAULT_DEVICE) \
            -> Optional[Reservation]:
        return self._resv.get(device)

    @property
    def devices(self):
        """Device names that have been occupied at least once."""
        return sorted(self._busy)

    # ---- queue -----------------------------------------------------------
    def push(self, event: Event) -> None:
        key = (event.time, KIND_ORDER.get(event.kind, 2),
               -getattr(event, "priority", 0), self._seq)
        heapq.heappush(self._heap, (key, event))
        self._seq += 1

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def streams(self):
        """Stream ids that have dispatched at least one data event."""
        return sorted(self.stream_scenarios)

    def scenario_of(self, stream: int = 0) -> int:
        """Current scenario of one stream (0 until its first data event)."""
        return self.stream_scenarios.get(stream, 0)

    # ---- device occupancy ------------------------------------------------
    def idle_at(self, t: float, device: str = DEFAULT_DEVICE) -> bool:
        """True when `device` can start new work at time `t`."""
        return t >= self._busy.get(device, 0.0)

    def occupy(self, start: float, duration: float, *, stream: int = 0,
               priority: int = 0, preemptible: bool = False,
               device: str = DEFAULT_DEVICE) -> Reservation:
        """Reserve `device` for `duration` seconds, no earlier than
        `start` and never overlapping that device's in-flight work.
        Returns a `Reservation` (unpacks as ``(actual_start, end)`` for
        legacy callers); the device's `busy_until` advances to its end. A
        `preemptible` reservation may later be split by `preempt`.
        Devices occupy independently — the fleet's timelines only couple
        through the shared event queue and ledger."""
        actual = max(start, self._busy.get(device, 0.0))
        self._busy[device] = actual + duration
        self._resv[device] = Reservation(actual, self._busy[device], stream,
                                         priority, preemptible, device)
        return self._resv[device]

    def can_preempt(self, t: float, priority: int,
                    device: str = DEFAULT_DEVICE) -> bool:
        """True when an arrival of `priority` at time `t` may split the
        device's in-flight reservation: the device is busy, the
        reservation opted in, and the arrival outranks the reservation's
        stream."""
        r = self._resv.get(device)
        return (r is not None and r.preemptible and t < r.end
                and t >= r.start and priority > r.priority)

    def preempt(self, t: float, device: str = DEFAULT_DEVICE) -> float:
        """Split the device's in-flight reservation at time `t`: its `end`
        is pulled back to `t` (the completed segment), the device's
        `busy_until` rewinds with it, and the unserved remainder (seconds)
        is returned — the owner re-occupies it (usually immediately,
        yielding only the preemption *point* to the arrival). Callers gate
        on `can_preempt`; splitting a non-preemptible reservation is
        always an error (its cost was charged as one synchronous round)."""
        r = self._resv.get(device)
        if r is None or not r.preemptible or t < r.start or t >= r.end:
            raise ValueError(f"no preemptible reservation to split at t={t}")
        remaining = r.end - t
        r.end = t
        self._busy[device] = t
        self._resv[device] = None
        return remaining

    # ---- dispatch --------------------------------------------------------
    def run(self, *, on_data: OnData, on_inference: OnInference,
            on_scenario_change: Optional[OnScenarioChange] = None,
            on_probe: Optional[OnProbe] = None,
            on_inference_segment: Optional[OnInferenceSegment] = None) -> None:
        """Drain the queue in time order, advancing `now` monotonically and
        emitting one callback per event. "probe" events (detector-driven
        drift confirmation, typically pushed mid-drain) go to `on_probe`
        and are dropped when no handler is wired — they carry no payload a
        generic embedder must not lose.

        With `on_inference_segment` wired (the compiled hot path,
        DESIGN.md §12), each *maximal run of consecutive inference
        events* — the timeline slice between two non-inference events —
        is popped in one go and delivered as a single segment, so the
        handler can fuse the whole run into one device dispatch. Slicing
        never reorders: the segment's events are exactly the events
        `on_inference` would have seen, in the same order, and `now` /
        `dispatched` advance identically."""
        trace = self.tracer if self.trace_dispatch else NULL_TRACER
        while self._heap:
            _, ev = heapq.heappop(self._heap)
            self.now = max(self.now, ev.time)
            self.dispatched += 1
            if trace:
                trace.instant("dispatch", ev.kind, ev.time,
                              stream=ev.stream, scenario=ev.scenario)
            if ev.kind == "data":
                previous = self.stream_scenarios.get(ev.stream, 0)
                boundary = ev.scenario != previous
                if boundary:
                    self.stream_scenarios[ev.stream] = ev.scenario
                    self.current_scenario = ev.scenario
                    if on_scenario_change is not None:
                        on_scenario_change(previous, ev)
                elif ev.stream not in self.stream_scenarios:
                    self.stream_scenarios[ev.stream] = ev.scenario
                on_data(ev, boundary)
            elif ev.kind == "probe":
                if on_probe is not None:
                    on_probe(ev)
                else:
                    # a probe with no handler vanishes by design (it
                    # carries no payload a generic embedder must not
                    # lose) — but never silently: log + count it, so a
                    # mis-wired composition root is diagnosable
                    self.dropped_probes += 1
                    log.warning(
                        "probe event dropped at t=%.3f (stream %s): no "
                        "on_probe handler wired (%d dropped so far)",
                        ev.time, ev.stream, self.dropped_probes)
            elif on_inference_segment is not None:
                segment = [ev]
                while self._heap and self._heap[0][1].kind == "inference":
                    _, nxt = heapq.heappop(self._heap)
                    self.dispatched += 1
                    segment.append(nxt)
                if trace:
                    for nxt in segment[1:]:
                        trace.instant("dispatch", nxt.kind, nxt.time,
                                      stream=nxt.stream,
                                      scenario=nxt.scenario)
                self.now = max(self.now, segment[-1].time)
                on_inference_segment(segment)
            else:
                on_inference(ev)
