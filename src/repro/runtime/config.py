"""RuntimeConfig — the declarative, validated, dict/JSON-round-trippable
session description for `ContinualRuntime` (DESIGN.md §11).

The pre-config runtime had accreted ~18 constructor kwargs across PRs;
every new capability (per-stream policies, QoS, ModelPool, hooks) meant
threading yet another argument through `ContinualRuntime.__init__`. A
`RuntimeConfig` replaces that surface with one serializable object:

- **slots**: one `SlotConfig` per model slot (a single entry is the
  single-model path; several entries run under a `ModelPool`). Each slot
  names its architecture, benchmark, **policy stack**
  (`repro.core.policies.PolicyStackSpec` — trigger / freeze / drift /
  publish) and **hooks** (fake-quant QAT, SimSiam — per slot, so a
  quantized CV slot can sit next to an fp32 NLP slot under a pool).
- **workload**: optionally a `repro.workloads` preset name +
  `workload_scale` knobs; the session then materializes per-stream
  benchmarks and the compiled event timeline itself.
- scalar session knobs: seed, boundaries, QoS (preemptible +
  preempt_resume_cost_s), serving (inference_batch/window), pool memory
  budget, replay/pretrain settings.

`ContinualRuntime.from_config(cfg, ...)` / `edgeol_session(cfg)` are the
front doors; non-serializable live objects (a custom benchmark, a
pre-built controller or pool, a cost model) are *injected* alongside the
config and win over what the config would build. The legacy kwarg
constructor delegates here and emits a `DeprecationWarning`.

`RuntimeConfig.from_dict(cfg.to_dict())` is the identity; unknown keys,
policy names and hook names raise with the valid alternatives listed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.policies import PolicyStackSpec
from repro.env.spec import EnvSpec
from repro.obs.spec import TelemetrySpec
from repro.runtime.executor import FakeQuantHook, RoundHook, SimSiamHook

#: workload_scale keys forwarded to `repro.workloads.presets` (plus
#: `batch_size`, consumed by per-stream benchmark materialization).
WORKLOAD_SCALE_KEYS = ("batches_per_scenario", "inferences",
                       "num_scenarios", "scenario_span", "batch_size",
                       "fleet_streams")

BOUNDARY_MODES = ("oracle", "detector")


@dataclass(frozen=True)
class HookSpec:
    """One named `RoundHook`: ``{"name": "fake-quant", "bits": 8}`` or
    ``{"name": "simsiam", "fraction": 0.5}``."""
    name: str
    params: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, **self.params}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HookSpec":
        if not isinstance(d, dict) or "name" not in d:
            raise ValueError(f"a hook spec must be a dict with a 'name' "
                             f"key (got {d!r})")
        d = dict(d)
        return cls(name=d.pop("name"), params=d)


_HOOK_PARAMS = {"fake-quant": ("bits",), "simsiam": ("fraction",)}


def _check_hook_spec(spec: HookSpec) -> None:
    """Validate name/params without instantiating."""
    if spec.name not in _HOOK_PARAMS:
        raise ValueError(f"unknown hook {spec.name!r}; known hooks: "
                         f"{sorted(_HOOK_PARAMS)}")
    required = _HOOK_PARAMS[spec.name]
    if set(spec.params) != set(required):
        raise ValueError(f"hook {spec.name!r}: expected exactly "
                         f"parameter(s) {list(required)} "
                         f"(got {sorted(spec.params)})")


def build_hook(spec: HookSpec) -> RoundHook:
    _check_hook_spec(spec)
    if spec.name == "fake-quant":
        return FakeQuantHook(int(spec.params["bits"]))
    return SimSiamHook(float(spec.params["fraction"]))


@dataclass(frozen=True)
class SlotConfig:
    """One model slot: architecture + benchmark binding + policy stack +
    per-slot hooks. `benchmark_kw` feeds the benchmark maker when the
    session (not a workload preset) materializes it; `memory_mb` pins the
    slot's footprint under a pool budget (None = measure live)."""
    arch: str = "mobilenetv2"
    benchmark: str = "nc"
    benchmark_kw: Dict[str, Any] = field(default_factory=dict)
    policies: PolicyStackSpec = field(default_factory=PolicyStackSpec)
    hooks: Tuple[HookSpec, ...] = ()
    memory_mb: Optional[float] = None

    def validate(self, context: str) -> "SlotConfig":
        if not self.arch or not isinstance(self.arch, str):
            raise ValueError(f"{context}: arch must be a non-empty string")
        try:
            self.policies.validate()
            for h in self.hooks:
                _check_hook_spec(h)
        except ValueError as e:
            raise ValueError(f"{context}: {e}") from None
        return self

    def build_hooks(self) -> List[RoundHook]:
        return [build_hook(h) for h in self.hooks]

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"arch": self.arch, "benchmark": self.benchmark}
        if self.benchmark_kw:
            out["benchmark_kw"] = dict(self.benchmark_kw)
        out["policies"] = self.policies.to_dict()
        if self.hooks:
            out["hooks"] = [h.to_dict() for h in self.hooks]
        if self.memory_mb is not None:
            out["memory_mb"] = self.memory_mb
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SlotConfig":
        if not isinstance(d, dict):
            raise ValueError(f"a slot config must be a dict (got {d!r})")
        valid = {"arch", "benchmark", "benchmark_kw", "policies", "hooks",
                 "memory_mb"}
        unknown = set(d) - valid
        if unknown:
            raise ValueError(f"slot config: unknown key(s) "
                             f"{sorted(unknown)}; valid: {sorted(valid)}")
        kw = dict(d)
        if "policies" in kw:
            kw["policies"] = PolicyStackSpec.from_dict(kw["policies"])
        if "hooks" in kw:
            kw["hooks"] = tuple(HookSpec.from_dict(h) for h in kw["hooks"])
        return cls(**kw)


@dataclass(frozen=True)
class DeviceConfig:
    """One fleet device (DESIGN.md §13): a name plus its hardware envelope
    relative to the reference `EdgeCostModel` device. `speed_scale`
    multiplies throughput (2.0 = rounds finish in half the time),
    `energy_scale` multiplies both power draws (0.5 = half the joules per
    second), and `memory_budget_mb` caps the device's ModelPool residency
    (0.0 = unbounded, like the single-device default). `env` optionally
    attaches a physical environment (`repro.env.EnvSpec`, DESIGN.md §15:
    battery budget, thermal RC node, DVFS governor); the default None —
    and an inactive spec — is today's unconstrained behavior, bit-exact."""
    name: str
    speed_scale: float = 1.0
    energy_scale: float = 1.0
    memory_budget_mb: float = 0.0
    env: Optional[EnvSpec] = None

    def validate(self, context: str = "device") -> "DeviceConfig":
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"{context}: name must be a non-empty string")
        for fname in ("speed_scale", "energy_scale"):
            if getattr(self, fname) <= 0:
                raise ValueError(f"{context} {self.name!r}: {fname} must "
                                 f"be > 0")
        if self.memory_budget_mb < 0:
            raise ValueError(f"{context} {self.name!r}: memory_budget_mb "
                             f"must be >= 0")
        if self.env is not None:
            if not isinstance(self.env, EnvSpec):
                raise ValueError(f"{context} {self.name!r}: env must be an "
                                 f"EnvSpec or None (got "
                                 f"{type(self.env).__name__})")
            self.env.validate(f"{context} {self.name!r} env")
        return self

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"name": self.name}
        if self.speed_scale != 1.0:
            out["speed_scale"] = self.speed_scale
        if self.energy_scale != 1.0:
            out["energy_scale"] = self.energy_scale
        if self.memory_budget_mb:
            out["memory_budget_mb"] = self.memory_budget_mb
        if self.env is not None:
            out["env"] = self.env.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DeviceConfig":
        if not isinstance(d, dict) or "name" not in d:
            raise ValueError(f"a device config must be a dict with a "
                             f"'name' key (got {d!r})")
        valid = {"name", "speed_scale", "energy_scale", "memory_budget_mb",
                 "env"}
        unknown = set(d) - valid
        if unknown:
            raise ValueError(f"device config: unknown key(s) "
                             f"{sorted(unknown)}; valid: {sorted(valid)}")
        kw = dict(d)
        if "env" in kw:
            kw["env"] = EnvSpec.from_dict(kw["env"])
        return cls(**kw)


def _default_slots() -> Dict[str, SlotConfig]:
    return {"default": SlotConfig()}


@dataclass(frozen=True)
class RuntimeConfig:
    """Full declarative session description (module docstring)."""
    slots: Dict[str, SlotConfig] = field(default_factory=_default_slots)
    workload: Optional[str] = None
    workload_scale: Dict[str, Any] = field(default_factory=dict)
    seed: int = 0
    boundaries: str = "oracle"
    replay_batches: int = 2
    pretrain_epochs: int = 3
    inference_batch: int = 16
    calibrate_cost: bool = True
    inference_window: float = 0.0
    preemptible: bool = False
    preempt_resume_cost_s: float = 0.0
    memory_budget_mb: float = 0.0
    # compiled hot path (DESIGN.md §12): fused scan training, deferred
    # vmapped serving, segment-sliced event loop. Off by default — the
    # golden regression pins the eager path bit-for-bit.
    compiled: bool = False
    # route attention forwards and the SimFreeze CKA probe through the
    # Pallas kernels (interpret mode on CPU, so CI runs them)
    use_pallas: bool = False
    # fleet (DESIGN.md §13): the devices streams route across (empty =
    # one implicit default device, the legacy single-device session),
    # the stream->device routing policy, and the cross-device delta-merge
    # period in timeline seconds (0.0 = never aggregate)
    devices: Tuple[DeviceConfig, ...] = ()
    routing: str = "static"
    aggregate_every: float = 0.0
    # observability (DESIGN.md §14): the default spec is inactive — no
    # tracer, no metrics, no sinks; the run is bit-exact with the
    # pre-telemetry runtime. Any of enabled/trace_jsonl/chrome_trace
    # builds a live `repro.obs.Telemetry` for the session.
    telemetry: TelemetrySpec = field(default_factory=TelemetrySpec)

    # ---- validation ------------------------------------------------------
    def validate(self) -> "RuntimeConfig":
        if not self.slots or not isinstance(self.slots, dict):
            raise ValueError("RuntimeConfig.slots must be a non-empty "
                             "dict of slot-name -> SlotConfig")
        for name, sc in self.slots.items():
            if not isinstance(name, str) or not name:
                raise ValueError(f"slot names must be non-empty strings "
                                 f"(got {name!r})")
            if not isinstance(sc, SlotConfig):
                raise ValueError(f"slot {name!r} must be a SlotConfig "
                                 f"(got {type(sc).__name__})")
            sc.validate(f"slot {name!r}")
        if self.boundaries not in BOUNDARY_MODES:
            raise ValueError(f"boundaries must be one of {BOUNDARY_MODES} "
                             f"(got {self.boundaries!r})")
        unknown = set(self.workload_scale) - set(WORKLOAD_SCALE_KEYS)
        if unknown:
            raise ValueError(f"workload_scale: unknown key(s) "
                             f"{sorted(unknown)}; valid: "
                             f"{list(WORKLOAD_SCALE_KEYS)}")
        if self.workload_scale and self.workload is None:
            raise ValueError("workload_scale given without a workload name")
        for fname in ("replay_batches", "pretrain_epochs"):
            if getattr(self, fname) < 0:
                raise ValueError(f"{fname} must be >= 0")
        if self.inference_batch < 1:
            raise ValueError("inference_batch must be >= 1")
        for fname in ("inference_window", "preempt_resume_cost_s",
                      "memory_budget_mb", "aggregate_every"):
            if getattr(self, fname) < 0:
                raise ValueError(f"{fname} must be >= 0")
        names = [dc.name for dc in self.devices]
        if len(set(names)) != len(names):
            raise ValueError(f"device names must be unique (got {names})")
        for dc in self.devices:
            if not isinstance(dc, DeviceConfig):
                raise ValueError(f"devices entries must be DeviceConfig "
                                 f"(got {type(dc).__name__})")
            dc.validate()
        from repro.runtime.fleet import ROUTING_POLICIES

        if self.routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {self.routing!r}; "
                             f"known: {sorted(ROUTING_POLICIES)}")
        if not isinstance(self.telemetry, TelemetrySpec):
            raise ValueError(f"telemetry must be a TelemetrySpec (got "
                             f"{type(self.telemetry).__name__})")
        self.telemetry.validate()
        return self

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "slots": {n: s.to_dict() for n, s in self.slots.items()},
            "seed": self.seed, "boundaries": self.boundaries,
            "replay_batches": self.replay_batches,
            "pretrain_epochs": self.pretrain_epochs,
            "inference_batch": self.inference_batch,
            "calibrate_cost": self.calibrate_cost,
            "inference_window": self.inference_window,
            "preemptible": self.preemptible,
            "preempt_resume_cost_s": self.preempt_resume_cost_s,
            "memory_budget_mb": self.memory_budget_mb,
            "compiled": self.compiled,
            "use_pallas": self.use_pallas,
        }
        if self.workload is not None:
            out["workload"] = self.workload
            if self.workload_scale:
                out["workload_scale"] = dict(self.workload_scale)
        if self.devices:
            out["devices"] = [dc.to_dict() for dc in self.devices]
        if self.routing != "static":
            out["routing"] = self.routing
        if self.aggregate_every:
            out["aggregate_every"] = self.aggregate_every
        if self.telemetry != TelemetrySpec():
            out["telemetry"] = self.telemetry.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RuntimeConfig":
        if not isinstance(d, dict):
            raise ValueError(f"a runtime config must be a dict (got {d!r})")
        valid = {"slots", "workload", "workload_scale", "seed", "boundaries",
                 "replay_batches", "pretrain_epochs", "inference_batch",
                 "calibrate_cost", "inference_window", "preemptible",
                 "preempt_resume_cost_s", "memory_budget_mb", "compiled",
                 "use_pallas", "devices", "routing", "aggregate_every",
                 "telemetry"}
        unknown = set(d) - valid
        if unknown:
            raise ValueError(f"runtime config: unknown key(s) "
                             f"{sorted(unknown)}; valid: {sorted(valid)}")
        kw = dict(d)
        if "slots" in kw:
            if not isinstance(kw["slots"], dict):
                raise ValueError("runtime config: 'slots' must be a dict")
            kw["slots"] = {n: SlotConfig.from_dict(s)
                           for n, s in kw["slots"].items()}
        if "devices" in kw:
            kw["devices"] = tuple(DeviceConfig.from_dict(dc)
                                  for dc in kw["devices"])
        if "telemetry" in kw:
            kw["telemetry"] = TelemetrySpec.from_dict(kw["telemetry"])
        return cls(**kw).validate()


# ---------------------------------------------------------------------------
# session materialization


def _build_telemetry(spec: TelemetrySpec):
    """An active spec becomes a live `repro.obs.Telemetry`; the default
    inactive spec stays None — the zero-overhead legacy path."""
    if not spec.active:
        return None
    from repro.obs.telemetry import Telemetry

    return Telemetry(spec)


def materialize_stream_benchmarks(spec, seed: int,
                                  batch_size: int = 8) -> Dict[int, Any]:
    """One continual benchmark per stream of a `WorkloadSpec` (scenario 0
    is reserved for pretraining, so each gets num_scenarios + 1)."""
    from repro.data import streams

    benches: Dict[int, Any] = {}
    for i, ss in enumerate(spec.streams):
        maker = streams.REGISTRY[ss.benchmark]
        kw = dict(batches=max(ss.batches_per_scenario, 2),
                  batch_size=batch_size, seed=seed + 13 * i)
        if ss.benchmark != "s-cifar":
            kw["num_scenarios"] = spec.num_scenarios + 1
        benches[i] = maker(**kw)
    return benches


def _build_benchmark(slot_cfg: SlotConfig, seed: int):
    from repro.data import streams

    name = slot_cfg.benchmark
    if name not in streams.REGISTRY:
        raise ValueError(f"unknown benchmark {name!r}; known: "
                         f"{sorted(streams.REGISTRY)}")
    kw = dict(slot_cfg.benchmark_kw)
    kw.setdefault("seed", seed)
    return streams.REGISTRY[name](**kw)


def _build_model(arch: str, *, use_pallas: bool = False,
                 compiled: bool = False):
    from repro.configs import get_reduced
    from repro.models import build_model

    mcfg = get_reduced(arch)
    if use_pallas:
        mcfg = mcfg.replace(use_pallas=True)
    model = build_model(mcfg)
    if compiled:
        from repro.runtime.train_loop import compiled_model

        model = compiled_model(model)
    return model


def _slot_policies(cfg: RuntimeConfig, sc: SlotConfig) -> PolicyStackSpec:
    """The slot's policy stack, with the SimFreeze drift probe routed
    through the Pallas CKA kernel when the session asks for it (an
    explicit `use_kernel` in the spec always wins)."""
    import dataclasses

    if not cfg.use_pallas or sc.policies.freeze.name != "simfreeze" \
            or "use_kernel" in sc.policies.freeze.params:
        return sc.policies
    freeze = dataclasses.replace(
        sc.policies.freeze,
        params={**sc.policies.freeze.params, "use_kernel": True})
    return dataclasses.replace(sc.policies, freeze=freeze)


def _pool_from_config(cfg: RuntimeConfig, spec, benches):
    """One `ModelSlot` per workload modality, arch/memory from the
    matching `SlotConfig`; each slot pretrains/validates on the benchmark
    of its first bound stream (same binding `benchmarks.build_pool`
    uses)."""
    from repro.runtime.modelpool import ModelPool, ModelSlot

    slots = []
    for m in spec.modalities:
        sc = cfg.slots[m]
        first = next(i for i, s in enumerate(spec.streams)
                     if s.modality == m)
        slots.append(ModelSlot(
            m, _build_model(sc.arch, use_pallas=cfg.use_pallas,
                            compiled=cfg.compiled),
            benches[first], memory_mb=sc.memory_mb))
    return ModelPool(slots, memory_budget_mb=cfg.memory_budget_mb)


def resolve_session(cfg: RuntimeConfig, *, model=None, benchmark=None,
                    controller=None, controller_factory=None,
                    stream_benchmarks=None, model_pool=None,
                    cost_model=None, opt_cfg=None, extra_hooks=None,
                    workload_spec=None) -> Dict[str, Any]:
    """Turn a `RuntimeConfig` (+ optional injected live objects, which
    win over what the config would build) into the keyword set
    `ContinualRuntime._init` wires. Returns a plain dict so the
    constructor paths — `from_config` and the deprecated legacy kwarg
    `__init__` — share one resolution."""
    cfg.validate()
    session_events = None
    spec = workload_spec

    if spec is None and cfg.workload is not None:
        from repro.workloads import presets

        scale = dict(cfg.workload_scale)
        batch_size = scale.pop("batch_size", 8)
        known = presets(seed=cfg.seed, **scale)
        if cfg.workload not in known:
            raise ValueError(f"unknown workload preset {cfg.workload!r}; "
                             f"known presets: {sorted(known)}")
        spec = known[cfg.workload]
    else:
        batch_size = dict(cfg.workload_scale).get("batch_size", 8)

    slot_hooks: Dict[str, List[RoundHook]] = {}
    config_built_pool = False

    if spec is not None:
        from repro.workloads.generators import compile_workload

        missing = [m for m in spec.modalities if m not in cfg.slots]
        if missing:
            raise ValueError(
                f"workload {spec.name!r} needs a SlotConfig per modality; "
                f"missing {missing} (have {sorted(cfg.slots)})")
        if stream_benchmarks is None:
            stream_benchmarks = materialize_stream_benchmarks(
                spec, cfg.seed, batch_size)
        session_events = compile_workload(spec)
        if len(spec.modalities) > 1 and model_pool is None:
            model_pool = _pool_from_config(cfg, spec, stream_benchmarks)
            config_built_pool = True

    hooks: List[RoundHook] = []
    if model_pool is not None:
        # per-slot hooks (the RoundHooks-under-a-pool ROADMAP item): each
        # pool slot binds the hooks its SlotConfig names; hooks on a slot
        # the pool does not have — including the legacy global
        # quant/simsiam kwargs, which land on "default" — are rejected,
        # as is the extra_hooks injection (ambiguous binding).
        if extra_hooks:
            raise ValueError("extra_hooks wrap one model; with model_pool "
                             "bind hooks per slot via SlotConfig.hooks")
        for name, sc in cfg.slots.items():
            if not sc.hooks:
                continue
            if name not in model_pool.slots:
                raise ValueError(
                    f"hooks configured for slot {name!r}, but the pool "
                    f"has {sorted(model_pool.slots)}; RoundHooks bind "
                    f"per slot under a ModelPool")
            slot_hooks[name] = sc.build_hooks()
        # synthesize per-slot controllers from the slot policies ONLY for
        # a pool this resolution built from the config — an injected pool
        # keeps the explicit "slot has no controller" contract (its slot
        # names matching the default 'default' SlotConfig must not
        # silently pick up a full policy stack the caller never asked
        # for)
        if controller_factory is None and config_built_pool:
            pool = model_pool
            stacks = {n: _slot_policies(cfg, sc)
                      for n, sc in cfg.slots.items()}

            def controller_factory(key, _pool=pool, _stacks=stacks):
                return _stacks[key].build(_pool.slot(key).model)
    else:
        single = cfg.slots[next(iter(cfg.slots))] if len(cfg.slots) == 1 \
            else None
        if single is None:
            raise ValueError(
                "multiple slots need a multi-modality workload or an "
                "injected model_pool (got "
                f"{sorted(cfg.slots)} and neither)")
        if model is None:
            model = _build_model(single.arch, use_pallas=cfg.use_pallas,
                                 compiled=cfg.compiled)
        elif cfg.compiled:
            # injected model: still jit its serving/probe forwards (the
            # controller below is built on the wrapped model, so
            # SimFreeze's feature probes dispatch through jit too)
            from repro.runtime.train_loop import compiled_model

            model = compiled_model(model)
        if benchmark is None:
            if stream_benchmarks is not None and 0 in stream_benchmarks:
                benchmark = stream_benchmarks[0]
            else:
                benchmark = _build_benchmark(single, cfg.seed)
        if controller is None:
            controller = _slot_policies(cfg, single).build(model)
        if controller_factory is None and spec is not None:
            mdl = model
            policies = _slot_policies(cfg, single)

            def controller_factory(key, _m=mdl, _p=policies):
                return _p.build(_m)
        hooks = single.build_hooks()
        hooks.extend(extra_hooks or [])

    from repro.runtime.costmodel import EdgeCostModel

    return dict(
        model=model, benchmark=benchmark, controller=controller,
        cost_model=cost_model if cost_model is not None else EdgeCostModel(),
        opt_cfg=opt_cfg, seed=cfg.seed, boundaries=cfg.boundaries,
        replay_batches=cfg.replay_batches,
        pretrain_epochs=cfg.pretrain_epochs,
        inference_batch=cfg.inference_batch,
        calibrate_cost=cfg.calibrate_cost,
        inference_window=cfg.inference_window,
        hooks=hooks, slot_hooks=slot_hooks,
        stream_benchmarks=stream_benchmarks,
        controller_factory=controller_factory,
        preemptible=cfg.preemptible,
        preempt_resume_cost_s=cfg.preempt_resume_cost_s,
        model_pool=model_pool, compiled=cfg.compiled,
        use_pallas=cfg.use_pallas, session_events=session_events,
        devices=cfg.devices, routing=cfg.routing,
        aggregate_every=cfg.aggregate_every,
        telemetry=_build_telemetry(cfg.telemetry))
