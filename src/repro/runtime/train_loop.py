"""Train-step factory: jitted, freeze-plan-aware, with a compiled-variant
cache (the "system initialization" LazyTune amortizes) and XLA-measured
FLOPs per plan for the cost model."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         sgdm_init, sgdm_update)


@dataclass
class TrainStepCache:
    """Per-freeze-plan compiled train steps + their HLO FLOPs."""
    model: Any
    opt_cfg: Any
    _steps: Dict[Any, Callable] = field(default_factory=dict)
    _flops: Dict[Any, float] = field(default_factory=dict)
    recompiles: int = 0

    def _make_step(self, plan):
        opt_cfg = self.opt_cfg
        loss_fn = self.model.loss

        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, plan), has_aux=True)(params)
            if isinstance(opt_cfg, AdamWConfig):
                params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
            else:
                params, opt_state = sgdm_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, metrics

        return jax.jit(step)

    def get(self, plan) -> Callable:
        if plan not in self._steps:
            self._steps[plan] = self._make_step(plan)
            self.recompiles += 1
        return self._steps[plan]

    def flops(self, plan, example_batch) -> float:
        """XLA-measured FLOPs of one train step under `plan` (compiled once,
        cached). Used by EdgeCostModel so SimFreeze savings are *measured*,
        not assumed."""
        if plan not in self._flops:
            step = self.get(plan)
            params = self.model.init(jax.random.PRNGKey(0))
            opt_state = (adamw_init(params, self.opt_cfg)
                         if isinstance(self.opt_cfg, AdamWConfig)
                         else sgdm_init(params, self.opt_cfg))
            from repro.roofline.analysis import cost_analysis_dict

            lowered = step.lower(params, opt_state, example_batch)
            cost = cost_analysis_dict(lowered.compile())
            self._flops[plan] = float(cost.get("flops", 0.0))
        return self._flops[plan]


def as_jnp(batch: dict) -> dict:
    """Host batch dict -> device arrays (shared by training and serving)."""
    return {k: jnp.asarray(v) for k, v in batch.items()}


def make_optimizer_state(model, opt_cfg, params):
    if isinstance(opt_cfg, AdamWConfig):
        return adamw_init(params, opt_cfg)
    return sgdm_init(params, opt_cfg)


def evaluate(model, params, batch) -> Tuple[float, Any]:
    """Returns (accuracy, logits) on a labeled batch."""
    logits = model.predict(params, batch) if model.predict is not None else None
    if logits is None:
        raise ValueError("model has no predict()")
    import numpy as np

    acc = float(jnp.mean((jnp.argmax(logits, -1) ==
                          jnp.asarray(batch["labels"])).astype(jnp.float32)))
    return acc, np.asarray(logits)


def grad_accum_step(loss_fn, params, batches, plan=None):
    """Gradient accumulation over microbatches via scan (large global
    batches on small meshes)."""
    def micro(carry, batch):
        gsum, lsum = carry
        (l, _), g = jax.value_and_grad(lambda p: loss_fn(p, batch, plan),
                                       has_aux=True)(params)
        return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), batches)
    n = jax.tree.leaves(batches)[0].shape[0]
    return (jax.tree.map(lambda g: g / n, gsum), lsum / n)
