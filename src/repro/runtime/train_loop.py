"""Train-step factory: jitted, freeze-plan-aware, with a compiled-variant
cache (the "system initialization" LazyTune amortizes) and XLA-measured
FLOPs per plan for the cost model.

Compiled hot path (DESIGN.md §12): steps donate their `(params,
opt_state)` buffers, the compile ledger is keyed by *(plan, batch
shape)* so alternating streams/slots can't thrash it, and
`fused_call` runs a whole run of same-shape batches as one
`lax.scan` dispatch. Every compiled-mode update — even a single batch —
goes through the same scan body: a scan's while-loop HLO is
trip-count-independent, so k fused micro-steps are bit-identical to k
single-step calls of the same program, which is what makes segment
batching a pure dispatch optimization. Scan lengths are padded up to
power-of-two buckets with a per-step validity mask (`jnp.where(valid,
new, old)` keeps the carry — including the Adam step count — bitwise
unchanged on padding steps), bounding compiles to log2(max round length)
per (plan, shape).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         sgdm_init, sgdm_update)

# CPU has no buffer-donation support: jit warns once per donated program
# and silently keeps the copy. The donation is still correct (and load-
# bearing on GPU/TPU), so the warning is noise here.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


# Process-global program registries. jit caches live on the jit-wrapped
# callable, so a fresh `jax.jit` per session would re-pay every XLA
# compile; keying the wrapped callables by (loss-fn identity, opt config,
# plan, ...) instead lets every session over the same (memoized) model
# share programs. Keys hold the loss function itself (not id()) so a
# live registry entry can never collide with a recycled id.
_STEPS: Dict[Tuple, Callable] = {}
_MULTI: Dict[Tuple, Callable] = {}
_MULTI_BUCKETS: Dict[Tuple, set] = {}
_FLOPS: Dict[Tuple, float] = {}


def batch_signature(batch: dict) -> Tuple:
    """Hashable (shape, dtype) signature of a host/device batch dict —
    the retrace key of every compiled step."""
    return tuple(sorted(
        (k, tuple(getattr(v, "shape", ())), str(getattr(v, "dtype", "")))
        for k, v in batch.items()))


def _bucket(n: int) -> int:
    """Next power of two >= n (scan-length / group-size padding bucket)."""
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class TrainStepCache:
    """Per-freeze-plan compiled train steps + their HLO FLOPs.

    `recompiles` counts distinct (plan, batch-shape) programs: one per
    new plan, plus one per *additional* batch shape a plan is asked to
    handle (the first shape rides on the plan's own compile). `donate`
    marks params/opt_state as donated in every jitted step (a no-op on
    CPU, halves peak optimizer-state memory on accelerators).
    """
    model: Any
    opt_cfg: Any
    donate: bool = True
    _jits: Dict[Any, Callable] = field(default_factory=dict)
    _shapes: Dict[Any, set] = field(default_factory=dict)
    _flops: Dict[Any, float] = field(default_factory=dict)
    recompiles: int = 0

    def _raw_step(self, plan):
        opt_cfg = self.opt_cfg
        loss_fn = self.model.loss

        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, plan), has_aux=True)(params)
            if isinstance(opt_cfg, AdamWConfig):
                params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
            else:
                params, opt_state = sgdm_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, metrics

        return step

    def _make_step(self, plan):
        key = ("step", self.model.loss, self.opt_cfg, plan, self.donate)
        fn = _STEPS.get(key)
        if fn is None:
            fn = _STEPS[key] = jax.jit(
                self._raw_step(plan),
                donate_argnums=(0, 1) if self.donate else ())
        return fn

    def get(self, plan, example_batch: dict = None) -> Callable:
        """The jitted single step for `plan`. Passing the batch about to
        be trained keeps the recompile ledger shape-accurate (jax retraces
        per shape under the hood; we only *count* here)."""
        if plan not in self._jits:
            self._jits[plan] = self._make_step(plan)
            self._shapes[plan] = set()
            self.recompiles += 1
        if example_batch is not None:
            sig = batch_signature(example_batch)
            shapes = self._shapes[plan]
            if sig not in shapes:
                if shapes:  # first shape rides on the plan's compile
                    self.recompiles += 1
                shapes.add(sig)
        return self._jits[plan]

    # ---- fused multi-batch step (compiled hot path) ----------------------
    def multi_step(self, plan, example_batch: dict,
                   length: int) -> Tuple[Callable, int]:
        """Jitted masked scan over a stacked run of `length` same-shape
        batches; returns (fn, bucket) where fn(params, opt_state,
        stacked, valid) expects `bucket` stacked batches and a [bucket]
        bool mask. Padding steps leave the carry bitwise untouched —
        which also lets a short run ride an already-compiled *larger*
        bucket instead of compiling its own rung. Reuse is capped at 2x
        the run's natural bucket so padding never more than doubles the
        scan's device work (a singleton round must not ride an 8-step
        program just because pretraining compiled one)."""
        base = (self.model.loss, self.opt_cfg, plan, self.donate,
                batch_signature(example_batch))
        need = _bucket(length)
        compiled = _MULTI_BUCKETS.setdefault(base, set())
        fits = [b for b in compiled if need <= b <= 2 * need]
        bucket = min(fits) if fits else need
        compiled.add(bucket)
        key = base + (bucket,)
        fn = _MULTI.get(key)
        if fn is None:
            raw = self._raw_step(plan)

            def body(carry, xs):
                params, opt_state = carry
                batch, valid = xs
                p2, o2, metrics = raw(params, opt_state, batch)
                keep = lambda new, old: jnp.where(valid, new, old)
                return (jax.tree.map(keep, p2, params),
                        jax.tree.map(keep, o2, opt_state)), metrics

            def multi(params, opt_state, stacked, valid):
                (params, opt_state), metrics = jax.lax.scan(
                    body, (params, opt_state), (stacked, valid))
                return params, opt_state, metrics

            fn = _MULTI[key] = jax.jit(
                multi, donate_argnums=(0, 1) if self.donate else ())
        return fn, bucket

    def fused_call(self, plan, params, opt_state, batches: Sequence[dict]):
        """Run a same-shape run of batches as ONE device dispatch. The
        single-batch case is the same scan program at trip count 1, so
        per-event and segment-batched execution agree bitwise."""
        self.get(plan, batches[0])  # recompile-ledger bookkeeping
        fn, bucket = self.multi_step(plan, batches[0], len(batches))
        pad = bucket - len(batches)
        stacked = {k: jnp.stack([jnp.asarray(b[k]) for b in batches]
                                + [jnp.asarray(batches[0][k])] * pad)
                   for k in batches[0]}
        valid = jnp.arange(bucket) < len(batches)
        return fn(params, opt_state, stacked, valid)

    def flops(self, plan, example_batch) -> float:
        """XLA-measured FLOPs of one train step under `plan` (compiled once,
        cached). Used by EdgeCostModel so SimFreeze savings are *measured*,
        not assumed."""
        if plan not in self._flops:
            key = (self.model.loss, self.model.init, self.opt_cfg, plan,
                   batch_signature(example_batch))
            val = _FLOPS.get(key)
            if val is None:
                step = self.get(plan)
                # avals are enough to lower: skip materializing real params
                params = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
                opt_state = jax.eval_shape(
                    lambda p: make_optimizer_state(
                        self.model, self.opt_cfg, p),
                    params)
                from repro.roofline.analysis import cost_analysis_dict

                lowered = step.lower(params, opt_state, example_batch)
                cost = cost_analysis_dict(lowered.compile())
                val = _FLOPS[key] = float(cost.get("flops", 0.0))
            self._flops[plan] = val
        return self._flops[plan]


def same_shape_runs(batches: Sequence[dict]):
    """Yield the maximal runs of consecutive same-signature batches — the
    units segment batching fuses into single scan dispatches."""
    i, n = 0, len(batches)
    while i < n:
        j = i + 1
        sig = batch_signature(batches[i])
        while j < n and batch_signature(batches[j]) == sig:
            j += 1
        yield batches[i:j]
        i = j


def as_jnp(batch: dict) -> dict:
    """Host batch dict -> device arrays (shared by training and serving)."""
    return {k: jnp.asarray(v) for k, v in batch.items()}


def make_optimizer_state(model, opt_cfg, params):
    if isinstance(opt_cfg, AdamWConfig):
        return adamw_init(params, opt_cfg)
    return sgdm_init(params, opt_cfg)


_COMPILED_MODELS: Dict[Any, Any] = {}


def compiled_model(model):
    """Model whose predict/features dispatch through jit (per-shape XLA
    cache) — the compiled hot path's serving/probe side. `loss` stays
    raw: it is only ever traced inside train steps. Memoized on the
    (features, predict) closures so repeat wraps of the same model share
    one jit cache process-wide."""
    import dataclasses

    key = (model.features, model.predict)
    wrapped = _COMPILED_MODELS.get(key)
    if wrapped is None:
        kw = {"features": jax.jit(model.features)}
        if model.predict is not None:
            kw["predict"] = jax.jit(model.predict)
        wrapped = _COMPILED_MODELS[key] = dataclasses.replace(model, **kw)
    return wrapped


def evaluate(model, params, batch) -> Tuple[float, Any]:
    """Returns (accuracy, logits) on a labeled batch."""
    logits = model.predict(params, batch) if model.predict is not None else None
    if logits is None:
        raise ValueError("model has no predict()")
    import numpy as np

    acc = float(jnp.mean((jnp.argmax(logits, -1) ==
                          jnp.asarray(batch["labels"])).astype(jnp.float32)))
    return acc, np.asarray(logits)


def grad_accum_step(loss_fn, params, batches, plan=None):
    """Gradient accumulation over microbatches via scan (large global
    batches on small meshes)."""
    def micro(carry, batch):
        gsum, lsum = carry
        (l, _), g = jax.value_and_grad(lambda p: loss_fn(p, batch, plan),
                                       has_aux=True)(params)
        return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), batches)
    n = jax.tree.leaves(batches)[0].shape[0]
    return (jax.tree.map(lambda g: g / n, gsum), lsum / n)
