"""Execution-cost models.

``EdgeCostModel`` — Jetson-Xavier-NX-class device for the paper-faithful
experiments. This container cannot measure Jetson wall-clock or energy, so
time/energy are *modeled* from XLA-measured FLOPs plus per-round overheads.
Constants are calibrated so that immediate fine-tuning reproduces the
paper's Fig. 3 breakdown: overheads (system init + model load/save) =
~58% of round time and ~38% of round energy on ResNet50 with 16-image
batches. All benchmark outputs state that they are model-derived.

``PodCostModel`` — TPU v5e roofline constants for §Roofline
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per chip).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EdgeCostModel:
    # compute
    flops_per_sec: float = 0.5e12     # effective sustained training throughput
    compute_power_w: float = 15.0     # paper: 15W power mode
    # per-round overheads (system init / compile, model load, model save)
    t_init_s: float = 0.55
    t_load_s: float = 0.3
    t_save_s: float = 0.25
    overhead_power_w: float = 6.5     # IO/compile phases draw less than compute
    # recompilation after a freeze-plan change (extra system init)
    t_recompile_s: float = 0.55

    @property
    def t_overhead_s(self) -> float:
        return self.t_init_s + self.t_load_s + self.t_save_s

    def round_cost(self, compute_flops: float, recompiles: int = 0):
        """Returns (time_s, energy_j, breakdown dict) for one fine-tuning
        round executing `compute_flops` of training work."""
        t_compute = compute_flops / self.flops_per_sec
        t_over = self.t_overhead_s + recompiles * self.t_recompile_s
        e_compute = t_compute * self.compute_power_w
        e_over = t_over * self.overhead_power_w
        return (t_compute + t_over, e_compute + e_over, {
            "t_compute": t_compute, "t_overhead": t_over,
            "e_compute": e_compute, "e_overhead": e_over})

    def compute_cost(self, flops: float):
        """Pure-compute cost (e.g. CKA probes)."""
        t = flops / self.flops_per_sec
        return t, t * self.compute_power_w


def scale_cost(cost: EdgeCostModel, *, speed: float = 1.0,
               energy: float = 1.0) -> EdgeCostModel:
    """A heterogeneous fleet device's cost model, relative to a reference
    one (DESIGN.md §13): `speed` multiplies throughput and divides every
    fixed time overhead (init/load/save/recompile), `energy` multiplies
    both power draws. Identity scales return `cost` unchanged, so the
    default device is bitwise the reference device. Note executor cost
    calibration re-derives `flops_per_sec` and multiplies the calibrated
    figure by the same speed scale (`FineTuneExecutor.speed_scale`)."""
    if speed == 1.0 and energy == 1.0:
        return cost
    import dataclasses

    return dataclasses.replace(
        cost,
        flops_per_sec=cost.flops_per_sec * speed,
        compute_power_w=cost.compute_power_w * energy,
        overhead_power_w=cost.overhead_power_w * energy,
        t_init_s=cost.t_init_s / speed,
        t_load_s=cost.t_load_s / speed,
        t_save_s=cost.t_save_s / speed,
        t_recompile_s=cost.t_recompile_s / speed)


@dataclass(frozen=True)
class PodCostModel:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # bytes/s / chip
    ici_bw: float = 50e9              # bytes/s / link
    chips: int = 256

    def roofline_terms(self, hlo_flops: float, hlo_bytes: float,
                       collective_bytes: float):
        """The three §Roofline terms, in seconds (whole-step, all chips)."""
        return {
            "compute_s": hlo_flops / (self.chips * self.peak_flops),
            "memory_s": hlo_bytes / (self.chips * self.hbm_bw),
            "collective_s": collective_bytes / (self.chips * self.ici_bw),
        }
