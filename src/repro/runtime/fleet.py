"""DeviceFleet — the multi-device coordinator over DeviceRuntimes.

The ROADMAP north star is serving millions of users; this module is the
first rung (DESIGN.md §13): N simulated edge devices — each a full
`DeviceRuntime` (runtime/device.py) with its own executors, serving lane,
pool and occupancy lane — driven off ONE shared event timeline and ONE
shared `CostLedger`. The fleet owns exactly three cross-device concerns:

- **routing**: a `RoutingPolicy` assigns each arrival stream to a device
  up front (`static` index affinity, or `least-loaded` LPT over event
  counts weighted by device speed), and re-routes the streams of slow or
  evicted devices mid-run;
- **aggregation**: every `aggregate_every` timeline seconds, devices'
  fine-tuned params are merged federated-style — a per-slot weighted
  average, weight = rounds trained since the last merge. Frozen leaves
  are identical across devices (they started from one pretrained model
  and freezing keeps them fixed), so averaging all leaves merges exactly
  the unfrozen deltas. Each participant is charged a cross-device sync
  (`CostLedger.charge_sync`: serialize out + load merged back, at its own
  scaled IO costs) on the fleet pseudo-stream `FLEET_STREAM`;
- **stragglers/elasticity**: the seed `distributed.StragglerTracker` is
  fed each device's mean round time per sync interval; flagged devices'
  streams re-route (to the fastest active device per `rebalance_plan`)
  and their deltas drop out of the merge; `evict_after` consecutive flags
  evicts the device for good (`tracker.evict`), optionally shrinking an
  injected mesh via `distributed.elastic.shrink_mesh`/`remesh`.

`ContinualRuntime.run()` always delegates here: the default session is a
fleet of one device built through the exact legacy code path, so the
golden regression pins fleet-of-1 ≡ single-device bit-for-bit, and every
`RunResult` now carries `per_device` attribution (summing to totals like
`per_stream`/`per_model`) plus a `syncs` counter.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import adapt_controller
from repro.data.arrivals import Event
from repro.distributed.straggler import StragglerConfig, StragglerTracker
from repro.env import DeviceEnv, EnvLedgerObserver
from repro.obs.log import get_logger
from repro.obs.trace import NULL_TRACER
from repro.runtime.config import DeviceConfig
from repro.runtime.device import (DeviceRuntime, clone_device_slots,
                                  clone_pool)
from repro.runtime.ledger import (DEFAULT_DEVICE, DEVICE_KEYS, MODEL_KEYS,
                                  STREAM_KEYS, CostLedger)
from repro.runtime.scheduler import EventScheduler
from repro.runtime.train_loop import (as_jnp, make_optimizer_state,
                                      same_shape_runs)

#: Pseudo-stream id cross-device sync charges land on: no arrival stream
#: caused them, the fleet did. Appears in `per_stream` like any stream
#: (the sums-to-totals contract is unchanged).
FLEET_STREAM = -1

log = get_logger("fleet")


# ---------------------------------------------------------------------------
# routing policies (PolicyStack-style registry, DESIGN.md §10)


class RoutingPolicy:
    """Maps arrival streams to device indices, once, before the run.
    Mid-run moves (stragglers, evictions) are the fleet's job — a policy
    only picks the initial placement."""

    name = "routing"

    def assign(self, stream_ids: List[int], events: List[Event],
               specs: List[DeviceConfig]) -> Dict[int, int]:
        raise NotImplementedError


class StaticAffinity(RoutingPolicy):
    """Stream i -> device i mod N: deterministic, oblivious to load.
    Keeps stream 0 on device 0, which is what makes the fleet-of-1
    delegation trivially exact."""

    name = "static"

    def assign(self, stream_ids, events, specs):
        n = len(specs)
        return {st: i % n for i, st in enumerate(sorted(stream_ids))}


class LeastLoaded(RoutingPolicy):
    """LPT over per-stream event counts: streams are placed heaviest
    first, each onto the device with the least assigned load, where load
    is assigned events divided by the device's speed scale (a 2x device
    absorbs twice the events). Deterministic: ties break on stream id
    (sort) and device index (argmin)."""

    name = "least-loaded"

    def assign(self, stream_ids, events, specs):
        weight: Dict[int, int] = {st: 0 for st in stream_ids}
        for e in events:
            weight[e.stream] = weight.get(e.stream, 0) + 1
        load = [0.0] * len(specs)
        out: Dict[int, int] = {}
        for st in sorted(stream_ids, key=lambda s: (-weight.get(s, 0), s)):
            d = min(range(len(specs)), key=lambda i: (load[i], i))
            out[st] = d
            load[d] += weight.get(st, 0) / specs[d].speed_scale
        return out


ROUTING_POLICIES = {"static": StaticAffinity, "least-loaded": LeastLoaded}


def build_routing(name: str) -> RoutingPolicy:
    if name not in ROUTING_POLICIES:
        raise ValueError(f"unknown routing policy {name!r}; known: "
                         f"{sorted(ROUTING_POLICIES)}")
    return ROUTING_POLICIES[name]()


def fleet_devices(n: int, *, seed: int = 0, speed_spread: float = 0.0,
                  energy_spread: float = 0.0,
                  memory_budget_mb: float = 0.0) -> tuple:
    """N `DeviceConfig`s named dev0..dev{N-1}. Device 0 is always the
    reference device (scale 1.0 — the golden lane); the rest draw
    deterministic speed/energy scales from `1 +- spread` so a
    heterogeneous fleet is one call away."""
    if n < 1:
        raise ValueError("a fleet needs at least one device")
    rng = np.random.default_rng([seed, 7, n])
    out = [DeviceConfig(DEFAULT_DEVICE, memory_budget_mb=memory_budget_mb)]
    for i in range(1, n):
        speed = 1.0 + speed_spread * float(rng.uniform(-1.0, 1.0))
        energy = 1.0 + energy_spread * float(rng.uniform(-1.0, 1.0))
        out.append(DeviceConfig(f"dev{i}", speed_scale=max(speed, 0.05),
                                energy_scale=max(energy, 0.05),
                                memory_budget_mb=memory_budget_mb))
    return tuple(out)


# ---------------------------------------------------------------------------
# the coordinator


class DeviceFleet:
    """Drives one session's timeline across N `DeviceRuntime`s.

    Constructed from a `ContinualRuntime` (the config holder); device
    specs / routing / aggregation period default to the host's
    (`RuntimeConfig.devices/routing/aggregate_every`) and can be
    overridden per run. `straggler` takes a `StragglerConfig`;
    `mesh`/`mesh_axis`/`param_specs` optionally wire
    `distributed.elastic` so an eviction shrinks a real device mesh and
    re-shards the survivors' params onto it."""

    def __init__(self, host, *, devices: Optional[List[DeviceConfig]] = None,
                 routing: Optional[str] = None,
                 aggregate_every: Optional[float] = None,
                 straggler: Optional[StragglerConfig] = None,
                 mesh=None, mesh_axis: str = "data", param_specs=None):
        self.host = host
        specs = list(devices) if devices is not None \
            else (list(getattr(host, "devices", ())) or
                  [DeviceConfig(DEFAULT_DEVICE)])
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"device names must be unique (got {names})")
        self.specs = specs
        self.policy = build_routing(
            routing if routing is not None
            else getattr(host, "routing", "static"))
        self.aggregate_every = float(
            aggregate_every if aggregate_every is not None
            else getattr(host, "aggregate_every", 0.0))
        self._straggler_cfg = straggler \
            or getattr(host, "straggler_config", None)
        self._mesh = mesh
        self._mesh_axis = mesh_axis
        self._param_specs = param_specs
        # populated by run()
        self.scheduler: Optional[EventScheduler] = None
        self.ledger: Optional[CostLedger] = None
        self.devices: List[DeviceRuntime] = []
        self.assignment: Dict[int, int] = {}
        self.tracker: Optional[StragglerTracker] = None
        self._evicted: set = set()
        self._flagged: set = set()
        # physical environment (DESIGN.md §15): device name -> DeviceEnv
        # for every device whose DeviceConfig carries an active EnvSpec;
        # empty (the default) keeps every env branch untaken.
        self.envs: Dict[str, DeviceEnv] = {}
        # observability (DESIGN.md §14): run() swaps in the host's live
        # Telemetry bundle when one is configured; the falsy NULL_TRACER
        # default keeps every instrumented path allocation-free.
        self.telemetry = None
        self.tracer = NULL_TRACER

    # ---- lookups (fleet-level policy state, see device.py docstring) -----
    def device_for(self, stream: int) -> DeviceRuntime:
        return self.devices[self.assignment.get(stream, 0)]

    def ctrl_for(self, st: int):
        return self.controllers.get(st, self.primary_ctrl)

    def bench_for(self, st: int):
        b = self.host.stream_benchmarks.get(st)
        return b if b is not None else self.device_for(st).slot_of(st).bench

    # ---- run -------------------------------------------------------------
    def run(self, events: List[Event]):
        from repro.runtime.continual import RunResult

        host = self.host
        rng = np.random.default_rng(host.seed)
        ledger = CostLedger()
        self.ledger = ledger
        # observability: reset the host's Telemetry for this run (fresh
        # tracer + registry), install it as the ledger's observer and
        # expose its tracer to every subsystem built below. A host
        # without telemetry keeps the falsy NULL_TRACER everywhere.
        tel = getattr(host, "telemetry", None)
        self.telemetry = tel
        if tel is not None:
            tel.reset()
            self.tracer = tel.tracer
            ledger.telemetry = tel
        slots0 = host._build_slots(ledger, rng, device=self.specs[0])
        primary_slot = next(iter(slots0.values()))
        primary_ctrl = host.controller if host.controller is not None \
            else primary_slot.controller

        # --- pretrain every slot on its scenario 0 (not cost-accounted;
        # paper §V-A) and measure slot memory footprints — once, centrally:
        # every fleet device starts from the same pretrained model --------
        for st in slots0.values():
            params = st.model.init(jax.random.PRNGKey(host.seed))
            opt_state = make_optimizer_state(st.model, host.opt_cfg, params)
            if st.steps.donate:
                # donation needs de-aliased buffers: init trees share
                # zero-filled leaves (and constant-cache hits), which a
                # donating step would otherwise donate twice
                params = jax.tree.map(jnp.copy, params)
                opt_state = jax.tree.map(jnp.copy, opt_state)
            plan0 = st.controller.plan
            pre = [b for _ in range(host.pretrain_epochs)
                   for b in st.bench.scenarios[0].train_batches]
            if host.compiled:
                # one fused scan per same-shape run of pretrain batches
                for run in same_shape_runs(pre):
                    params, opt_state, _ = st.steps.fused_call(
                        plan0, params, opt_state, run)
            else:
                step0 = st.steps.get(plan0)
                for b in pre:
                    params, opt_state, _ = step0(params, opt_state,
                                                 as_jnp(b))
            st.reference_params = params  # "initial model before fine-tuning"
            st.executor.load(params, opt_state)
        if host.pool is not None:
            from repro.runtime.modelpool import tree_mb

            for name, st in slots0.items():
                host.pool.set_memory(name, tree_mb(st.executor.params,
                                                   st.executor.opt_state))
            host.pool.warm()

        # --- route streams, compose the per-device runtimes ---------------
        stream_ids = sorted({e.stream for e in events}) or [0]
        self.stream_slot: Dict[int, str] = {}
        if host.pool is not None:
            for e in events:
                self.stream_slot.setdefault(e.stream, e.modality)
            for st_id, name in self.stream_slot.items():
                host.pool.slot(name)  # raise early on an unknown modality
        self.assignment = dict(self.policy.assign(stream_ids, events,
                                                  self.specs))
        scheduler = EventScheduler(events)
        scheduler.tracer = self.tracer
        scheduler.trace_dispatch = tel.spec.dispatch_events \
            if tel is not None else True
        self.scheduler = scheduler
        # live handles: controller callbacks / tests may push events onto
        # the running timeline (mid-drain push is supported)
        host.scheduler = scheduler
        host.fleet = self

        self.pending_change = {st: False for st in stream_ids}
        # probes_pushed numbers probe Events; probes_fired counts the ones
        # actually dispatched (a detection during the post-drain flush
        # pushes onto an already-drained scheduler and never runs)
        self.probes_pushed = [0]
        self.probes_fired = [0]
        self.scenario_started: Dict[int, bool] = {}
        self.last_round_end: Dict[int, float] = {}
        self.launch_scenario: Dict[int, int] = {}
        self.val_curve: List[float] = []
        # QoS: a stream's priority rides on its events; a round reserves
        # its device at the stream's priority, so only strictly-higher-
        # priority arrivals can split it
        self.stream_priority: Dict[int, int] = {st: 0 for st in stream_ids}
        for e in events:
            self.stream_priority[e.stream] = max(
                self.stream_priority[e.stream], e.priority)

        self.devices = [DeviceRuntime(self, self.specs[0], 0, slots0,
                                      host.pool, rng)]
        for d, spec in enumerate(self.specs[1:], start=1):
            slots, dev_rng = clone_device_slots(self, spec, d, slots0,
                                                ledger)
            self.devices.append(DeviceRuntime(
                self, spec, d, slots, clone_pool(host, spec, slots),
                dev_rng))

        # --- physical environments (DESIGN.md §15): one DeviceEnv per
        # device with an active EnvSpec; the env observer wraps whatever
        # telemetry observer the ledger already has so every charge's
        # energy drains the owning device's battery / heats its RC node.
        # No active env -> no observer swap: the default path is
        # bit-exact untouched.
        self.envs = {}
        for dev in self.devices:
            env_spec = getattr(dev.spec, "env", None)
            if env_spec is not None and env_spec.active:
                dev.env = DeviceEnv(env_spec, dev.name, tracer=self.tracer)
                self.envs[dev.name] = dev.env
        if self.envs:
            ledger.telemetry = EnvLedgerObserver(self.envs,
                                                 inner=ledger.telemetry)

        # per-stream controllers: stream 0 is the primary controller;
        # extra streams get their own from the factory, or share the
        # primary one. Under a ModelPool a stream's controller is its
        # *slot's* on its owning device (streams sharing a model share
        # the policy that owns its freeze plan).
        controllers: Dict[int, object] = {}
        for st in stream_ids:
            if host.pool is not None:
                controllers[st] = self.device_for(st).slot_of(st).controller
            elif st == 0 or host.controller_factory is None:
                controllers[st] = primary_ctrl
            else:
                controllers[st] = host.controller_factory(st)
        self.controllers = {st: adapt_controller(c)
                            for st, c in controllers.items()}
        self.primary_ctrl = adapt_controller(primary_ctrl)

        # stragglers are observable once >= 2 devices report round times;
        # mitigation fires at sync boundaries, so it needs a sync period
        if len(self.specs) > 1 and self.aggregate_every > 0.0:
            self.tracker = StragglerTracker(
                len(self.specs), config=self._straggler_cfg)
        self._next_sync = self.aggregate_every or float("inf")

        # --- drive the shared timeline ------------------------------------
        def on_data(ev: Event, boundary: bool) -> None:
            self._advance(ev.time)
            self._settle_all(ev.time)
            if self.envs:
                self._step_envs(ev.time)
            self.device_for(ev.stream).on_data(ev, boundary)

        def on_scenario_change(previous: int, ev: Event) -> None:
            self.device_for(ev.stream).on_scenario_change(previous, ev)

        def on_inference(ev: Event) -> None:
            self._advance(ev.time)
            self._settle_all(ev.time)
            if self.envs:
                self._step_envs(ev.time)
            self.device_for(ev.stream).on_inference(ev)

        def on_inference_event(ev: Event) -> None:
            # compiled but unsegmented (detector mode, or `segment` off):
            # serve each event's deferred dispatch before the next event
            on_inference(ev)
            self.device_for(ev.stream).server.drain()

        def on_probe(ev: Event) -> None:
            self._advance(ev.time)
            self._settle_all(ev.time)
            if self.envs:
                self._step_envs(ev.time)
            self.device_for(ev.stream).on_probe(ev)

        def on_inference_segment(segment: List[Event]) -> None:
            # a maximal run of consecutive inference events (compiled hot
            # path, DESIGN.md §12): per-event bookkeeping is unchanged,
            # only each device's dispatch is deferred and fused per drain
            for ev in segment:
                on_inference(ev)
            for dev in self.devices:
                dev.server.drain()

        segmented = (host.compiled and host.segment
                     and host.boundaries != "detector")
        scheduler.run(
            on_data=on_data,
            on_inference=on_inference_event if host.compiled
            else on_inference,
            on_scenario_change=on_scenario_change, on_probe=on_probe,
            on_inference_segment=on_inference_segment if segmented
            else None)
        self._settle_all(float("inf"))  # finalize rounds still in flight
        for dev in self.devices:
            dev.server.flush()
            dev.server.drain()
            dev.trailing_flush()

        return self._assemble(RunResult)

    # ---- aggregation / stragglers ----------------------------------------
    def _settle_all(self, now: float) -> None:
        for dev in self.devices:
            dev.settle(now)

    def _step_envs(self, now: float) -> None:
        """Advance every live environment to `now` (after the devices
        settled, so the energy each env integrates is the energy the
        ledger actually charged up to `now`), apply any DVFS rescale to
        the device's executors, and hand battery-dead devices to the
        existing eviction path: streams re-route, deltas leave the
        merge — exactly like a persistent straggler, different cause."""
        for dev in self.devices:
            env = dev.env
            if env is None:
                continue
            env.step(now)
            dev.apply_dvfs()
            if env.battery_dead and dev.index not in self._evicted:
                self.evict_device(dev.index, now, reason="battery dead")

    def _advance(self, t: float) -> None:
        """Cross the sync boundaries the timeline has passed: settle
        every device to the boundary instant, then merge/mitigate."""
        while t >= self._next_sync:
            ts = self._next_sync
            self._settle_all(ts)
            self._sync(ts)
            self._next_sync += self.aggregate_every

    def _sync(self, ts: float) -> None:
        if self.tracker is not None:
            times = {d.index: float(np.mean(d.round_times))
                     for d in self.devices
                     if d.round_times and d.index not in self._evicted}
            if times:
                self.tracker.record_step(times)
            for d in self.devices:
                d.round_times.clear()
            for h in sorted(set(self.tracker.to_evict()) - self._evicted):
                self.evict_device(h, ts)
            current = set(self.tracker.stragglers()) - self._evicted
            for h in sorted(current - self._flagged):
                # straggler mitigation must be loud: a flagged device
                # loses its streams and sits merges out until it recovers
                log.warning("sync at t=%.3f: device %s flagged as "
                            "straggler — re-routing its streams",
                            ts, self.devices[h].name)
                if self.telemetry is not None:
                    self.telemetry.metrics.counter(
                        "straggler_flags",
                        device=self.devices[h].name).inc()
                if self.tracer:
                    self.tracer.instant("straggler", "flag", ts,
                                        device=self.devices[h].name)
                self._reroute_streams(h, ts)
            self._flagged = current
        self._merge(ts)

    def _merge(self, ts: float) -> None:
        """Federated merge (module docstring): per slot, average the
        participants' params weighted by rounds trained since the last
        sync. A device sits a slot's merge out when it is evicted,
        flagged slow, or mid-round (its params are a checkpointed round
        in flight); a merge needs >= 2 such devices and > 0 total weight.
        Optimizer state stays local (FedAvg merges params only)."""
        candidates = [d for d in self.devices
                      if d.index not in self._evicted
                      and d.index not in self._flagged
                      and not (d.env is not None and d.env.battery_dead)]
        tel = self.telemetry
        for name in self.devices[0].slots:
            group = [d for d in candidates
                     if d.slots[name].executor.active_round is None]
            skipped = [d for d in candidates if d not in group]
            for d in skipped:
                # never a silent drop: a mid-round device sitting a merge
                # out is expected, but observable (log + counter)
                log.info("sync at t=%.3f: device %s sits out slot %r "
                         "merge (round in flight)", ts, d.name, name)
                if tel is not None:
                    tel.metrics.counter("sync_skips", device=d.name).inc()
            if len(group) < 2:
                log.info("sync at t=%.3f: slot %r merge skipped "
                         "(%d eligible device(s), need >= 2)",
                         ts, name, len(group))
                continue
            ws = [float(d.rounds_since_sync.get(name, 0)) for d in group]
            total = sum(ws)
            if total <= 0.0:
                continue
            trees = [d.slots[name].executor.params for d in group]
            merged = jax.tree.map(
                lambda *ls: (sum(w * l.astype(jnp.float32)
                                 for w, l in zip(ws, ls))
                             / total).astype(ls[0].dtype), *trees)
            for d in group:
                ex = d.slots[name].executor
                ex.params = jax.tree.map(jnp.copy, merged)
                d.server.publish(ex.params, ts, slot=name)
                c = ex.cost
                t_sync = c.t_save_s + c.t_load_s
                self.ledger.charge_sync(
                    time_s=t_sync, energy_j=t_sync * c.overhead_power_w,
                    device=d.name, stream=FLEET_STREAM, model=name)
                r = self.scheduler.occupy(ts, t_sync, stream=FLEET_STREAM,
                                          device=d.name)
                if self.tracer:
                    self.tracer.span("sync", f"sync/{name}", r.start,
                                     t_sync, stream=FLEET_STREAM,
                                     device=d.name, slot=name,
                                     participants=len(group))
                d.rounds_since_sync[name] = 0

    def _reroute_streams(self, from_idx: int, ts: float) -> None:
        """Move every stream off device `from_idx` to the active
        non-flagged device with the largest rebalance share (inverse EMA
        step time — the fastest one). Buffered batches move with the
        stream; controllers and policy latches are fleet-level, so the
        stream's policy state survives the move untouched."""
        plan = self.tracker.rebalance_plan() if self.tracker else {}
        targets = [d for d in self.devices
                   if d.index not in self._evicted
                   and d.index not in self._flagged
                   and d.index != from_idx]
        if not targets:
            return
        target = max(targets, key=lambda d: plan.get(d.index, 0.0))
        src = self.devices[from_idx]
        for st, di in sorted(self.assignment.items()):
            if di != from_idx:
                continue
            self.assignment[st] = target.index
            batches = src.slot_of(st).executor.buffers.pop(st, None)
            for b in batches or ():
                target.slot_of(st).executor.enqueue(b, stream=st)

    def evict_device(self, index: int, ts: float, *,
                     reason: str = "persistent straggler") -> None:
        """Drop a device for good: its streams re-route, its deltas drop
        out of every future merge, and — when an elastic mesh was
        injected — the mesh shrinks and the survivors' params re-shard
        onto it (values preserved; distributed/elastic.py). `reason`
        distinguishes straggler evictions from env-driven ones (a dead
        battery rides the same path, DESIGN.md §15)."""
        if index in self._evicted:
            return
        log.warning("t=%.3f: evicting device %s (%s); "
                    "its streams re-route and its deltas leave the merge",
                    ts, self.devices[index].name, reason)
        if self.telemetry is not None:
            self.telemetry.metrics.counter(
                "evictions", device=self.devices[index].name).inc()
        if self.tracer:
            self.tracer.instant("straggler", "evict", ts,
                                device=self.devices[index].name,
                                reason=reason)
        if self.tracker is not None:
            self.tracker.evict(index)
        self._evicted.add(index)
        self._reroute_streams(index, ts)
        if self._mesh is not None:
            from repro.distributed import elastic

            shape = dict(self._mesh.shape)
            if shape.get(self._mesh_axis, 0) % 2 == 0 \
                    and shape.get(self._mesh_axis, 0) >= 2:
                self._mesh = elastic.shrink_mesh(self._mesh,
                                                 self._mesh_axis)
                if self._param_specs is not None:
                    for d in self.devices:
                        if d.index in self._evicted:
                            continue
                        for st in d.slots.values():
                            st.executor.params = elastic.remesh(
                                st.executor.params, self._mesh,
                                self._param_specs)

    # ---- result ----------------------------------------------------------
    def _assemble(self, RunResult):
        host = self.host
        ledger, scheduler = self.ledger, self.scheduler
        slots0 = self.devices[0].slots
        stats = self.primary_ctrl.stats() \
            if hasattr(self.primary_ctrl, "stats") else {}
        accs_by_stream: Dict[int, List[float]] = {}
        lats_by_stream: Dict[int, List[float]] = {}
        accs_by_slot: Dict[str, List[float]] = {}
        all_accs: List[float] = []
        for dev in self.devices:
            for st, a in dev.server.accs_by_stream.items():
                accs_by_stream.setdefault(st, []).extend(a)
            for st, ls in dev.server.latencies_by_stream.items():
                lats_by_stream.setdefault(st, []).extend(ls)
            for name, a in dev.server.accs_by_slot.items():
                accs_by_slot.setdefault(name, []).extend(a)
            all_accs.extend(dev.server.accs)
        per_stream: Dict[int, Dict[str, float]] = {}
        for st in sorted(set(self.assignment) | set(ledger.per_stream)
                         | set(accs_by_stream)):
            cell = dict(ledger.per_stream.get(
                st, {k: 0.0 for k in STREAM_KEYS}))
            accs = accs_by_stream.get(st, [])
            cell["avg_inference_acc"] = float(np.mean(accs)) if accs else 0.0
            cell["inferences"] = float(len(accs))
            lats = lats_by_stream.get(st, [])
            cell["latency_p50"] = float(np.percentile(lats, 50)) \
                if lats else 0.0
            cell["latency_p95"] = float(np.percentile(lats, 95)) \
                if lats else 0.0
            per_stream[st] = cell
        per_model: Dict[str, Dict[str, float]] = {}
        for name in sorted(set(slots0) | set(ledger.per_model)
                           | set(accs_by_slot)):
            cell = dict(ledger.per_model.get(
                name, {k: 0.0 for k in MODEL_KEYS}))
            accs = accs_by_slot.get(name, [])
            cell["avg_inference_acc"] = float(np.mean(accs)) if accs else 0.0
            cell["inferences"] = float(len(accs))
            per_model[name] = cell
        makespan = max([scheduler.now]
                       + [scheduler.busy_until_of(d.name)
                          for d in self.devices])
        for dev in self.devices:
            if dev.env is not None:
                dev.env.finalize(makespan)
        per_device: Dict[str, Dict[str, float]] = {}
        for dev in self.devices:
            cell = dict(ledger.per_device.get(
                dev.name, {k: 0.0 for k in DEVICE_KEYS}))
            accs = dev.server.accs
            cell["avg_inference_acc"] = float(np.mean(accs)) if accs else 0.0
            cell["inferences"] = float(len(accs))
            cell["streams"] = float(sum(
                1 for di in self.assignment.values() if di == dev.index))
            cell["utilization"] = cell["time_s"] / makespan \
                if makespan > 0 else 0.0
            cell["evicted"] = float(dev.index in self._evicted)
            cell["battery_dead"] = float(dev.env.battery_dead) \
                if dev.env is not None else 0.0
            cell["throttle_s"] = dev.env.throttle_s \
                if dev.env is not None else 0.0
            per_device[dev.name] = cell
        tel = self.telemetry
        if tel is not None:
            for dev in self.devices:
                tel.metrics.gauge("utilization", device=dev.name).set(
                    per_device[dev.name]["utilization"])
                env = dev.env
                if env is not None:
                    st = env.state()
                    tel.metrics.gauge("temperature_c",
                                      device=dev.name).set(st.temperature_c)
                    if st.soc is not None:
                        tel.metrics.gauge("soc",
                                          device=dev.name).set(st.soc)
            tel.metrics.gauge("recompiles").set(float(
                sum(st.steps.recompiles for st in slots0.values())
                if host.pool is not None else host.steps.recompiles))
            tel.metrics.gauge("makespan_s").set(makespan)
            tel.flush_sinks()
        return RunResult(
            avg_inference_acc=float(np.mean(all_accs)) if all_accs else 0.0,
            total_time_s=ledger.total_time_s,
            total_energy_j=ledger.total_energy_j,
            compute_tflops=ledger.compute_tflops, rounds=ledger.rounds,
            recompiles=sum(st.steps.recompiles for st in slots0.values())
            if host.pool is not None else host.steps.recompiles,
            inference_accs=all_accs,
            breakdown=ledger.breakdown, controller_stats=stats,
            val_curve=self.val_curve, per_stream=per_stream,
            per_model=per_model, per_device=per_device,
            preemptions=ledger.preemptions,
            swaps=ledger.swaps, syncs=ledger.syncs,
            probes=self.probes_fired[0])
