"""Elastic scaling: re-shard a live pytree (params + optimizer state) onto
a different mesh — grow after repair, shrink after eviction — without
changing global array values. Combined with the checkpoint manager this is
the recovery path: restore_latest() -> remesh() -> resume.

On the real fleet the source and target meshes are different process
groups; here both are host-device meshes, which exercises the same
jax.device_put resharding machinery."""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.distributed import sharding as sh


def remesh(tree, new_mesh: Mesh, spec_tree):
    """Move every leaf to its spec on the new mesh (values preserved)."""
    shardings = sh.named(new_mesh, spec_tree)
    return jax.tree.map(
        lambda leaf, s: jax.device_put(leaf, s), tree, shardings)


def shrink_mesh(mesh: Mesh, drop_axis: str = "data") -> Mesh:
    """Mesh with half the devices along `drop_axis` (failure of a slice)."""
    names = mesh.axis_names
    shape = dict(mesh.shape)
    assert shape[drop_axis] % 2 == 0, (drop_axis, shape)
    shape[drop_axis] //= 2
    devs = np.asarray(mesh.devices)
    idx = [slice(None)] * devs.ndim
    idx[names.index(drop_axis)] = slice(0, shape[drop_axis])
    return Mesh(devs[tuple(idx)], names)


def elastic_restore(manager, like, cfg: ModelConfig, mesh: Mesh,
                    policy: sh.ShardingPolicy = sh.ShardingPolicy()):
    """Restore the latest valid checkpoint directly onto `mesh` (which may
    have any shape — e.g. after an eviction)."""
    specs = sh.param_specs(like, cfg, mesh, policy)
    shardings = sh.named(mesh, specs)
    tree, step = manager.restore_latest(like, shardings=shardings)
    return tree, step
