"""Straggler detection & mitigation for 1000+-node fleets.

On a synchronous TPU mesh every step is implicitly barriered, so a slow
host delays the world. The tracker keeps a per-host EMA of step times,
flags hosts whose recent times exceed a robust z-score threshold, and the
mitigation policy decides between:
- `rebalance`: shrink the flagged host's data shard (work stealing) —
  returns a per-host batch-fraction plan;
- `evict`: drop the host and trigger an elastic remesh (distributed/
  elastic.py) from the latest checkpoint.

The container has one real host, so the unit tests drive the tracker with
synthetic timing traces; the interfaces are what a multi-host launcher
would call around each step."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class StragglerConfig:
    ema: float = 0.9
    z_threshold: float = 3.0
    min_samples: int = 8
    slow_factor: float = 1.5        # flagged if > factor x fleet median
    evict_after: int = 3            # consecutive flags before eviction


@dataclass
class HostStat:
    ema_time: float = 0.0
    samples: int = 0
    flags: int = 0


class StragglerTracker:
    def __init__(self, num_hosts: int,
                 config: Optional[StragglerConfig] = None):
        # NOTE: the config default must be built per instance — a
        # `config=StragglerConfig()` default would be evaluated once at
        # function definition and *shared by every tracker* (mutable
        # dataclass), so tuning one tracker's thresholds would silently
        # retune all of them
        self.cfg = config if config is not None else StragglerConfig()
        self.hosts: Dict[int, HostStat] = {h: HostStat() for h in range(num_hosts)}
        self.evicted: List[int] = []

    def record_step(self, host_times: Dict[int, float]) -> None:
        for h, t in host_times.items():
            st = self.hosts.get(h)
            if st is None or h in self.evicted:
                continue
            st.ema_time = t if st.samples == 0 else \
                self.cfg.ema * st.ema_time + (1 - self.cfg.ema) * t
            st.samples += 1
        self._update_flags()

    def _active(self) -> List[int]:
        return [h for h in self.hosts if h not in self.evicted]

    def _update_flags(self) -> None:
        act = [h for h in self._active()
               if self.hosts[h].samples >= self.cfg.min_samples]
        if len(act) < 2:
            return
        med = float(np.median([self.hosts[h].ema_time for h in act]))
        for h in act:
            if self.hosts[h].ema_time > self.cfg.slow_factor * med:
                self.hosts[h].flags += 1
            else:
                self.hosts[h].flags = 0

    def stragglers(self) -> List[int]:
        return [h for h in self._active() if self.hosts[h].flags > 0]

    def to_evict(self) -> List[int]:
        return [h for h in self._active()
                if self.hosts[h].flags >= self.cfg.evict_after]

    # -- mitigation plans ------------------------------------------------
    def rebalance_plan(self) -> Dict[int, float]:
        """Per-host share of the global batch, inversely proportional to
        EMA step time (work stealing). Sums to 1."""
        act = self._active()
        times = np.array([max(self.hosts[h].ema_time, 1e-6) for h in act])
        inv = 1.0 / times
        shares = inv / inv.sum()
        return {h: float(s) for h, s in zip(act, shares)}

    def evict(self, host: int) -> None:
        if host not in self.evicted:
            self.evicted.append(host)
            self.hosts[host].flags = 0
