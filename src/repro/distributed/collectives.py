"""Explicit collective paths used where GSPMD's implicit ones are not
enough:

- `sync_grads_shard_map`: data-parallel gradient sum via shard_map psum,
  with optional int8 error-feedback compression (all-gather the compressed
  payloads, decompress-and-sum locally — the standard compressed-allreduce
  construction) and freeze-aware *skipping*: frozen chunks are never
  communicated at all (ETuner's collective-term saving; DESIGN.md §2).
- `hierarchical_grad_sync`: reduce within pod first (fast ICI), then
  across pods (slow DCN) — composable axes for the multi-pod mesh.
"""
from __future__ import annotations

import inspect
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.optim import compression

try:  # jax >= 0.6 exposes shard_map at the top level (check_vma kwarg)
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x keeps it in experimental (check_rep kwarg)
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
    """Version-tolerant `shard_map`: translates the modern ``check_vma``
    kwarg to 0.4.x's ``check_rep`` (same meaning, renamed upstream)."""
    if "check_vma" in _SM_PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _SM_PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def sync_grads_shard_map(mesh: Mesh, grads, *, axis: str = "data",
                         compress: bool = False, residual=None,
                         freeze_mask=None):
    """grads: per-device local grads (replicated tree structure). Returns
    (synced grads averaged over `axis`, new residual).

    freeze_mask: optional 0/1 pytree; leaves with mask==0 are returned
    untouched (zeros) and produce NO collective traffic."""

    def select(tree, keep: bool):
        if freeze_mask is None:
            return tree if keep else None
        flat, treedef = jax.tree_util.tree_flatten(tree)
        mflat = jax.tree_util.tree_flatten(freeze_mask)[0]
        out = [l for l, m in zip(flat, mflat)
               if (bool(jnp.all(m == 0)) != keep)]
        return out

    n = mesh.shape[axis]

    if not compress:
        @partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
                 check_vma=False)
        def sync(g):
            return jax.tree.map(lambda x: jax.lax.psum(x, axis) / n, g)

        if freeze_mask is not None:
            flat, treedef = jax.tree_util.tree_flatten(grads)
            mflat = jax.tree_util.tree_flatten(freeze_mask)[0]
            active = [l for l, m in zip(flat, mflat) if not bool(jnp.all(m == 0))]
            synced = sync(tuple(active)) if active else ()
            it = iter(synced)
            out = [next(it) if not bool(jnp.all(m == 0)) else jnp.zeros_like(l)
                   for l, m in zip(flat, mflat)]
            return jax.tree_util.tree_unflatten(treedef, out), residual
        return sync(grads), residual

    # compressed path: quantize locally (+error feedback), all-gather the
    # int8 payloads over the axis, dequantize-and-mean locally.
    if residual is None:
        residual = compression.init_residual(grads)
    q_tree, s_tree, new_residual = compression.int8_compress_tree(grads, residual)

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
             check_vma=False)
    def gather_sum(q, s):
        def leaf(qi, si):
            qs = jax.lax.all_gather(qi, axis)           # [n, ...] int8
            ss = jax.lax.all_gather(si, axis)           # [n]
            deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * qi.ndim)
            return jnp.mean(deq, axis=0)

        return jax.tree.map(leaf, q, s)

    return gather_sum(q_tree, s_tree), new_residual


def hierarchical_grad_sync(mesh: Mesh, grads):
    """Reduce over 'data' (intra-pod ICI) then 'pod' (inter-pod DCN)."""
    axes = [a for a in ("data", "pod") if a in mesh.axis_names]

    @partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
             check_vma=False)
    def sync(g):
        out = g
        for a in axes:
            out = jax.tree.map(lambda x, a=a: jax.lax.psum(x, a), out)
        denom = 1
        for a in axes:
            denom *= mesh.shape[a]
        return jax.tree.map(lambda x: x / denom, out)

    return sync(grads)
