"""Sharding rules for the production meshes.

Meshes (launch/mesh.py): single-pod ``(data=16, model=16)`` = 256 chips,
multi-pod ``(pod=2, data=16, model=16)`` = 512 chips.

Param placement is name-based with **divisibility fallback chains** —
the `model` axis is 16 but e.g. gemma2-2b has 8 query heads and granite-20b
has a single KV head, so a fixed "shard heads on model" rule cannot hold
across the 10 assigned archs. Each tensor kind declares an ordered list of
(dim, axis) candidates; the first whose dimension divides the mesh axis
size wins, otherwise the tensor is replicated on that axis and the extra
collectives show up in — and are attributed by — the roofline analysis.

FSDP ("zero3"): optionally shard the d_model/reduction dim of every large
param over the data axes (and the pod axis in multi-pod runs) — required
to fit kimi-k2 (≈1T params) and jamba-1.5 (398B); XLA inserts the
all-gathers (and reduce-scatters in backward) automatically.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# activation sharding hints (MaxText-style logical-axis constraints)
#
# GSPMD propagation alone loses the batch sharding at the embedding gather
# (the FSDP-sharded table wins, and attention then runs on the full global
# batch — observed in EXPERIMENTS.md §Perf iteration 1). Model code
# therefore asserts activation layouts at layer boundaries. The mesh is
# provided through a thread-local context so the same model code runs
# un-constrained on a bare CPU (tests) and constrained under the
# production mesh (dry-run / real launch).

_ACTIVATION_MESH = threading.local()

BATCH_AXES = ("pod", "data")


@contextmanager
def activation_sharding(mesh: Mesh):
    old = getattr(_ACTIVATION_MESH, "mesh", None)
    _ACTIVATION_MESH.mesh = mesh
    try:
        yield
    finally:
        _ACTIVATION_MESH.mesh = old


def _current_mesh() -> Optional[Mesh]:
    return getattr(_ACTIVATION_MESH, "mesh", None)


def hint(x, *spec):
    """with_sharding_constraint(x, P(*spec)) against the activation mesh,
    silently dropping axes that are absent or don't divide the dim."""
    mesh = _current_mesh()
    if mesh is None or not hasattr(x, "ndim"):
        return x
    clean = []
    for dim in range(x.ndim):
        s = spec[dim] if dim < len(spec) else None
        axes = (s,) if isinstance(s, str) else tuple(s or ())
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 1 and x.shape[dim] % size == 0:
            clean.append(axes[0] if len(axes) == 1 else axes)
        else:
            clean.append(None)
    if all(c is None for c in clean):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))


def hint_batch(x):
    """[B, ...] activations: batch over (pod, data)."""
    return hint(x, BATCH_AXES)


@dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True               # shard params over data axes (ZeRO-3)
    fsdp_pod: bool = True           # include the pod axis in FSDP
    shard_embed_vocab: bool = True  # vocab dim of embeddings on `model`
    seq_shard_long: bool = True     # shard seq dim when batch < data axis


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh: Mesh, policy: ShardingPolicy) -> Tuple[str, ...]:
    if not policy.fsdp:
        return ()
    axes = ["data"] if "data" in mesh.axis_names else []
    if policy.fsdp_pod and "pod" in mesh.axis_names:
        axes = ["pod"] + axes
    return tuple(axes)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    size = _axis_size(mesh, axes)
    return size > 1 and dim % size == 0


def _pick(mesh: Mesh, shape, candidates) -> P:
    """candidates: ordered [(dim_index, axes)] claims; claims compose as
    long as dims differ and each divides. Returns a PartitionSpec."""
    spec = [None] * len(shape)
    used = set()
    for dim, axes in candidates:
        if axes is None or dim >= len(shape) or spec[dim] is not None:
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        if any(a in used for a in ax_tuple):
            continue
        if all(a in mesh.axis_names for a in ax_tuple) and _fits(shape[dim], mesh, ax_tuple):
            spec[dim] = axes
            used.update(ax_tuple)
    return P(*spec)


# ---------------------------------------------------------------------------
# parameter specs


def param_specs(params, cfg: ModelConfig, mesh: Mesh,
                policy: ShardingPolicy = ShardingPolicy()):
    """Pytree of PartitionSpec matching `params` (LM models; the paper's
    unrolled CV/NLP models run single-device and use replicated specs)."""
    fa = fsdp_axes(mesh, policy)

    def spec_for(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shape = leaf.shape
        stacked = "blocks" in names  # leading [G] scan dim
        off = 1 if stacked else 0

        def cands(raw):  # shift dim indices past the scan dim
            return [(d + off, a) for d, a in raw]

        name = names[-1]
        parent = names[-2] if len(names) >= 2 else ""
        c: list = []
        if "embed" in names and name == "tok":
            c = [(0, "model") if policy.shard_embed_vocab else (0, None),
                 (1, fa)] if fa else [(0, "model")]
            c = [(0, "model"), (1, fa)] if fa else [(0, "model")]
        elif "embed" in names and name == "head":
            c = [(1, "model"), (0, fa)]
        elif "embed" in names and name == "frontend_proj":
            c = [(1, "model")]
        elif name in ("wq",):
            # GQA fallback chain: heads -> (optional head_dim) -> replicate.
            # head_dim sharding splits RoPE/softmax dims and costs per-layer
            # collectives, so it is off by default (EXPERIMENTS.md §Perf).
            c = cands([(1, "model"), (0, fa)] + (
                [(2, "model")] if cfg.shard_head_dim else []))
        elif name in ("wk", "wv"):
            c = cands([(1, "model"), (0, fa)] + (
                [(2, "model")] if cfg.shard_head_dim else []))
        elif name == "wo" and parent == "mix" and leaf.ndim - off == 3:
            c = cands([(0, "model"), (2, fa)] + (
                [(1, "model")] if cfg.shard_head_dim else []))
        elif name in ("bq", "bk", "bv"):
            c = cands([(0, "model")] + (
                [(1, "model")] if cfg.shard_head_dim else []))
        elif name in ("wg", "wu") and leaf.ndim - off == 3:  # moe [E, D, F]
            c = cands([(0, "model"), (1, fa)])
        elif name == "wd" and leaf.ndim - off == 3:          # moe [E, F, D]
            c = cands([(0, "model"), (2, fa)])
        elif name in ("wg", "wu"):                           # mlp [D, F]
            c = cands([(1, "model"), (0, fa)])
        elif name == "wd":                                   # mlp [F, D]
            c = cands([(0, "model"), (1, fa)])
        elif name == "router":
            c = cands([(1, "model")])
        elif name == "in_proj":                              # mamba [D, 2di]
            c = cands([(1, "model"), (0, fa)])
        elif name == "out_proj":                             # mamba [di, D]
            c = cands([(0, "model"), (1, fa)])
        elif name in ("x_proj",):                            # [di, R+2N]
            c = cands([(0, "model")])
        elif name in ("dt_proj",):                           # [R, di]
            c = cands([(1, "model")])
        elif name in ("A_log", "D_skip", "dt_bias"):
            c = cands([(0, "model")])
        elif name == "conv_w":                               # [w, di]
            c = cands([(1, "model")])
        elif name == "conv_b":
            c = cands([(0, "model")])
        elif parent == "mix" and name in ("wr", "wk", "wv", "wg"):  # rwkv [D,D]
            c = cands([(1, "model"), (0, fa)])
        elif parent == "mix" and name == "wo":
            c = cands([(0, "model"), (1, fa)])
        elif parent == "ffn" and name in ("wr",):
            c = cands([(1, "model")])
        elif name in ("wA",):
            c = cands([(0, fa)])
        elif name in ("wB",):
            c = cands([(1, "model")])
        elif name == "u":
            c = cands([(0, "model")])
        else:  # norms, biases, mu, small tensors: replicated
            c = []
        return _pick(mesh, shape, c)

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# batch / cache / activation specs


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                policy: ShardingPolicy = ShardingPolicy()):
    da = data_axes(mesh)
    B = shape.global_batch
    batch_ax = da if B % max(_axis_size(mesh, da), 1) == 0 and _axis_size(mesh, da) > 1 else None
    specs = {"tokens": P(batch_ax, None),
             "targets": P(batch_ax, None)}
    if cfg.frontend != "none":
        specs["frontend_embeds"] = P(batch_ax, None, None)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, cache,
                policy: ShardingPolicy = ShardingPolicy()):
    """Specs for the KV/state cache pytree (leaves may carry a leading [G]
    scan dim). Falls back to sequence-dim sharding when the batch does not
    divide the data axes (long_500k: batch=1, 524288-long cache)."""
    da = data_axes(mesh)
    dsize = _axis_size(mesh, da)
    B = shape.global_batch
    batch_ok = dsize > 1 and B % dsize == 0

    def spec_for(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shape_ = leaf.shape
        stacked = leaf.ndim >= 1 and cfg.scan_layers
        off = 1 if stacked else 0
        name = names[-1]
        if name in ("k", "v"):   # [G, B, L, Hkv, hd]
            c = [(0 + off, da if batch_ok else None)]
            if not batch_ok and policy.seq_shard_long:
                c.append((1 + off, da))
            c += [(2 + off, "model"), (3 + off, "model")]
            return _pick(mesh, shape_, c)
        if name == "h":          # mamba [G, B, di, N]
            return _pick(mesh, shape_, [(0 + off, da if batch_ok else None),
                                        (1 + off, "model")])
        if name == "conv":       # [G, B, w-1, di]
            return _pick(mesh, shape_, [(0 + off, da if batch_ok else None),
                                        (2 + off, "model")])
        if name == "s":          # rwkv [G, B, H, n, n]
            return _pick(mesh, shape_, [(0 + off, da if batch_ok else None),
                                        (1 + off, "model")])
        if name in ("x_tm", "x_cm"):  # [G, B, D]
            return _pick(mesh, shape_, [(0 + off, da if batch_ok else None)])
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree, opt_state, params):
    """Optimizer moments mirror their parameter's spec; scalars replicate."""
    flat_p, _ = jax.tree_util.tree_flatten(params)
    flat_s = jax.tree_util.tree_flatten(param_spec_tree,
                                        is_leaf=lambda x: isinstance(x, P))[0]
    by_shape = {}
    for p, s in zip(flat_p, flat_s):
        by_shape.setdefault((p.shape, str(p.dtype)), s)
    by_shape_any = {p.shape: s for p, s in zip(flat_p, flat_s)}

    def spec_for(leaf):
        if leaf.ndim == 0:
            return P()
        s = by_shape.get((leaf.shape, str(leaf.dtype)))
        if s is None:
            s = by_shape_any.get(leaf.shape, P())
        return s

    return jax.tree.map(spec_for, opt_state)
