from repro.distributed import collectives, elastic, sharding, straggler

__all__ = ["collectives", "elastic", "sharding", "straggler"]
