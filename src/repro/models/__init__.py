"""Model zoo. ``build_model(cfg)`` returns a uniform ``Model`` record used
by the runtime, the ETuner controller, and the dry-run launcher.

``build_model`` is memoized by config value: a ``Model`` is a frozen
record of pure closures over ``cfg``, so two calls with equal configs
are interchangeable. Sharing the instance means every downstream
program cache keyed by function identity (train steps, jitted
predict/features, serving vmaps — see runtime/train_loop.py) is shared
across sessions in one process, which is what keeps a benchmark sweep
from re-paying XLA compiles per cell."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                      # (rng) -> params
    loss: Callable                      # (params, batch, plan) -> (loss, metrics)
    features: Callable                  # (params, batch) -> list of activations
    num_freeze_units: int               # groups (scan) or layers (unrolled)
    prefill: Optional[Callable] = None  # (params, batch) -> (logits, cache)
    decode: Optional[Callable] = None   # (params, tokens, cache, pos) -> (logits, cache)
    init_cache: Optional[Callable] = None
    predict: Optional[Callable] = None  # classifiers: (params, batch) -> logits


_MODELS: Dict[ModelConfig, Model] = {}


def build_model(cfg: ModelConfig) -> Model:
    model = _MODELS.get(cfg)
    if model is None:
        model = _MODELS[cfg] = _build_model(cfg)
    return model


def _build_model(cfg: ModelConfig) -> Model:
    if cfg.is_lm:
        from repro.models import transformer as T

        return Model(
            cfg=cfg,
            init=lambda rng: T.init_lm(rng, cfg),
            loss=lambda params, batch, plan=None: T.lm_loss(params, cfg, batch, plan),
            features=lambda params, batch: T.lm_features(params, cfg, batch),
            num_freeze_units=T.num_groups(cfg),
            prefill=lambda params, batch: T.lm_prefill(params, cfg, batch),
            decode=lambda params, tokens, cache, pos: T.lm_decode(
                params, cfg, tokens, cache, pos),
            init_cache=lambda batch, max_len, dtype: T.init_lm_cache(
                cfg, batch, max_len, dtype),
        )
    if cfg.family == "cnn":
        from repro.models import cnn

        return cnn.build(cfg)
    if cfg.family == "vit":
        from repro.models import vit

        return vit.build(cfg)
    if cfg.family == "encoder":
        from repro.models import bert

        return bert.build(cfg)
    raise ValueError(cfg.family)
