"""Shared building blocks: initializers, norms, embeddings, RoPE / M-RoPE,
activation and softcap helpers. Pure-functional (params are pytrees of
jnp arrays); no framework dependency."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def normal_init(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    """Truncated-normal-ish init scaled by 1/sqrt(fan_in)."""
    return normal_init(key, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


def zeros(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    angles = angles[..., None, :]  # broadcast over heads -> [..., S, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, hd]; positions3: [3, B, S] (t, h, w position ids).
    `sections` gives the number of hd/2 frequency slots assigned to each of
    the three axes (sum(sections) == hd // 2).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta))  # [hd/2]
    # section id for each frequency slot
    sec_id = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    sec_id = jnp.asarray(sec_id)  # [hd/2]
    # pick position per slot from the matching axis
    pos = positions3.astype(jnp.float32)  # [3, B, S]
    pos_per_slot = jnp.take(pos, sec_id, axis=0)  # [hd/2, B, S]
    angles = jnp.moveaxis(pos_per_slot, 0, -1) * freqs  # [B, S, hd/2]
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def default_mrope_positions(batch: int, seq: int) -> jax.Array:
    """Text-only M-RoPE degenerates to the same position on all 3 axes."""
    p = jnp.broadcast_to(jnp.arange(seq)[None, :], (batch, seq))
    return jnp.stack([p, p, p], axis=0)


# ---------------------------------------------------------------------------
# embedding


def init_embedding(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"tok": normal_init(k1, (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, cfg.d_model, (cfg.d_model, cfg.vocab_size), dt)
    if cfg.frontend != "none":
        p["frontend_proj"] = dense_init(k3, cfg.frontend_dim,
                                        (cfg.frontend_dim, cfg.d_model), dt)
    return p


def embed_tokens(p: dict, cfg: ModelConfig, tokens: jax.Array,
                 frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.family in ("dense", "moe", "hybrid", "ssm", "vlm", "audio"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype) if cfg.tie_embeddings else x
    if frontend_embeds is not None and cfg.frontend != "none":
        # Modality stub: project precomputed patch/frame embeddings and
        # prepend them to the token sequence (prefix conditioning).
        pre = frontend_embeds.astype(x.dtype) @ p["frontend_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    return x


def lm_logits(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"])
    logits = logits.astype(jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Sharding-friendly CE: no take_along_axis gather over the (possibly
    model-axis-sharded) vocab dim — the target logit is picked with an
    elementwise iota comparison that XLA keeps fused and partial-sums."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == targets[..., None], shifted, 0.0),
                     axis=-1)
    nll = logz - picked
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
