"""DeiT-tiny — the paper's vision-transformer evaluation model (§V-A).
Unrolled pre-LN ViT; freeze units = patch-embed, each encoder block, head."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.freeze_plan import maybe_stop
from repro.models import common


def simple_mha(p, x, num_heads, causal=False, use_pallas=False):
    """Bidirectional MHA used by ViT/BERT. x: [B,S,D].

    `use_pallas` routes the attention core through the Pallas flash
    kernel (interpret mode on CPU; DESIGN.md §12). Forward-only: the
    kernel has no custom VJP, so loss paths always pass False. Both our
    sequence lengths (ViT S=65 reduced, BERT S<=512) sit within one
    kernel block, so the kernel's padding path never engages.
    """
    B, S, D = x.shape
    hd = D // num_heads
    q = (x @ p["wq"] + p["bq"]).reshape(B, S, num_heads, hd)
    k = (x @ p["wk"] + p["bk"]).reshape(B, S, num_heads, hd)
    v = (x @ p["wv"] + p["bv"]).reshape(B, S, num_heads, hd)
    if use_pallas:
        from repro.kernels.attention import ops as att_ops

        o = att_ops.flash_attention(q, k, v, causal=causal).reshape(B, S, D)
        return o @ p["wo"] + p["bo"]
    s = jnp.einsum("bqhk,bshk->bhqs", q, k) / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s.astype(jnp.float32), -1).astype(x.dtype)
    o = jnp.einsum("bhqs,bshk->bqhk", a, v).reshape(B, S, D)
    return o @ p["wo"] + p["bo"]


def init_mha(key, d):
    ks = jax.random.split(key, 4)
    z = jnp.zeros((d,), jnp.float32)
    return {"wq": common.dense_init(ks[0], d, (d, d), jnp.float32), "bq": z,
            "wk": common.dense_init(ks[1], d, (d, d), jnp.float32), "bk": z,
            "wv": common.dense_init(ks[2], d, (d, d), jnp.float32), "bv": z,
            "wo": common.dense_init(ks[3], d, (d, d), jnp.float32), "bo": z}


def init_ffn(key, d, ff):
    k1, k2 = jax.random.split(key)
    return {"w1": common.dense_init(k1, d, (d, ff), jnp.float32),
            "b1": jnp.zeros((ff,), jnp.float32),
            "w2": common.dense_init(k2, ff, (ff, d), jnp.float32),
            "b2": jnp.zeros((d,), jnp.float32)}


def _ln_p(d):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def _ln(x, p, eps=1e-6):
    return common.layer_norm(x, p["scale"], p["bias"], eps)


def patch_size(cfg: ModelConfig) -> int:
    return 4 if "reduced" in cfg.name else 16


def init_vit(rng, cfg: ModelConfig):
    d = cfg.d_model
    patch = patch_size(cfg)
    n_patch = (cfg.image_size // patch) ** 2
    keys = iter(jax.random.split(rng, 8 + 2 * cfg.num_layers))
    params = {
        "patch": {"w": common.dense_init(next(keys), patch * patch * 3,
                                         (patch, patch, 3, d), jnp.float32),
                  "b": jnp.zeros((d,), jnp.float32)},
        "cls": common.normal_init(next(keys), (1, 1, d), 0.02, jnp.float32),
        "pos": common.normal_init(next(keys), (1, n_patch + 1, d), 0.02, jnp.float32),
        "blocks": [],
        "final_ln": _ln_p(d),
        "head": {"w": common.dense_init(next(keys), d, (d, cfg.num_classes), jnp.float32),
                 "b": jnp.zeros((cfg.num_classes,), jnp.float32)},
    }
    for _ in range(cfg.num_layers):
        params["blocks"].append({
            "ln1": _ln_p(d), "attn": init_mha(next(keys), d),
            "ln2": _ln_p(d), "ffn": init_ffn(next(keys), d, cfg.d_ff)})
    return params


def _forward(params, cfg: ModelConfig, images, plan, collect=False,
             use_pallas=False):
    patch = patch_size(cfg)
    x = jax.lax.conv_general_dilated(
        images, params["patch"]["w"], (patch, patch), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["patch"]["b"]
    B = x.shape[0]
    x = x.reshape(B, -1, cfg.d_model)
    x = jnp.concatenate([jnp.broadcast_to(params["cls"], (B, 1, cfg.d_model)), x], 1)
    x = x + params["pos"]
    flags = plan.layers if plan is not None else (False,) * (len(params["blocks"]) + 2)
    # unit 0 = patch embed (+cls/pos); units 1..L = blocks; unit L+1 = head
    feats = []
    prefix_frozen = True
    if flags[0]:
        x = jax.lax.stop_gradient(x)
    else:
        prefix_frozen = False
    if collect:
        feats.append(x)
    for bi, blk in enumerate(params["blocks"]):
        frozen = flags[1 + bi]
        blk = maybe_stop(blk, frozen)
        x = x + simple_mha(blk["attn"], _ln(x, blk["ln1"]), cfg.num_heads,
                           use_pallas=use_pallas)
        h = _ln(x, blk["ln2"])
        h = jax.nn.gelu(h @ blk["ffn"]["w1"] + blk["ffn"]["b1"])
        x = x + (h @ blk["ffn"]["w2"] + blk["ffn"]["b2"])
        if frozen and prefix_frozen:
            x = jax.lax.stop_gradient(x)
        else:
            prefix_frozen = False
        if collect:
            feats.append(x)
    x = _ln(x, params["final_ln"])
    head = maybe_stop(params["head"], flags[-1])
    logits = x[:, 0] @ head["w"] + head["b"]
    return logits, feats


def build(cfg: ModelConfig):
    from repro.models import Model

    def loss(params, batch, plan=None):
        logits, _ = _forward(params, cfg, batch["images"], plan)
        l = common.cross_entropy(logits, batch["labels"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return l, {"loss": l, "acc": acc, "logits": logits}

    def predict(params, batch):
        return _forward(params, cfg, batch["images"], None,
                        use_pallas=cfg.use_pallas)[0]

    def features(params, batch):
        return _forward(params, cfg, batch["images"], None, collect=True,
                        use_pallas=cfg.use_pallas)[1]

    return Model(cfg=cfg, init=lambda rng: init_vit(rng, cfg), loss=loss,
                 features=features, num_freeze_units=cfg.num_layers + 2,
                 predict=predict)
