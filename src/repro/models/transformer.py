"""Decoder-LM assembler for all 10 assigned architectures.

Layers are organized into *groups* of size g = the architecture's block
period (1 for uniform stacks, 2 for gemma2 local/global, 8 for jamba's
mamba:attn 7:1 interleave). Group parameters are stacked `[G, ...]` and
executed with `lax.scan` (HLO size independent of depth — required to
compile 80-layer × 512-device dry-runs), or unrolled for reduced/test
configs (`cfg.scan_layers=False`).

SimFreeze integration (DESIGN.md §2): a `FreezePlan` partitions the groups
into contiguous *segments*; each frozen segment's stacked params enter the
graph behind `lax.stop_gradient`, so XLA never emits their weight-gradient
einsums — the scan-mode equivalent of the paper's Fig. 2 case 2. If the
embedding and the leading segments are all frozen, the activation gradient
is stopped as well (case 3).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.freeze_plan import FreezePlan, lm_segments
from repro.distributed import sharding as shd
from repro.models import attention, common, mamba, mlp, moe, rwkv6

Params = Any


def group_size(cfg: ModelConfig) -> int:
    g = 1
    if cfg.attn_period:
        g = cfg.attn_period
    if cfg.local_global_period:
        g = max(g, cfg.local_global_period)
    if cfg.num_experts and cfg.moe_period > 1:
        import math
        g = math.lcm(g, cfg.moe_period)
    assert cfg.num_layers % g == 0, (cfg.name, cfg.num_layers, g)
    return g


def num_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // group_size(cfg)


# ---------------------------------------------------------------------------
# per-layer (offset-within-group) blocks


def _init_block(key, cfg: ModelConfig, offset: int) -> dict:
    kind = cfg.layer_kind(offset)
    is_moe = cfg.layer_is_moe(offset)
    k1, k2 = jax.random.split(key)
    p: dict = {"ln1": common.zeros((cfg.d_model,), jnp.float32),
               "ln2": common.zeros((cfg.d_model,), jnp.float32)}
    if cfg.post_norms:
        p["ln1_post"] = common.zeros((cfg.d_model,), jnp.float32)
        p["ln2_post"] = common.zeros((cfg.d_model,), jnp.float32)
    if kind == "attn":
        p["mix"] = attention.init_attention(k1, cfg)
    elif kind == "mamba":
        p["mix"] = mamba.init_mamba(k1, cfg)
    elif kind == "rwkv":
        p["mix"] = rwkv6.init_rwkv_time_mix(k1, cfg)
    if kind == "rwkv":
        p["ffn"] = rwkv6.init_rwkv_channel_mix(k2, cfg)
    elif is_moe:
        p["ffn"] = moe.init_moe(k2, cfg)
    else:
        p["ffn"] = mlp.init_mlp(k2, cfg)
    return p


def _apply_block(p: dict, cfg: ModelConfig, x: jax.Array, offset: int,
                 positions, mode: str, cache: Optional[dict],
                 pos) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, cache_out, moe_aux)."""
    kind = cfg.layer_kind(offset)
    window = cfg.layer_window(offset)
    aux = jnp.zeros((), jnp.float32)
    x = shd.hint(x, shd.BATCH_AXES, None, None)
    h = common.rms_norm(x, p["ln1"], cfg.norm_eps)
    cache_out = {}
    if kind == "attn":
        if mode == "train":
            a = attention.attention_train(p["mix"], cfg, h, positions, window)
            c = None
        elif mode == "prefill":
            a, c = attention.attention_prefill(p["mix"], cfg, h, positions, window)
        else:
            a, c = attention.attention_decode(p["mix"], cfg, h, cache["attn"], pos, window)
        if c is not None:
            cache_out["attn"] = c
    elif kind == "mamba":
        if mode == "decode":
            a, c = mamba.mamba_decode(p["mix"], cfg, h, cache["mamba"])
        else:
            a, c = mamba.mamba_train(p["mix"], cfg, h,
                                     return_state=(mode == "prefill"))
        if c is not None:
            cache_out["mamba"] = c
    else:  # rwkv
        if mode == "decode":
            a, c = rwkv6.time_mix_decode(p["mix"], cfg, h, cache["rwkv"])
        else:
            a, c = rwkv6.time_mix_train(p["mix"], cfg, h,
                                        return_state=(mode == "prefill"))
        if c is not None:
            cache_out["rwkv"] = c
    if cfg.post_norms:
        a = common.rms_norm(a, p["ln1_post"], cfg.norm_eps)
    x = x + a

    h = common.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        if mode == "decode":
            f, c = rwkv6.channel_mix_decode(p["ffn"], cfg, h, cache_out.get(
                "rwkv", cache["rwkv"] if cache else None))
            cache_out["rwkv"] = c
        else:
            f, c = rwkv6.channel_mix_train(p["ffn"], cfg, h,
                                           state=cache_out.get("rwkv"),
                                           return_state=(mode == "prefill"))
            if c is not None:
                cache_out["rwkv"] = c
    elif cfg.layer_is_moe(offset):
        f, aux = moe.moe_ffn(p["ffn"], cfg, h)
    else:
        f = mlp.mlp(p["ffn"], cfg, h)
    if cfg.post_norms:
        f = common.rms_norm(f, p["ln2_post"], cfg.norm_eps)
    x = x + f
    return x, (cache_out or None), aux


# ---------------------------------------------------------------------------
# init


def init_lm(rng, cfg: ModelConfig) -> Params:
    g, G = group_size(cfg), num_groups(cfg)
    k_emb, k_blocks = jax.random.split(rng)
    params: Dict[str, Any] = {"embed": common.init_embedding(k_emb, cfg),
                              "final_norm": common.zeros((cfg.d_model,), jnp.float32)}
    blocks = []
    for o in range(g):
        ko = jax.random.fold_in(k_blocks, o)
        if cfg.scan_layers:
            keys = jax.random.split(ko, G)
            blocks.append(jax.vmap(lambda k, o=o: _init_block(k, cfg, o))(keys))
        else:
            blocks.append([_init_block(jax.random.fold_in(ko, gi), cfg, o)
                           for gi in range(G)])
    params["blocks"] = tuple(blocks)
    return params


# ---------------------------------------------------------------------------
# forward


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _slice_groups(blocks, lo: int, hi: int, scan: bool):
    if scan:
        return jax.tree.map(lambda a: a[lo:hi], blocks)
    return tuple(b[lo:hi] for b in blocks)


def _run_groups(blocks, cfg: ModelConfig, x, positions, mode, caches, pos,
                collect_feats: bool = False):
    """Run all groups (no freezing). Returns (x, caches_out, aux, feats)."""
    g = group_size(cfg)

    def group_body(x, block_slice, cache_slice):
        aux = jnp.zeros((), jnp.float32)
        cache_out = []
        for o in range(g):
            c = cache_slice[o] if cache_slice is not None else None
            x, co, a = _apply_block(block_slice[o], cfg, x, o, positions,
                                    mode, c, pos)
            aux = aux + a
            cache_out.append(co)
        return x, tuple(cache_out), aux

    if cfg.scan_layers:
        body = _remat(lambda x, bs_cs: group_body(x, bs_cs[0], bs_cs[1]), cfg)

        def scan_body(carry, xs):
            x, aux = carry
            xn, cache_out, a = body(x, xs)
            ys = (cache_out, xn if collect_feats else jnp.zeros((), jnp.float32))
            return (xn, aux + a), ys

        if caches is None:
            G = jax.tree.leaves(blocks)[0].shape[0]
            caches = _none_caches(G, g)
        (x, aux), (cache_ys, feat_ys) = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), (blocks, caches),
            unroll=True if cfg.scan_unroll else 1)
        feats = feat_ys if collect_feats else None
        return x, cache_ys, aux, feats
    else:
        G = len(blocks[0])
        aux = jnp.zeros((), jnp.float32)
        cache_out: List = []
        feats = []
        for gi in range(G):
            bs = tuple(blocks[o][gi] for o in range(g))
            cs = caches[gi] if caches is not None else None
            x, co, a = group_body(x, bs, cs)
            aux = aux + a
            cache_out.append(co)
            if collect_feats:
                feats.append(x)
        return x, cache_out, aux, feats


def _none_caches(G: int, g: int):
    # scan requires xs with a leading G axis; use empty placeholder.
    return tuple(jnp.zeros((G, 0)) for _ in range(g))


def _run_with_plan(params, cfg: ModelConfig, x, positions,
                   plan: Optional[FreezePlan]):
    """Training-mode execution honoring FreezePlan segments."""
    blocks = params["blocks"]
    aux_total = jnp.zeros((), jnp.float32)
    if plan is None or not plan.groups or not any(plan.groups):
        x, _, aux_total, _ = _run_groups(blocks, cfg, x, positions, "train",
                                         None, None)
        return x, aux_total
    prefix_stops_grad = plan.embed
    for lo, hi, frozen in lm_segments(plan):
        seg = _slice_groups(blocks, lo, hi, cfg.scan_layers)
        if frozen:
            seg = jax.lax.stop_gradient(seg)
        x, _, aux, _ = _run_groups(seg, cfg, x, positions, "train", None, None)
        aux_total = aux_total + aux
        if frozen and prefix_stops_grad:
            # paper Fig.2 case 3: no trainable layer below -> stop activation grads
            x = jax.lax.stop_gradient(x)
        else:
            prefix_stops_grad = False
    return x, aux_total


def _embed(params, cfg: ModelConfig, batch: dict, frozen_embed: bool):
    emb = params["embed"]
    if frozen_embed:
        emb = jax.lax.stop_gradient(emb)
    x = common.embed_tokens(emb, cfg, batch["tokens"],
                            batch.get("frontend_embeds"))
    x = shd.hint(x, shd.BATCH_AXES, None, None)
    return x, emb


def lm_loss(params, cfg: ModelConfig, batch: dict,
            plan: Optional[FreezePlan] = None) -> Tuple[jax.Array, dict]:
    """batch: tokens [B,S], targets [B,S], optional frontend_embeds
    [B,F,frontend_dim], optional mask [B,S]."""
    x, emb = _embed(params, cfg, batch, plan.embed if plan else False)
    B, St = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(St), (B, St))
    x, aux = _run_with_plan(params, cfg, x, positions, plan)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    F = St - batch["tokens"].shape[1]
    if F > 0:
        x = x[:, F:]
    head = emb if cfg.tie_embeddings else params["embed"]
    if plan is not None and plan.head:
        head = jax.lax.stop_gradient(head)
    logits = common.lm_logits(head, cfg, x)
    logits = shd.hint(logits, shd.BATCH_AXES, None, "model")
    loss = common.cross_entropy(logits, batch["targets"], batch.get("mask"))
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux_loss": aux, "logits_mean": logits.mean()}


def lm_features(params, cfg: ModelConfig, batch: dict) -> List[jax.Array]:
    """Per-group hidden states for CKA probes. Returns list of [B,S,D]."""
    x, _ = _embed(params, cfg, batch, False)
    B, St = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(St), (B, St))
    _, _, _, feats = _run_groups(params["blocks"], cfg, x, positions, "train",
                                 None, None, collect_feats=True)
    if cfg.scan_layers:
        G = feats.shape[0]
        return [feats[i] for i in range(G)]
    return feats


# ---------------------------------------------------------------------------
# serving


def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Tuple:
    g, G = group_size(cfg), num_groups(cfg)
    caches = []
    for o in range(g):
        kind = cfg.layer_kind(o)
        if kind == "attn":
            c = {"attn": attention.init_cache(cfg, batch, max_len, dtype)}
        elif kind == "mamba":
            c = {"mamba": mamba.init_mamba_state(cfg, batch)}
        else:
            c = {"rwkv": rwkv6.init_rwkv_state(cfg, batch)}
        if cfg.scan_layers:
            c = jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), c)
        else:
            c = [c for _ in range(G)]
        caches.append(c)
    if cfg.scan_layers:
        return tuple(caches)
    # unrolled: reorganize to per-group list of per-offset tuples
    G_list = []
    for gi in range(G):
        G_list.append(tuple(caches[o][gi] for o in range(g)))
    return G_list


def lm_prefill(params, cfg: ModelConfig, batch: dict) -> Tuple[jax.Array, Any]:
    """Returns (last-position logits [B,V], cache)."""
    x, emb = _embed(params, cfg, batch, False)
    B, St = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(St), (B, St))
    x, caches, _, _ = _run_groups(params["blocks"], cfg, x, positions,
                                  "prefill", None, None)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = emb if cfg.tie_embeddings else params["embed"]
    logits = common.lm_logits(head, cfg, x[:, -1:])
    return logits[:, 0], caches


def lm_decode(params, cfg: ModelConfig, tokens: jax.Array, caches,
              pos) -> Tuple[jax.Array, Any]:
    """tokens: [B,1]; pos: scalar int32. Returns (logits [B,V], caches)."""
    x = common.embed_tokens(params["embed"], cfg, tokens)
    x, caches_out, _, _ = _run_groups(params["blocks"], cfg, x, None,
                                      "decode", caches, pos)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"]
    logits = common.lm_logits(head, cfg, x)
    return logits[:, 0], caches_out
