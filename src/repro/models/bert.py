"""BERT-base encoder classifier — the paper's NLP evaluation model (§V-B2,
20News benchmark). Unrolled post-LN encoder; freeze units = embeddings,
each encoder block, classifier head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.freeze_plan import maybe_stop
from repro.models import common
from repro.models.vit import _ln, _ln_p, init_ffn, init_mha, simple_mha

MAX_POS = 512


def init_bert(rng, cfg: ModelConfig):
    d = cfg.d_model
    keys = iter(jax.random.split(rng, 8 + 2 * cfg.num_layers))
    params = {
        "embed": {
            "tok": common.normal_init(next(keys), (cfg.vocab_size, d), 0.02, jnp.float32),
            "pos": common.normal_init(next(keys), (MAX_POS, d), 0.02, jnp.float32),
            "ln": _ln_p(d)},
        "blocks": [],
        "pooler": {"w": common.dense_init(next(keys), d, (d, d), jnp.float32),
                   "b": jnp.zeros((d,), jnp.float32)},
        "head": {"w": common.dense_init(next(keys), d, (d, cfg.num_classes), jnp.float32),
                 "b": jnp.zeros((cfg.num_classes,), jnp.float32)},
    }
    for _ in range(cfg.num_layers):
        params["blocks"].append({
            "attn": init_mha(next(keys), d), "ln1": _ln_p(d),
            "ffn": init_ffn(next(keys), d, cfg.d_ff), "ln2": _ln_p(d)})
    return params


def _forward(params, cfg: ModelConfig, tokens, plan, collect=False,
             use_pallas=False):
    B, S = tokens.shape
    flags = plan.layers if plan is not None else (False,) * (len(params["blocks"]) + 2)
    emb = maybe_stop(params["embed"], flags[0])
    x = jnp.take(emb["tok"], tokens, axis=0) + emb["pos"][:S]
    x = _ln(x, emb["ln"])
    prefix_frozen = flags[0]
    if prefix_frozen:
        x = jax.lax.stop_gradient(x)
    feats = [x] if collect else []
    for bi, blk in enumerate(params["blocks"]):
        frozen = flags[1 + bi]
        blk = maybe_stop(blk, frozen)
        x = _ln(x + simple_mha(blk["attn"], x, cfg.num_heads,
                               use_pallas=use_pallas), blk["ln1"])
        h = jax.nn.gelu(x @ blk["ffn"]["w1"] + blk["ffn"]["b1"])
        x = _ln(x + (h @ blk["ffn"]["w2"] + blk["ffn"]["b2"]), blk["ln2"])
        if frozen and prefix_frozen:
            x = jax.lax.stop_gradient(x)
        else:
            prefix_frozen = False
        if collect:
            feats.append(x)
    pooled = jnp.tanh(x[:, 0] @ params["pooler"]["w"] + params["pooler"]["b"])
    head = maybe_stop(params["head"], flags[-1])
    logits = pooled @ head["w"] + head["b"]
    return logits, feats


def build(cfg: ModelConfig):
    from repro.models import Model

    def loss(params, batch, plan=None):
        logits, _ = _forward(params, cfg, batch["tokens"], plan)
        l = common.cross_entropy(logits, batch["labels"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return l, {"loss": l, "acc": acc, "logits": logits}

    def predict(params, batch):
        return _forward(params, cfg, batch["tokens"], None,
                        use_pallas=cfg.use_pallas)[0]

    def features(params, batch):
        return _forward(params, cfg, batch["tokens"], None, collect=True,
                        use_pallas=cfg.use_pallas)[1]

    return Model(cfg=cfg, init=lambda rng: init_bert(rng, cfg), loss=loss,
                 features=features, num_freeze_units=cfg.num_layers + 2,
                 predict=predict)
