"""ResNet50 and MobileNetV2 — the paper's CV evaluation models (§V-A).

Unrolled execution: every weight-bearing unit (stem / residual block / head)
is a separate pytree subtree and a separate freeze unit, so SimFreeze's
arbitrary per-layer freezing behaves exactly as in the paper (Fig. 2):
`stop_gradient` on a frozen unit's params removes its weight-gradient
computation via XLA DCE, and a frozen prefix stops activation gradients.

Normalization uses batch statistics (functional BN without running stats) —
a deliberate simplification recorded in DESIGN.md; the CL benchmarks
evaluate with batch statistics as well.
"""
from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.freeze_plan import LayerFreezePlan, maybe_stop
from repro.models import common


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return common.normal_init(key, (kh, kw, cin, cout),
                              math.sqrt(2.0 / fan_in), jnp.float32)


def conv2d(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def bn(x, scale, bias, eps=1e-5):
    mean = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def _bn_params(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# ResNet (bottleneck)


def _resnet_spec(cfg: ModelConfig):
    if "reduced" in cfg.name:
        return [1, 1, 1, 1], 32
    return [3, 4, 6, 3], 64


def resnet_static_spec(cfg: ModelConfig):
    """Static per-unit structure (kept out of the params pytree)."""
    blocks_per_stage, base = _resnet_spec(cfg)
    spec = [{"kind": "stem"}]
    cin = base
    for si, nblocks in enumerate(blocks_per_stage):
        width = base * (2 ** si)
        cout = width * 4
        for bi in range(nblocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            spec.append({"kind": "bottleneck", "stride": stride, "cin": cin,
                         "width": width, "cout": cout,
                         "proj": cin != cout or stride != 1})
            cin = cout
    spec.append({"kind": "head", "cin": cin})
    return spec


def init_resnet(rng, cfg: ModelConfig):
    _, base = _resnet_spec(cfg)
    spec = resnet_static_spec(cfg)
    keys = iter(jax.random.split(rng, 256))
    units: List[dict] = []
    for sp in spec[:-1]:
        if sp["kind"] == "stem":
            units.append({"conv": _conv_init(next(keys), 7, 7, 3, base),
                          "bn": _bn_params(base)})
            continue
        cin, width, cout = sp["cin"], sp["width"], sp["cout"]
        u = {"c1": _conv_init(next(keys), 1, 1, cin, width), "b1": _bn_params(width),
             "c2": _conv_init(next(keys), 3, 3, width, width), "b2": _bn_params(width),
             "c3": _conv_init(next(keys), 1, 1, width, cout), "b3": _bn_params(cout)}
        if sp["proj"]:
            u["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
            u["proj_bn"] = _bn_params(cout)
        units.append(u)
    cin = spec[-1]["cin"]
    head = {"w": common.dense_init(next(keys), cin, (cin, cfg.num_classes), jnp.float32),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
    return {"units": units, "head": head}


def _apply_resnet_unit(sp: dict, u: dict, x):
    if sp["kind"] == "stem":
        x = jax.nn.relu(bn(conv2d(x, u["conv"], 2), **u["bn"]))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
        return x
    h = jax.nn.relu(bn(conv2d(x, u["c1"]), **u["b1"]))
    h = jax.nn.relu(bn(conv2d(h, u["c2"], sp["stride"]), **u["b2"]))
    h = bn(conv2d(h, u["c3"]), **u["b3"])
    sc = x
    if "proj" in u:
        sc = bn(conv2d(x, u["proj"], sp["stride"]), **u["proj_bn"])
    return jax.nn.relu(h + sc)


# ---------------------------------------------------------------------------
# MobileNetV2 (inverted residuals)

_MBV2_SPEC = [  # (expansion, out_c, num_blocks, stride)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
_MBV2_SPEC_REDUCED = [(1, 16, 1, 1), (6, 24, 1, 2), (6, 32, 1, 2), (6, 64, 1, 2)]


def mbv2_static_spec(cfg: ModelConfig):
    table = _MBV2_SPEC_REDUCED if "reduced" in cfg.name else _MBV2_SPEC
    wm = cfg.width_mult

    def c(ch):
        return max(8, int(ch * wm + 4) // 8 * 8)

    spec = [{"kind": "stem", "cout": c(32)}]
    cin = c(32)
    for t, ch, n, s in table:
        cout = c(ch)
        for bi in range(n):
            stride = s if bi == 0 else 1
            spec.append({"kind": "invres", "stride": stride, "expand": t,
                         "cin": cin, "hid": cin * t, "cout": cout})
            cin = cout
    spec.append({"kind": "last", "cin": cin, "cout": c(1280)})
    return spec


def init_mbv2(rng, cfg: ModelConfig):
    spec = mbv2_static_spec(cfg)
    keys = iter(jax.random.split(rng, 256))
    units: List[dict] = []
    for sp in spec:
        if sp["kind"] == "stem":
            units.append({"conv": _conv_init(next(keys), 3, 3, 3, sp["cout"]),
                          "bn": _bn_params(sp["cout"])})
        elif sp["kind"] == "last":
            units.append({"conv": _conv_init(next(keys), 1, 1, sp["cin"], sp["cout"]),
                          "bn": _bn_params(sp["cout"])})
        else:
            hid, cout, cin = sp["hid"], sp["cout"], sp["cin"]
            u = {"dw": _conv_init(next(keys), 3, 3, 1, hid),
                 "dw_bn": _bn_params(hid),
                 "pw": _conv_init(next(keys), 1, 1, hid, cout), "pw_bn": _bn_params(cout)}
            if sp["expand"] != 1:
                u["exp"] = _conv_init(next(keys), 1, 1, cin, hid)
                u["exp_bn"] = _bn_params(hid)
            units.append(u)
    clast = spec[-1]["cout"]
    head = {"w": common.dense_init(next(keys), clast, (clast, cfg.num_classes), jnp.float32),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
    return {"units": units, "head": head}


def _apply_mbv2_unit(sp: dict, u: dict, x):
    if sp["kind"] in ("stem", "last"):
        s = 2 if sp["kind"] == "stem" else 1
        return jax.nn.relu6(bn(conv2d(x, u["conv"], s), **u["bn"]))
    h = x
    if "exp" in u:
        h = jax.nn.relu6(bn(conv2d(h, u["exp"]), **u["exp_bn"]))
    hid = h.shape[-1]
    h = jax.nn.relu6(bn(conv2d(h, u["dw"], sp["stride"], groups=hid), **u["dw_bn"]))
    h = bn(conv2d(h, u["pw"]), **u["pw_bn"])
    if sp["stride"] == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


# ---------------------------------------------------------------------------
# shared classifier scaffolding


def _forward(params, cfg: ModelConfig, images, plan: LayerFreezePlan,
             spec, apply_unit, collect=False):
    units = params["units"]
    nunits = len(units) + 1  # + head
    flags = plan.layers if plan is not None else (False,) * nunits
    prefix_frozen = True
    feats = []
    x = images
    for sp, u, frozen in zip(spec, units, flags):
        u = maybe_stop(u, frozen)
        x = apply_unit(sp, u, x)
        if frozen and prefix_frozen:
            x = jax.lax.stop_gradient(x)  # paper Fig.2 case 3
        else:
            prefix_frozen = False
        if collect:
            feats.append(x)
    x = x.mean(axis=(1, 2))
    head = maybe_stop(params["head"], flags[-1])
    logits = x @ head["w"] + head["b"]
    return logits, feats


def build(cfg: ModelConfig):
    from repro.models import Model

    is_resnet = cfg.name.startswith("resnet")
    init_fn = init_resnet if is_resnet else init_mbv2
    unit_fn = _apply_resnet_unit if is_resnet else _apply_mbv2_unit
    spec = (resnet_static_spec(cfg) if is_resnet else mbv2_static_spec(cfg))
    if is_resnet:
        spec = spec[:-1]  # drop head entry; head handled separately
    n_units = len(spec) + 1

    def loss(params, batch, plan=None):
        logits, _ = _forward(params, cfg, batch["images"], plan, spec, unit_fn)
        l = common.cross_entropy(logits, batch["labels"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return l, {"loss": l, "acc": acc, "logits": logits}

    def predict(params, batch):
        logits, _ = _forward(params, cfg, batch["images"], None, spec, unit_fn)
        return logits

    def features(params, batch):
        _, feats = _forward(params, cfg, batch["images"], None, spec, unit_fn,
                            collect=True)
        return feats

    return Model(cfg=cfg, init=lambda rng: init_fn(rng, cfg), loss=loss,
                 features=features, num_freeze_units=n_units, predict=predict)
