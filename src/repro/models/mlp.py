"""Gated (SwiGLU/GeGLU) feed-forward block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import common


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> dict:
    dt = common.dtype_of(cfg)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": common.dense_init(kg, d, (d, ff), dt),
        "wu": common.dense_init(ku, d, (d, ff), dt),
        "wd": common.dense_init(kd, ff, (ff, d), dt),
    }


def mlp(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    g = common.activation(jnp.einsum("bsd,df->bsf", x, p["wg"]), cfg.act)
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    h = shd.hint(g * u, shd.BATCH_AXES, None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])
