"""Mixture-of-Experts layer: top-k routing with capacity-based gather
dispatch (expert-parallel friendly).

Dispatch strategy (TPU adaptation, see DESIGN.md): rather than a dense
[tokens, experts, capacity] one-hot einsum (MaxText-classic, O(T*E*C)
memory) we build a [E, T] gate matrix and let every expert `top_k` its C
highest-gated tokens — deterministic shapes, no sort, and the expert
buffers shard cleanly as [E(model), C(data), D]. Tokens over capacity are
dropped (standard capacity-factor semantics); the router aux loss keeps
load balanced so drops are rare.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import common


def init_moe(key, cfg: ModelConfig) -> dict:
    dt = common.dtype_of(cfg)
    d, ff, E = cfg.d_model, cfg.expert_ff, cfg.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": common.dense_init(kr, d, (d, E), jnp.float32),
        "wg": common.dense_init(kg, d, (E, d, ff), dt),
        "wu": common.dense_init(ku, d, (E, d, ff), dt),
        "wd": common.dense_init(kd, ff, (E, ff, d), dt),
    }


def moe_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    cap = int(cfg.capacity_factor * num_tokens * cfg.experts_per_token
              / cfg.num_experts)
    return min(num_tokens, max(8, cap))


def _dispatch_shards(cfg: ModelConfig, batch: int) -> int:
    """Local-dispatch granularity: the data-parallel shard count, so every
    expert selects its capacity *per data shard* and the token gather never
    crosses the data axis (EXPERIMENTS.md §Perf, MoE iteration)."""
    if not cfg.moe_local_dispatch:
        return 1
    mesh = shd._current_mesh()
    if mesh is None:
        return 1
    n = shd._axis_size(mesh, shd.data_axes(mesh))
    return n if n > 1 and batch % n == 0 else 1


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    ns = _dispatch_shards(cfg, B)
    if ns > 1:
        # group-local routing: shard-major groups match the batch sharding,
        # so the token gather/scatter never crosses the data axis
        C_total = moe_capacity(cfg, B * S)
        out, aux = _moe_dispatch(p, cfg, x, groups=ns,
                                 capacity=max(8, C_total // ns))
        return out, aux
    return _moe_dispatch(p, cfg, x, groups=1,
                         capacity=moe_capacity(cfg, B * S))


def _moe_dispatch(p: dict, cfg: ModelConfig, x: jax.Array, *, groups: int,
                  capacity: int) -> Tuple[jax.Array, jax.Array]:
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    logits = shd.hint(logits, shd.BATCH_AXES, None)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gates, eidx = jax.lax.top_k(probs, K)    # [T, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Scatter top-k gates into a dense [T, E] gate matrix.
    gate_te = jnp.zeros((T, E), jnp.float32)
    gate_te = gate_te.at[jnp.arange(T)[:, None], eidx].set(gates)

    if groups > 1:
        # group-local routing: experts pick C tokens within each group
        Tl = T // groups
        g_te = gate_te.reshape(groups, Tl, E)
        g_te = shd.hint(g_te, shd.BATCH_AXES, None, None)
        gval, loc_idx = jax.lax.top_k(jnp.swapaxes(g_te, 1, 2), C)  # [G,E,C]
        tok_idx = loc_idx + (jnp.arange(groups) * Tl)[:, None, None]
        tok_idx = tok_idx.reshape(groups, E * C)
        gval = gval.reshape(groups, E, C)
        keep = (gval > 0.0).astype(jnp.float32)
        xe = jnp.take(xt.reshape(groups, Tl, D),
                      loc_idx.reshape(groups, E * C), axis=1,
                      batch_dims=1 if False else None) if False else             jnp.take_along_axis(
                xt.reshape(groups, Tl, 1, D),
                loc_idx.reshape(groups, E * C, 1, 1).clip(0, Tl - 1), axis=1
            )[:, :, 0].reshape(groups, E, C, D)
        xe = jnp.swapaxes(xe, 0, 1)                     # [E, G, C, D]
        xe = shd.hint(xe, "model", shd.BATCH_AXES, None, None)
        g = common.activation(jnp.einsum("egcd,edf->egcf", xe, p["wg"]), cfg.act)
        u = jnp.einsum("egcd,edf->egcf", xe, p["wu"])
        ye = jnp.einsum("egcf,efd->egcd", g * u, p["wd"])
        ye = ye * jnp.swapaxes(gval * keep, 0, 1)[..., None].astype(ye.dtype)
        ye = shd.hint(ye, "model", shd.BATCH_AXES, None, None)
        out = jnp.zeros((T, D), ye.dtype).at[tok_idx.reshape(-1)].add(
            jnp.swapaxes(ye, 0, 1).reshape(groups * E * C, D))
    else:
        # Every expert picks its C strongest tokens.
        gval, tok_idx = jax.lax.top_k(gate_te.T, C)    # [E, C]
        keep = (gval > 0.0).astype(jnp.float32)        # [E, C]

        xe = jnp.take(xt, tok_idx, axis=0)             # [E, C, D]
        xe = shd.hint(xe, "model", shd.BATCH_AXES, None)  # expert-parallel buffers
        g = common.activation(jnp.einsum("ecd,edf->ecf", xe, p["wg"]), cfg.act)
        u = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
        ye = jnp.einsum("ecf,efd->ecd", g * u, p["wd"])  # [E, C, D]
        ye = ye * (gval * keep)[..., None].astype(ye.dtype)
        ye = shd.hint(ye, "model", shd.BATCH_AXES, None)

        out = jnp.zeros((T, D), ye.dtype).at[tok_idx.reshape(-1)].add(
            ye.reshape(E * C, D))

    # Load-balancing aux loss (Switch-style): E * sum(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=0)                              # [E]
    one_hot_top1 = jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return out.reshape(B, S, D), aux
