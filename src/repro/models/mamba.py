"""Mamba-1 selective SSM block (for jamba's hybrid stack).

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel is replaced
by a *chunked associative scan* — `lax.scan` over sequence chunks with a
`lax.associative_scan` inside each chunk, so the [B, Lc, d_inner, N]
state-expansion temporary is bounded by the chunk length and rematerialized
in the backward pass. The recurrent decode path is an exact single-step
update (O(1) state in sequence length, which is what makes jamba's
`long_500k` cell runnable)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import common


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba_expand * cfg.d_model


def init_mamba(key, cfg: ModelConfig) -> dict:
    dt = common.dtype_of(cfg)
    d, di, N, R = cfg.d_model, d_inner(cfg), cfg.mamba_state, dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": common.dense_init(ks[0], d, (d, 2 * di), dt),
        "conv_w": common.normal_init(ks[1], (cfg.mamba_conv, di), 0.1, dt),
        "conv_b": common.zeros((di,), dt),
        "x_proj": common.dense_init(ks[2], di, (di, R + 2 * N), dt),
        "dt_proj": common.normal_init(ks[3], (R, di), R ** -0.5, jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(a),
        "D_skip": common.ones((di,), jnp.float32),
        "out_proj": common.dense_init(ks[4], di, (di, d), dt),
    }


def _causal_conv(p: dict, x: jax.Array, width: int) -> jax.Array:
    """Depthwise causal conv over seq via stacked shifts. x: [B,S,di]."""
    out = jnp.zeros_like(x)
    for w in range(width):
        shift = width - 1 - w
        xs = x if shift == 0 else jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs * p["conv_w"][w]
    return out + p["conv_b"]


def _ssm_inputs(p: dict, cfg: ModelConfig, xc: jax.Array):
    """xc: [B,S,di] (post conv+silu). Returns decay [B,S,di,N] (in log space)
    and drive [B,S,di,N], plus C [B,S,N]."""
    R, N = dt_rank(cfg), cfg.mamba_state
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"]).astype(jnp.float32)
    dt_in, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])  # [B,S,di]
    A = -jnp.exp(p["A_log"])                                   # [di,N]
    log_decay = dt[..., None] * A                              # [B,S,di,N] (<=0)
    drive = (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]
    return log_decay, drive, Cc


def mamba_train(p: dict, cfg: ModelConfig, x: jax.Array, chunk: int = 0,
                return_state: bool = False):
    """x: [B,S,D] -> ([B,S,D], state|None). State returned for prefill."""
    B, S, D = x.shape
    chunk = chunk or cfg.ssm_chunk
    di, N = d_inner(cfg), cfg.mamba_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = shd.hint(xz, shd.BATCH_AXES, None, "model")
    x1, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p, x1, cfg.mamba_conv))
    log_decay, drive, Cc = _ssm_inputs(p, cfg, xc)

    sdt = jnp.dtype(cfg.ssm_dtype)
    nc = max(1, S // chunk)
    Lc = S // nc
    ld = log_decay.astype(sdt).reshape(B, nc, Lc, di, N)
    dr = drive.astype(sdt).reshape(B, nc, Lc, di, N)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    def chunk_step(h, ci):
        a, b = ld[:, ci], dr[:, ci]
        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_t = jnp.exp(a_cum) * h[:, None] + b_cum         # [B,Lc,di,N]
        y = jnp.einsum("bldn,bln->bld", h_t,
                       Cc.astype(sdt).reshape(B, nc, Lc, N)[:, ci])
        return h_t[:, -1], y

    h0 = jnp.zeros((B, di, N), sdt)
    h_fin, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nc),
                             unroll=True if cfg.scan_unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(jnp.float32)
    y = y + p["D_skip"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    state = None
    if return_state:
        w = cfg.mamba_conv
        conv_tail = x1[:, S - (w - 1):].astype(jnp.float32) if w > 1 \
            else jnp.zeros((B, 0, di), jnp.float32)
        state = {"h": h_fin, "conv": conv_tail}
    return out, state


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    di, N = d_inner(cfg), cfg.mamba_state
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_conv - 1, di), jnp.float32),
    }


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                 state: dict) -> Tuple[jax.Array, dict]:
    """x: [B,1,D]; exact recurrent step."""
    di, N, width = d_inner(cfg), cfg.mamba_state, cfg.mamba_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x1, z = jnp.split(xz[:, 0], 2, axis=-1)              # [B,di]
    conv_buf = jnp.concatenate(
        [state["conv"], x1[:, None].astype(jnp.float32)], axis=1)  # [B,width,di]
    xc = jnp.einsum("bwd,wd->bd", conv_buf, p["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32))
    log_decay, drive, Cc = _ssm_inputs(p, cfg, xc[:, None].astype(x.dtype))
    h = jnp.exp(log_decay[:, 0]) * state["h"] + drive[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])
    y = y + p["D_skip"] * xc
    y = (y.astype(x.dtype) * jax.nn.silu(z))[:, None]
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, {"h": h, "conv": conv_buf[:, 1:]}
