"""RWKV6 ("Finch") block: time-mix with data-dependent per-channel decay
and channel-mix FFN. [arXiv:2404.05892]

Training path uses a chunked closed form (GLA-style): within a chunk the
WKV recurrence
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ,   o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
is evaluated as a masked matmul with relative decay products computed in
log space (clamped for fp32 range; see tests for tolerance bounds); across
chunks an exact recurrent state is carried. This replaces the CUDA wkv6
kernel; the Pallas kernel in kernels/rwkv keeps the state in VMEM instead
(DESIGN.md §2). Decode is the exact single-step recurrence — O(1) state in
sequence length, which is why rwkv6-3b runs the long_500k cell."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import common

DECAY_LORA = 32
CUM_CLAMP = 18.0  # |log-decay| clamp inside a chunk (fp32 safety)


def num_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_size


def init_rwkv_time_mix(key, cfg: ModelConfig) -> dict:
    dt = common.dtype_of(cfg)
    d = cfg.d_model
    H, n = num_heads(cfg), cfg.rwkv_head_size
    ks = jax.random.split(key, 10)
    lora = min(DECAY_LORA, d)
    return {
        # token-shift mix coefficients for r,k,v,g,w
        "mu": common.normal_init(ks[0], (5, d), 0.02, jnp.float32) + 0.5,
        "wr": common.dense_init(ks[1], d, (d, d), dt),
        "wk": common.dense_init(ks[2], d, (d, d), dt),
        "wv": common.dense_init(ks[3], d, (d, d), dt),
        "wg": common.dense_init(ks[4], d, (d, d), dt),
        "wo": common.dense_init(ks[5], d, (d, d), dt),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "wA": common.dense_init(ks[6], d, (d, lora), jnp.float32),
        "wB": common.normal_init(ks[7], (lora, d), 0.01, jnp.float32),
        "u": common.normal_init(ks[8], (H, n), 0.3, jnp.float32),
        # per-head groupnorm on wkv output
        "ln_x_scale": common.ones((d,), jnp.float32),
        "ln_x_bias": common.zeros((d,), jnp.float32),
    }


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> dict:
    dt = common.dtype_of(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": common.normal_init(ks[0], (2, d), 0.02, jnp.float32) + 0.5,
        "wk": common.dense_init(ks[1], d, (d, ff), dt),
        "wv": common.dense_init(ks[2], ff, (ff, d), dt),
        "wr": common.dense_init(ks[0], d, (d, d), dt),
    }


def _token_shift(x: jax.Array, x_prev: jax.Array = None) -> jax.Array:
    """x: [B,S,D] -> previous token's features (zeros / x_prev at t=0)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev is not None:
        shifted = shifted.at[:, 0].set(x_prev)
    return shifted


def _lerp(x, xp, mu):
    return x + (xp - x) * mu.astype(x.dtype)


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """log w (negative) per channel: [B,S,D] float32."""
    lw = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    return -jnp.exp(lw)  # log-decay = -exp(.) <= 0


def _group_norm(x: jax.Array, scale, bias, H: int, eps=1e-5) -> jax.Array:
    """Per-head normalization of [B,S,D] with D = H*n."""
    B, S, D = x.shape
    xh = x.reshape(B, S, H, D // H).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, D) * scale + bias).astype(x.dtype)


def _rkvgw(p: dict, cfg: ModelConfig, x: jax.Array, xp: jax.Array):
    H, n = num_heads(cfg), cfg.rwkv_head_size
    B, S, D = x.shape
    r = _lerp(x, xp, p["mu"][0]) @ p["wr"]
    k = _lerp(x, xp, p["mu"][1]) @ p["wk"]
    v = _lerp(x, xp, p["mu"][2]) @ p["wv"]
    g = jax.nn.silu(_lerp(x, xp, p["mu"][3]) @ p["wg"])
    logw = _decay(p, _lerp(x, xp, p["mu"][4]))  # [B,S,D]
    shape = (B, S, H, n)
    r, k, v = (shd.hint(a.reshape(shape).astype(jnp.float32),
                        shd.BATCH_AXES, None, "model", None) for a in (r, k, v))
    return r, k, v, g, logw.reshape(shape)


def wkv_chunked(r, k, v, logw, u, s0=None, chunk: int = 64,
                unroll: bool = False):
    """Chunked WKV6. r,k,v,logw: [B,S,H,n] float32; u: [H,n].
    Returns (o [B,S,H,n], s_final [B,H,n,n])."""
    B, S, H, n = r.shape
    nc = max(1, S // chunk)
    Lc = S // nc
    rs, ks_, vs, lws = (a.reshape(B, nc, Lc, H, n) for a in (r, k, v, logw))
    if s0 is None:
        s0 = jnp.zeros((B, H, n, n), jnp.float32)

    causal = jnp.tril(jnp.ones((Lc, Lc), jnp.float32), k=-1)  # strict lower

    def chunk_step(S_prev, ci):
        rc, kc, vc, lwc = rs[:, ci], ks_[:, ci], vs[:, ci], lws[:, ci]
        cum = jnp.cumsum(lwc, axis=1)                      # inclusive [B,Lc,H,n]
        cum_ex = cum - lwc                                 # exclusive
        cl = jnp.clip(cum_ex, -CUM_CLAMP, 0.0)
        r_hat = rc * jnp.exp(cl)                           # decayed queries
        k_hat = kc * jnp.exp(jnp.clip(-cum, 0.0, CUM_CLAMP))
        scores = jnp.einsum("blhn,bmhn->bhlm", r_hat, k_hat) * causal
        diag = jnp.einsum("blhn,blhn->bhl", rc * u, kc)
        o = jnp.einsum("bhlm,bmhn->blhn", scores, vc)
        o = o + diag[..., None].transpose(0, 2, 1, 3) * vc
        # inter-chunk contribution from carried state
        o = o + jnp.einsum("blhn,bhnm->blhm", r_hat, S_prev)
        # state update to end of chunk
        total = cum[:, -1]                                 # [B,H,n]
        k_dec = kc * jnp.exp(jnp.clip(total[:, None] - cum, -CUM_CLAMP, 0.0))
        S_new = jnp.exp(jnp.clip(total, -CUM_CLAMP, 0.0))[..., None] * S_prev \
            + jnp.einsum("blhn,blhm->bhnm", k_dec, vc)
        return S_new, o

    s_fin, os_ = jax.lax.scan(chunk_step, s0, jnp.arange(nc),
                              unroll=True if unroll else 1)
    o = jnp.moveaxis(os_, 0, 1).reshape(B, S, H, n)
    return o, s_fin


def time_mix_train(p: dict, cfg: ModelConfig, x: jax.Array, chunk: int = 0,
                   return_state: bool = False):
    B, S, D = x.shape
    chunk = chunk or min(cfg.ssm_chunk, max(S, 1))
    H = num_heads(cfg)
    xp = _token_shift(x)
    r, k, v, g, logw = _rkvgw(p, cfg, x, xp)
    o, s_fin = wkv_chunked(r, k, v, logw, p["u"], chunk=chunk,
                           unroll=cfg.scan_unroll)
    o = _group_norm(o.reshape(B, S, D).astype(x.dtype),
                    p["ln_x_scale"], p["ln_x_bias"], H)
    out = (o * g) @ p["wo"]
    state = None
    if return_state:
        state = {"s": s_fin, "x_tm": x[:, -1].astype(jnp.float32)}
    return out, state


def channel_mix_train(p: dict, cfg: ModelConfig, x: jax.Array,
                      state: dict = None, return_state: bool = False):
    xp = _token_shift(x)
    kx = _lerp(x, xp, p["mu"][0])
    rx = _lerp(x, xp, p["mu"][1])
    k = jnp.square(jax.nn.relu(kx @ p["wk"]))
    out = jax.nn.sigmoid(rx @ p["wr"]) * (k @ p["wv"])
    new_state = None
    if return_state:
        new_state = dict(state or {})
        new_state["x_cm"] = x[:, -1].astype(jnp.float32)
    return out, new_state


# ---------------------------------------------------------------------------
# decode (exact recurrence)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    H, n = num_heads(cfg), cfg.rwkv_head_size
    return {
        "s": jnp.zeros((batch, H, n, n), jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "x_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def time_mix_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                    state: dict) -> Tuple[jax.Array, dict]:
    """x: [B,1,D]."""
    B, _, D = x.shape
    H, n = num_heads(cfg), cfg.rwkv_head_size
    xp = state["x_tm"].astype(x.dtype)[:, None]
    r, k, v, g, logw = _rkvgw(p, cfg, x, xp)
    r1, k1, v1, lw1 = r[:, 0], k[:, 0], v[:, 0], logw[:, 0]   # [B,H,n]
    S_prev = state["s"]
    o = jnp.einsum("bhn,bhnm->bhm", r1, S_prev) \
        + jnp.einsum("bhn,bhn,bhm->bhm", r1 * p["u"], k1, v1)
    S_new = jnp.exp(lw1)[..., None] * S_prev + jnp.einsum("bhn,bhm->bhnm", k1, v1)
    o = _group_norm(o.reshape(B, 1, D).astype(x.dtype),
                    p["ln_x_scale"], p["ln_x_bias"], H)
    out = (o * g) @ p["wo"]
    return out, {**state, "s": S_new, "x_tm": x[:, 0].astype(jnp.float32)}


def channel_mix_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                       state: dict) -> Tuple[jax.Array, dict]:
    xp = state["x_cm"].astype(x.dtype)[:, None]
    kx = _lerp(x, xp, p["mu"][0])
    rx = _lerp(x, xp, p["mu"][1])
    k = jnp.square(jax.nn.relu(kx @ p["wk"]))
    out = jax.nn.sigmoid(rx @ p["wr"]) * (k @ p["wv"])
    return out, {**state, "x_cm": x[:, 0].astype(jnp.float32)}
