"""GQA/MQA attention with sliding-window masks, logit softcaps, RoPE/M-RoPE,
chunked (memory-efficient, flash-style) training attention, and a KV cache
for prefill/decode serving.

Shapes follow [B, S, H, hd]. GQA groups Hq query heads onto Hkv KV heads.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import common

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig) -> dict:
    dt = common.dtype_of(cfg)
    d = cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(kq, d, (d, cfg.num_heads, cfg.head_dim), dt),
        "wk": common.dense_init(kk, d, (d, cfg.num_kv_heads, cfg.head_dim), dt),
        "wv": common.dense_init(kv, d, (d, cfg.num_kv_heads, cfg.head_dim), dt),
        "wo": common.dense_init(ko, cfg.q_dim, (cfg.num_heads, cfg.head_dim, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = common.zeros((cfg.num_heads, cfg.head_dim), dt)
        p["bk"] = common.zeros((cfg.num_kv_heads, cfg.head_dim), dt)
        p["bv"] = common.zeros((cfg.num_kv_heads, cfg.head_dim), dt)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    mesh = shd._current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if (cfg.attn_batch_shard and tp > 1 and cfg.num_heads % tp != 0
            and q.shape[0] % (tp * shd._axis_size(mesh, shd.data_axes(mesh))) == 0):
        # heads don't divide the model axis: batch-shard the whole attention
        # section over (data x model) instead of replicating it across TP
        # (EXPERIMENTS.md §Perf). The residual stream re-shards on exit.
        full = tuple(shd.data_axes(mesh)) + ("model",)
        q = shd.hint(q, full, None, None, None)
        k = shd.hint(k, full, None, None, None)
        v = shd.hint(v, full, None, None, None)
    else:
        q = shd.hint(q, shd.BATCH_AXES, None, "model", None)
        k = shd.hint(k, shd.BATCH_AXES, None, "model", None)
        v = shd.hint(v, shd.BATCH_AXES, None, "model", None)
    if cfg.mrope_sections:
        if positions.ndim == 2:  # [B,S] -> text-only 3-axis positions
            positions = jnp.stack([positions] * 3, axis=0)
        q = common.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = common.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """Causal (+ optional sliding-window) mask. True = attend."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _attend_dense(cfg: ModelConfig, q, k, v, q_pos, k_pos, window: int) -> jax.Array:
    """Plain attention; q: [B,Sq,Hq,hd], k/v: [B,Sk,Hkv,hd]."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = common.softcap(scores, cfg.attn_logit_softcap)
    mask = _mask(q_pos, k_pos, window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, Hq, hd)


def _attend_blockwise(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                      window: int) -> jax.Array:
    """Blockwise online-softmax attention with STATIC python loops.

    Two deliberate properties (DESIGN.md §2, EXPERIMENTS.md §Perf):
    - fully-masked (q-block, kv-block) pairs are skipped at *trace time* —
      the causal lower triangle and the sliding-window band are the only
      blocks that appear in the HLO, so both the FLOP count and the memory
      footprint reflect exactly the work a real flash kernel would do
      (gemma2 local layers at 32k attend 2 kv-blocks per q-block);
    - no lax.scan/map: XLA:CPU cost_analysis counts a while-loop body once
      regardless of trip count, which would corrupt the roofline terms.
    The Pallas kernel in kernels/attention is the TPU execution of the same
    blocking scheme with explicit VMEM tiles."""
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    g = Hq // Hkv
    bq = min(cfg.attn_q_block, Sq)
    bk = min(cfg.attn_k_block, Sk)
    nq = max(Sq // bq, 1)
    nk = max(Sk // bk, 1)
    bq, bk = Sq // nq, Sk // nk
    qs = q.reshape(B, nq, bq, Hkv, g, hd)
    ks = k.reshape(B, nk, bk, Hkv, hd)
    vs = v.reshape(B, nk, bk, Hkv, hd)
    qpos = q_pos.reshape(nq, bq)
    kpos = k_pos.reshape(nk, bk)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    outs = []
    for qi in range(nq):
        qb = qs[:, qi]
        qp = qpos[qi]
        q_lo, q_hi = qi * bq, (qi + 1) * bq - 1
        acc = jnp.zeros((B, Hkv, g, bq, hd), jnp.float32)
        m = jnp.full((B, Hkv, g, bq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, g, bq), jnp.float32)
        for ki in range(nk):
            k_lo, k_hi = ki * bk, (ki + 1) * bk - 1
            if k_lo > q_hi:
                continue  # static causal skip
            if window and k_hi < q_lo - window + 1 - bq:
                continue  # static sliding-window skip
            kb, vb, kp = ks[:, ki], vs[:, ki], kpos[ki]
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32) * scale
            s = common.softcap(s, cfg.attn_logit_softcap)
            msk = _mask(qp, kp, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.transpose(out, (0, 3, 1, 2, 4)))  # [B, bq, Hkv, g, hd]
    out = jnp.concatenate(outs, axis=1).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def attention_train(p: dict, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, window: int) -> jax.Array:
    """Full-sequence causal self-attention for training / prefill."""
    S = x.shape[1]
    pos1d = positions[0] if positions.ndim == 3 else positions
    q, k, v = _project_qkv(p, cfg, x, positions)
    q_pos = pos1d[0] if pos1d.ndim == 2 else pos1d  # mask uses per-row positions
    if S > cfg.attn_chunk:
        out = _attend_blockwise(cfg, q, k, v, q_pos, q_pos, window)
    else:
        out = _attend_dense(cfg, q, k, v, q_pos, q_pos, window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# serving: prefill fills a cache; decode attends one token against it


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def attention_prefill(p: dict, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, window: int) -> Tuple[jax.Array, dict]:
    S = x.shape[1]
    pos1d = positions[0] if positions.ndim == 3 else positions
    q, k, v = _project_qkv(p, cfg, x, positions)
    q_pos = pos1d[0] if pos1d.ndim == 2 else pos1d
    if S > cfg.attn_chunk:
        out = _attend_blockwise(cfg, q, k, v, q_pos, q_pos, window)
    else:
        out = _attend_dense(cfg, q, k, v, q_pos, q_pos, window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": k, "v": v}


def attention_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array, window: int) -> Tuple[jax.Array, dict]:
    """x: [B, 1, D]; cache k/v: [B, L, Hkv, hd]; pos: scalar int32 (current
    index). Returns output [B, 1, D] and the updated cache."""
    B = x.shape[0]
    L = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, pos, 0, 0))
    Hq, hd = cfg.num_heads, cfg.head_dim
    Hkv = cfg.num_kv_heads
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = common.softcap(scores, cfg.attn_logit_softcap)
    k_pos = jnp.arange(L)
    mask = k_pos[None, :] <= pos
    if window:
        mask &= (pos - k_pos[None, :]) < window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v).reshape(B, 1, Hq, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}
