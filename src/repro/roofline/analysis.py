"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
memory term     = HLO_bytes / (chips x 819 GB/s)
collective term = collective_bytes / (chips x 50 GB/s/link)

cost_analysis() on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes (verified in tests/test_roofline.py); we scale by chip count
to report globals. Collective bytes are not in cost_analysis: we parse the
post-partitioning HLO (`compiled.as_text()`) and sum operand bytes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, with ring-algorithm byte multipliers (all-reduce
moves ~2x its payload per device). Shapes in partitioned HLO are already
per-device, so `collective_bytes_per_chip / link_bw` is the term directly;
the table also reports the global `x chips` figure to match the formula in
the brief."""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# bytes-on-the-wire multiplier per collective kind (ring algorithms)
_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|ragged-all-to-all)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _tensor_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_per_chip: float = 0.0
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        # async pairs appear as -start/-done; count the -start only
        if "-done(" in line:
            continue
        shape_str = m.group(1) if m.group(1) is not None else m.group(2)
        b = _tensor_bytes(shape_str) * _FACTORS[kind]
        stats.bytes_per_chip += b
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + b
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    flops_ratio: float = 0.0            # MODEL_FLOPS / global HLO flops
    collective_counts: Dict[str, int] = field(default_factory=dict)
    memory_per_chip: Dict[str, float] = field(default_factory=dict)

    def finalize(self, peak_flops=197e12, hbm_bw=819e9, link_bw=50e9):
        self.compute_s = self.flops_per_chip / peak_flops
        self.memory_s = self.bytes_per_chip / hbm_bw
        self.collective_s = self.collective_bytes_per_chip / link_bw
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        global_flops = self.flops_per_chip * self.chips
        self.flops_ratio = self.model_flops / global_flops if global_flops else 0.0
        return self

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """How close the step is to the compute roofline: ideal compute
        time / achievable time (dominant term)."""
        ideal = self.model_flops / (self.chips * 197e12)
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops": self.model_flops, "compute_s": self.compute_s,
            "memory_s": self.memory_s, "collective_s": self.collective_s,
            "dominant": self.dominant, "flops_ratio": self.flops_ratio,
            "roofline_fraction": self.roofline_fraction(),
            "collective_counts": self.collective_counts,
            "memory_per_chip": self.memory_per_chip,
        }


def cost_analysis_dict(compiled) -> dict:
    """`compiled.cost_analysis()` as a flat dict across jax versions
    (0.4.x returns a one-element list of dicts, newer jax a dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> RooflineReport:
    cost = cost_analysis_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    mem_d = {
        "argument": float(getattr(mem, "argument_size_in_bytes", 0)),
        "output": float(getattr(mem, "output_size_in_bytes", 0)),
        "temp": float(getattr(mem, "temp_size_in_bytes", 0)),
        "generated_code": float(getattr(mem, "generated_code_size_in_bytes", 0)),
    }
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=bytes_acc,
        collective_bytes_per_chip=stats.bytes_per_chip,
        model_flops=model_flops, collective_counts=stats.counts,
        memory_per_chip=mem_d)
    return rep.finalize()


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active per token
    (decode), N = active params (MoE counts routed experts only)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
