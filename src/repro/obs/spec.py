"""TelemetrySpec — the declarative telemetry knob on `RuntimeConfig`.

Serializable like every other config piece (strict `to_dict`/`from_dict`
round trip, unknown keys raise listing the valid set). The default spec
is inactive: no tracer, no metrics, no sinks — the runtime takes the
legacy bit-exact path with zero telemetry allocations. Any of `enabled`
or a sink path activates it::

    RuntimeConfig(..., telemetry=TelemetrySpec(enabled=True,
                                               chrome_trace="run.json"))
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional


@dataclass(frozen=True)
class TelemetrySpec:
    """Telemetry configuration (module docstring).

    - `enabled`: collect spans + metrics in memory (exposed on the
      session as ``rt.telemetry`` after a run);
    - `trace_jsonl` / `chrome_trace`: sink paths written at run end
      (setting either implies collection);
    - `dispatch_events`: additionally record an instant per scheduler
      dispatch (event-level granularity; cheap, but the chattiest
      category — turn off for very long timelines).
    """
    enabled: bool = False
    trace_jsonl: Optional[str] = None
    chrome_trace: Optional[str] = None
    dispatch_events: bool = True

    @property
    def active(self) -> bool:
        return bool(self.enabled or self.trace_jsonl or self.chrome_trace)

    def validate(self, context: str = "telemetry") -> "TelemetrySpec":
        for fname in ("trace_jsonl", "chrome_trace"):
            v = getattr(self, fname)
            if v is not None and (not isinstance(v, str) or not v):
                raise ValueError(f"{context}: {fname} must be a non-empty "
                                 f"path string or None (got {v!r})")
        for fname in ("enabled", "dispatch_events"):
            if not isinstance(getattr(self, fname), bool):
                raise ValueError(f"{context}: {fname} must be a bool")
        return self

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"enabled": self.enabled}
        if self.trace_jsonl is not None:
            out["trace_jsonl"] = self.trace_jsonl
        if self.chrome_trace is not None:
            out["chrome_trace"] = self.chrome_trace
        if not self.dispatch_events:
            out["dispatch_events"] = False
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TelemetrySpec":
        if not isinstance(d, dict):
            raise ValueError(f"a telemetry spec must be a dict (got {d!r})")
        valid = {"enabled", "trace_jsonl", "chrome_trace", "dispatch_events"}
        unknown = set(d) - valid
        if unknown:
            raise ValueError(f"telemetry spec: unknown key(s) "
                             f"{sorted(unknown)}; valid: {sorted(valid)}")
        return cls(**d).validate()
