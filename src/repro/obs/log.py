"""Structured logging bootstrap for `src/repro` (DESIGN.md §14).

Library modules never call `logging.basicConfig` — they grab a module
logger via `get_logger(__name__-ish)` and log; entry points (benchmarks,
examples, `launch/platform.bootstrap`) call `configure_logging()` once,
which installs a single stderr handler on the `"edgeol"` root logger at
the level named by the ``EDGEOL_LOG`` environment variable (default
WARNING, so library users see problems but not chatter; set
``EDGEOL_LOG=DEBUG`` to watch sync skips and probe routing live).

The lint job enforces that no bare print call lands in `src/repro/` —
loggers only — so every runtime decision that used to be silent (dropped
probes, mid-round sync skips, straggler flags/evictions) flows through
here.
"""
from __future__ import annotations

import logging
import os
import sys

#: Root of the library's logger tree; every module logger hangs under it.
ROOT = "edgeol"

_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"


def get_logger(name: str) -> logging.Logger:
    """A logger under the `edgeol` tree: ``get_logger("fleet")`` ->
    ``edgeol.fleet``. Safe at import time — no handler is installed
    until `configure_logging` runs."""
    if name.startswith(ROOT):
        return logging.getLogger(name)
    return logging.getLogger(f"{ROOT}.{name}")


def configure_logging(level: str = None, *, stream=None,
                      force: bool = False) -> logging.Logger:
    """Idempotently install one stderr handler on the `edgeol` root
    logger. `level` falls back to ``$EDGEOL_LOG`` then ``WARNING``;
    `force=True` reconfigures (tests). Returns the root logger."""
    root = logging.getLogger(ROOT)
    if level is None:
        level = os.environ.get("EDGEOL_LOG", "WARNING")
    resolved = getattr(logging, str(level).upper(), None)
    if not isinstance(resolved, int):
        raise ValueError(f"unknown log level {level!r}; use one of "
                         f"DEBUG/INFO/WARNING/ERROR/CRITICAL")
    if force:
        for h in list(root.handlers):
            root.removeHandler(h)
    if not root.handlers:
        handler = logging.StreamHandler(stream or sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT))
        root.addHandler(handler)
        root.propagate = False
    root.setLevel(resolved)
    return root
