"""repro.obs — the runtime's observability layer (DESIGN.md §14).

Three cooperating pieces, all optional and all off by default:

- `trace` — a `Tracer` recording structured spans/instants on the
  *modeled* timeline (rounds, preemption segments, swaps, syncs, probes,
  serving dispatches), tagged with stream/device/slot; `NULL_TRACER` is
  the falsy no-op stand-in every hot path guards on, so a disabled run
  allocates nothing and stays bit-exact (the golden regression pins it).
- `metrics` — a `MetricsRegistry` of labeled counters/gauges/histograms
  fed by the `CostLedger` observer hook, so `snapshot()` reconciles
  against ledger totals exactly (per stream, per model, per device).
- `export` — JSONL and Chrome trace-event (Perfetto-loadable) sinks plus
  the validating loader CI uses; `benchmarks/trace_report.py` renders the
  human summary (utilization timeline, round Gantt, slowest segments).

`TelemetrySpec` (spec.py) is the JSON-round-trippable config knob
(`RuntimeConfig.telemetry`); `Telemetry` (telemetry.py) is the live
bundle a session carries. `log` is the structured-logging bootstrap
(`EDGEOL_LOG` env level) the whole of `src/repro` logs through.
"""
from repro.obs.export import (chrome_trace, chrome_tracks,
                              events_from_chrome, load_chrome_trace,
                              read_jsonl, write_chrome_trace, write_jsonl)
from repro.obs.log import configure_logging, get_logger
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.spec import TelemetrySpec
from repro.obs.telemetry import Telemetry
from repro.obs.trace import (DEVICE_TIME_CATS, NULL_TRACER, NullTracer,
                             TraceEvent, Tracer, device_time)

__all__ = [
    "Counter", "DEVICE_TIME_CATS", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "NullTracer", "Telemetry", "TelemetrySpec", "TraceEvent",
    "Tracer", "chrome_trace", "chrome_tracks", "configure_logging",
    "device_time",
    "events_from_chrome", "get_logger", "load_chrome_trace", "read_jsonl",
    "write_chrome_trace", "write_jsonl",
]
