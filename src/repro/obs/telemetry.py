"""Telemetry — the live observability bundle one session carries.

Built by `resolve_session` when `RuntimeConfig.telemetry` is active and
threaded to `ContinualRuntime._init` as ``telemetry=``; the `DeviceFleet`
resets it per run, hands its `tracer` to every instrumented subsystem,
installs it as the `CostLedger`'s observer, and flushes the configured
sinks at run end. A session without telemetry carries ``None`` and every
hot path short-circuits on the falsy `NULL_TRACER` — the disabled run is
allocation-free and bit-exact.

The ledger-observer contract (`on_charge`/`on_round`/`on_preemption`/
`on_swap`/`on_sync`) mirrors `CostLedger`'s charge methods one-to-one:
each charge bumps the matching `time_s`/`energy_j`/`flops` counters per
stream, per model and per device, so `reconcile(ledger)` — the max
absolute difference between counter sums and ledger attributions across
all three dimensions — is zero by construction on a consistent run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.obs.export import write_chrome_trace, write_jsonl
from repro.obs.metrics import MetricsRegistry
from repro.obs.spec import TelemetrySpec
from repro.obs.trace import Tracer

#: (ledger dimension name, counter label key) pairs `reconcile` walks.
_DIMS = (("per_stream", "stream"), ("per_model", "model"),
         ("per_device", "device"))


class Telemetry:
    def __init__(self, spec: Optional[TelemetrySpec] = None):
        self.spec = spec if spec is not None else TelemetrySpec(enabled=True)
        self.tracer = Tracer()
        self.metrics = MetricsRegistry()

    def reset(self) -> None:
        """Fresh tracer + registry (the fleet calls this at run start so
        a session re-run doesn't accumulate the previous run's events)."""
        self.tracer = Tracer()
        self.metrics = MetricsRegistry()

    # ---- CostLedger observer hooks ---------------------------------------
    def on_charge(self, *, time_s: float, energy_j: float, flops: float,
                  stream: int, model: str, device: str,
                  kind: str = "round") -> None:
        """Every ledger charge lands here once; `kind` is the breakdown
        family ('round', 'cka', 'swap', 'sync', 'probe', 'resume')."""
        m = self.metrics
        for name, amount in (("time_s", time_s), ("energy_j", energy_j),
                             ("flops", flops)):
            if amount:
                m.counter(name, stream=stream).inc(amount)
                m.counter(name, model=model).inc(amount)
                m.counter(name, device=device).inc(amount)
        m.counter("charges", kind=kind).inc()

    def on_round(self, *, stream: int, model: str, device: str) -> None:
        self.metrics.counter("rounds", device=device).inc()
        self.metrics.counter("rounds", stream=stream).inc()

    def on_preemption(self, *, stream: int) -> None:
        self.metrics.counter("preemptions", stream=stream).inc()

    def on_swap(self, *, model: str, device: str) -> None:
        self.metrics.counter("swaps", device=device).inc()
        self.metrics.counter("swaps", model=model).inc()

    def on_sync(self, *, device: str) -> None:
        self.metrics.counter("syncs", device=device).inc()

    # ---- reporting -------------------------------------------------------
    def reconcile(self, ledger) -> Dict[str, float]:
        """Max |counter sum − ledger attribution| per (dimension, field):
        ``{"per_stream.time_s": 0.0, ...}``. Exact zeros on a consistent
        run — the test suite asserts tiny float tolerances anyway."""
        out: Dict[str, float] = {}
        for dim_name, label in _DIMS:
            dim = getattr(ledger, dim_name)
            for fname in ("time_s", "energy_j", "flops"):
                worst = 0.0
                for key, cell in dim.items():
                    got = self.metrics.sum_counters(fname, **{label: key})
                    worst = max(worst, abs(got - cell.get(fname, 0.0)))
                out[f"{dim_name}.{fname}"] = worst
        return out

    def snapshot(self, ledger=None) -> Dict[str, Any]:
        """Metrics snapshot, with the ledger reconciliation and totals
        attached when a ledger is given. `ledger` may be the live
        `CostLedger` or a finished `RunResult` — both carry the three
        attribution dicts `reconcile` walks (the result's flops total is
        reported in TFLOPs, hence the fallback)."""
        snap = self.metrics.snapshot()
        snap["trace_events"] = len(self.tracer.events)
        if ledger is not None:
            flops = getattr(ledger, "total_flops", None)
            if flops is None:
                flops = ledger.compute_tflops * 1e12
            snap["ledger"] = {"total_time_s": ledger.total_time_s,
                              "total_energy_j": ledger.total_energy_j,
                              "total_flops": flops,
                              "rounds": ledger.rounds}
            snap["reconciliation"] = self.reconcile(ledger)
        return snap

    def flush_sinks(self) -> None:
        """Write the configured trace sinks (no-op when no paths set)."""
        if self.spec.trace_jsonl:
            write_jsonl(self.tracer.events, self.spec.trace_jsonl)
        if self.spec.chrome_trace:
            write_chrome_trace(self.tracer.events, self.spec.chrome_trace)
