"""Tracer — structured spans and instants on the *modeled* timeline.

A `TraceEvent` is one record: a duration **span** (``dur`` seconds of
modeled device/stream time) or an **instant** (``dur is None`` — a point
event like a serving dispatch, a publish, a straggler flag). Every event
may carry the three attribution tags the `CostLedger` uses — ``stream``
(arrival stream id, or `FLEET_STREAM` −1 for fleet-caused work),
``device`` (fleet device name) and ``slot`` (model slot) — plus free-form
JSON-able ``args`` (wall-clock milliseconds, recompile flags, vmap bucket
sizes).

The span taxonomy is pinned in DESIGN.md §14. The invariant the obs test
suite enforces: duration-bearing spans with a ``device`` tag are emitted
exactly at `CostLedger` charge sites (`DEVICE_TIME_CATS`), so summing
their durations per device reproduces ``per_device[dev]["time_s"]`` to
float tolerance — the trace *is* the ledger, unrolled over time.

`NullTracer` is the disabled path: falsy, stateless, allocation-free.
Hot paths guard with ``if self.tracer:`` so a disabled run (the default)
never builds an event, never formats an arg, never moves a bit — which
is what keeps the golden regression byte-identical.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

#: Categories whose spans carry modeled *device occupancy* time — one
#: span per `CostLedger` time charge. Per-device sums over exactly these
#: categories reconcile with `per_device[...]["time_s"]`; everything else
#: ("request" spans on stream tracks, instants) is observational.
DEVICE_TIME_CATS = frozenset(
    {"round", "segment", "resume", "swap", "sync", "probe", "cka"})


@dataclass
class TraceEvent:
    """One structured trace record (module docstring)."""
    name: str                      # human label, e.g. "round/cv"
    cat: str                       # taxonomy category, e.g. "round"
    ts: float                      # modeled start time (seconds)
    dur: Optional[float] = None    # span duration (None = instant)
    stream: Optional[int] = None   # arrival stream (-1 = fleet)
    device: Optional[str] = None   # fleet device lane
    slot: Optional[str] = None     # model slot
    args: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        return cls(**d)


class Tracer:
    """Collects `TraceEvent`s in memory; truthy, so instrumented call
    sites (guarded by ``if self.tracer:``) emit through it. Sinks
    (`repro.obs.export`) serialize `events` after the run."""

    enabled = True

    def __init__(self):
        self.events: List[TraceEvent] = []

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self.events)

    def reset(self) -> None:
        self.events = []

    def span(self, cat: str, name: str, ts: float, dur: float, *,
             stream: Optional[int] = None, device: Optional[str] = None,
             slot: Optional[str] = None, **args: Any) -> TraceEvent:
        """Record a duration span of `dur` modeled seconds at `ts`."""
        ev = TraceEvent(name, cat, float(ts), float(dur), stream, device,
                        slot, args)
        self.events.append(ev)
        return ev

    def instant(self, cat: str, name: str, ts: float, *,
                stream: Optional[int] = None, device: Optional[str] = None,
                slot: Optional[str] = None, **args: Any) -> TraceEvent:
        """Record a point event (no duration) at `ts`."""
        ev = TraceEvent(name, cat, float(ts), None, stream, device, slot,
                        args)
        self.events.append(ev)
        return ev


class NullTracer:
    """The disabled path: falsy and inert. Instrumented sites test
    ``if self.tracer:`` before building any event, so this object's
    methods exist only for unguarded/defensive calls."""

    enabled = False
    events: List[TraceEvent] = []  # always empty, shared, never written

    def __bool__(self) -> bool:
        return False

    def __len__(self) -> int:
        return 0

    def reset(self) -> None:
        pass

    def span(self, *a, **k) -> None:
        return None

    def instant(self, *a, **k) -> None:
        return None


#: Module singleton: the default value of every `tracer` attribute in the
#: runtime, so the disabled path costs one falsy attribute test.
NULL_TRACER = NullTracer()


def device_time(events: List[TraceEvent]) -> Dict[str, float]:
    """Summed durations of device-occupancy spans (`DEVICE_TIME_CATS`)
    per device — the trace-side half of the ledger reconciliation."""
    out: Dict[str, float] = {}
    for e in events:
        if e.dur is not None and e.device is not None \
                and e.cat in DEVICE_TIME_CATS:
            out[e.device] = out.get(e.device, 0.0) + e.dur
    return out
