"""MetricsRegistry — labeled counters, gauges and histograms.

The registry is the aggregate view the Tracer's event stream is too raw
for: per-stream serving-latency histograms, per-device utilization
gauges, swap/sync/preemption/recompile counters, and — crucially — the
`time_s`/`energy_j`/`flops` counters the `CostLedger` bumps through its
telemetry observer at every charge. Because ledger and registry see the
*same* increments, `Telemetry.reconcile(ledger)` is exact by
construction (float-identical, not merely close), across all three
attribution dimensions.

Metrics are identified by ``(name, frozen label set)``: ``counter("syncs",
device="dev1")`` get-or-creates one instrument per label combination.
`snapshot()` renders everything JSON-ready with stable
``name{k=v,...}`` keys.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> _Key:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing float."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Append-only sample set summarized at snapshot time (count / sum /
    min / max / p50 / p95). Runs are bounded (one sample per request), so
    samples are kept exact rather than bucketed."""

    __slots__ = ("samples",)

    def __init__(self):
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    def summary(self) -> Dict[str, float]:
        if not self.samples:
            return {"count": 0, "sum": 0.0}
        s = sorted(self.samples)
        n = len(s)

        def pct(q: float) -> float:
            return s[min(n - 1, int(q * (n - 1) + 0.5))]

        return {"count": n, "sum": float(sum(s)), "min": s[0], "max": s[-1],
                "p50": pct(0.50), "p95": pct(0.95)}


class MetricsRegistry:
    def __init__(self):
        self._counters: Dict[_Key, Counter] = {}
        self._gauges: Dict[_Key, Gauge] = {}
        self._histograms: Dict[_Key, Histogram] = {}

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._counters.setdefault(_key(name, labels), Counter())

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._gauges.setdefault(_key(name, labels), Gauge())

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._histograms.setdefault(_key(name, labels), Histogram())

    def counter_value(self, name: str, **labels: Any) -> float:
        c = self._counters.get(_key(name, labels))
        return c.value if c is not None else 0.0

    def sum_counters(self, name: str, **labels: Any) -> float:
        """Sum of every counter named `name` whose labels include the
        given subset (e.g. ``sum_counters("time_s", device="dev0")``)."""
        want = set(_key(name, labels)[1])
        return sum(c.value for (n, ls), c in self._counters.items()
                   if n == name and want <= set(ls))

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values `label` takes across counters named `name`."""
        out = set()
        for (n, ls) in self._counters:
            if n != name:
                continue
            for k, v in ls:
                if k == label:
                    out.add(v)
        return sorted(out)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready dump: counters/gauges as scalars, histograms as
        summary dicts, keys rendered ``name{label=value,...}``."""
        return {
            "counters": {_render(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {_render(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {_render(k): h.summary()
                           for k, h in sorted(self._histograms.items())},
        }
