"""Trace sinks: JSONL (full-fidelity round trip) and Chrome trace-event
JSON (Perfetto-loadable), plus the validating loader CI runs.

JSONL is the machine feed (one `TraceEvent` dict per line; `read_jsonl ∘
write_jsonl` is the identity — a test pins it). The Chrome export is the
human feed: open https://ui.perfetto.dev and drag the file in, or load
it at chrome://tracing. Track layout (DESIGN.md §14):

- **pid 1 "devices"** — one thread (track) per fleet device lane, named
  after the device. Every event tagged with a ``device`` lands here;
  duration spans on these tracks are exactly the ledger's device-time
  charges, so the lane reads as the device's occupancy Gantt.
- **pid 2 "streams"** — one track per arrival stream (the fleet
  pseudo-stream −1 renders as "fleet"). Every event tagged with a
  ``stream`` lands here too (an event may appear on both a device and a
  stream track — same span, two views).

Timestamps/durations are modeled seconds scaled to the format's
microseconds. Provenance (stream/device/slot) rides in each event's
``args``, so `events_from_chrome` can invert the export (device-track
copies win; stream-only events are picked off pid 2), which is what lets
`benchmarks.trace_report` summarize either sink format.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

from repro.obs.trace import TraceEvent

#: Chrome trace pids: one process groups the device lanes, one the
#: per-stream tracks.
DEVICE_PID = 1
STREAM_PID = 2

#: Display name of the fleet pseudo-stream's track (FLEET_STREAM = -1).
FLEET_TRACK = "fleet"

_US = 1e6  # modeled seconds -> trace microseconds


# ---------------------------------------------------------------------------
# JSONL


def write_jsonl(events: List[TraceEvent], path: str) -> None:
    """One JSON object per line; directories are created on demand."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e.to_dict(), sort_keys=True))
            f.write("\n")


def read_jsonl(path: str) -> List[TraceEvent]:
    out: List[TraceEvent] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(TraceEvent.from_dict(json.loads(line)))
            except (json.JSONDecodeError, TypeError) as e:
                raise ValueError(f"malformed trace JSONL {path} "
                                 f"line {i + 1}: {e}") from None
    return out


# ---------------------------------------------------------------------------
# Chrome trace-event format


def _stream_track(stream: int) -> str:
    return FLEET_TRACK if stream < 0 else f"stream {stream}"


def chrome_trace(events: List[TraceEvent]) -> Dict[str, Any]:
    """Build a Chrome trace-event document (module docstring layout)."""
    devices = sorted({e.device for e in events if e.device is not None})
    streams = sorted({e.stream for e in events if e.stream is not None})
    dev_tid = {d: i for i, d in enumerate(devices)}
    st_tid = {s: i for i, s in enumerate(streams)}
    out: List[Dict[str, Any]] = []
    for pid, pname in ((DEVICE_PID, "devices"), (STREAM_PID, "streams")):
        out.append({"ph": "M", "pid": pid, "tid": 0,
                    "name": "process_name", "args": {"name": pname}})
    for d, tid in dev_tid.items():
        out.append({"ph": "M", "pid": DEVICE_PID, "tid": tid,
                    "name": "thread_name", "args": {"name": d}})
    for s, tid in st_tid.items():
        out.append({"ph": "M", "pid": STREAM_PID, "tid": tid,
                    "name": "thread_name",
                    "args": {"name": _stream_track(s)}})

    def emit(e: TraceEvent, pid: int, tid: int) -> None:
        args = {"cat_": e.cat, "stream": e.stream, "device": e.device,
                "slot": e.slot, **e.args}
        rec: Dict[str, Any] = {"name": e.name, "cat": e.cat, "pid": pid,
                               "tid": tid, "ts": e.ts * _US, "args": args}
        if e.cat == "gauge":
            # env gauges (DESIGN.md §15) render as Perfetto counter
            # tracks. Counter identity is (pid, name) — gauge names embed
            # the device (`temperature_c/dev0`) so fleets don't collide —
            # and counter args must be numeric-only series.
            rec["ph"] = "C"
            rec["args"] = {k: v for k, v in e.args.items()
                           if isinstance(v, (int, float))}
        elif e.dur is None:
            rec["ph"] = "i"
            rec["s"] = "t"
        else:
            rec["ph"] = "X"
            rec["dur"] = e.dur * _US
        out.append(rec)

    for e in events:
        if e.device is not None:
            emit(e, DEVICE_PID, dev_tid[e.device])
        if e.stream is not None:
            emit(e, STREAM_PID, st_tid[e.stream])
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"generator": "edgeol.obs",
                          "devices": devices,
                          "streams": [_stream_track(s) for s in streams]}}


def write_chrome_trace(events: List[TraceEvent], path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f)
        f.write("\n")


def load_chrome_trace(path: str) -> Dict[str, Any]:
    """Load + validate a Chrome trace file (the CI gate). Raises
    `ValueError` naming the file and the first structural problem;
    returns the parsed document."""
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(f"malformed Chrome trace {path}: {e}") from None
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace (missing the "
                         f"'traceEvents' object key)")
    evs = doc["traceEvents"]
    if not isinstance(evs, list) or not evs:
        raise ValueError(f"{path}: 'traceEvents' must be a non-empty list")
    for i, rec in enumerate(evs):
        for key in ("ph", "pid", "tid", "name"):
            if key not in rec:
                raise ValueError(f"{path}: traceEvents[{i}] missing {key!r}")
        if rec["ph"] in ("X", "i", "C") and not isinstance(
                rec.get("ts"), (int, float)):
            raise ValueError(f"{path}: traceEvents[{i}] ({rec['ph']!r}) "
                             f"needs a numeric 'ts'")
        if rec["ph"] == "X":
            dur = rec.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{path}: traceEvents[{i}] span has no "
                                 f"non-negative 'dur' (got {dur!r})")
    if not chrome_tracks(doc)["devices"]:
        raise ValueError(f"{path}: no named device tracks (pid "
                         f"{DEVICE_PID} thread_name metadata)")
    return doc


def chrome_tracks(doc: Dict[str, Any]) -> Dict[str, List[str]]:
    """Track names by group: ``{"devices": [...], "streams": [...]}``
    from the document's thread_name metadata."""
    out: Dict[str, List[str]] = {"devices": [], "streams": []}
    for rec in doc.get("traceEvents", []):
        if rec.get("ph") == "M" and rec.get("name") == "thread_name":
            group = "devices" if rec.get("pid") == DEVICE_PID else "streams"
            out[group].append(rec.get("args", {}).get("name", "?"))
    out["devices"].sort()
    out["streams"].sort()
    return out


def events_from_chrome(doc: Dict[str, Any]) -> List[TraceEvent]:
    """Invert `chrome_trace`: reconstruct `TraceEvent`s from the export.
    Device-track copies are taken verbatim; stream-track records are kept
    only when the event had no device tag (otherwise the device copy
    already carries it) — so the result matches the original event list
    up to ordering."""
    out: List[TraceEvent] = []
    for rec in doc.get("traceEvents", []):
        if rec.get("ph") not in ("X", "i", "C"):
            continue
        args = dict(rec.get("args", {}))
        device = args.pop("device", None)
        stream = args.pop("stream", None)
        slot = args.pop("slot", None)
        cat = args.pop("cat_", rec.get("cat", ""))
        if rec["pid"] == STREAM_PID and device is not None:
            continue  # duplicate of the device-track copy
        dur = rec["dur"] / _US if rec.get("ph") == "X" else None
        out.append(TraceEvent(rec["name"], cat, rec["ts"] / _US, dur,
                              stream, device, slot, args))
    return out
