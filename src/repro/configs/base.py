"""Configuration dataclasses for the repro framework.

Every architecture is described by a frozen (hashable) ``ModelConfig`` so it
can be used as a static argument to ``jax.jit`` and as a cache key for the
compiled-function cache that LazyTune amortizes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape × step-kind) cell of the dry-run matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes (identical across all 10 archs).
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering dense / MoE / hybrid / SSM
    decoder LMs plus the paper's own CV/NLP models."""

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio | cnn | vit | encoder
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1          # MoE layer every `moe_period` layers (1 = all)
    capacity_factor: float = 1.25
    moe_d_ff: int = 0            # expert hidden size (defaults to d_ff)
    router_aux_coef: float = 0.01

    # --- attention flavour ---
    sliding_window: int = 0          # >0: local attention window
    local_global_period: int = 0     # gemma2: alternate local/global every k layers
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0 # gemma2: 30.0
    qkv_bias: bool = False           # qwen1.5 / qwen2
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t,h,w) sections

    # --- hybrid / ssm ---
    attn_period: int = 0         # jamba: 1 attention layer every `attn_period`
    mamba_state: int = 16        # SSM state dimension N
    mamba_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_size: int = 64

    # --- misc ---
    post_norms: bool = False     # gemma2: post-attn/post-ffn norms
    norm_eps: float = 1e-6
    act: str = "silu"            # 'silu' | 'gelu'
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- modality frontend stub ---
    frontend: str = "none"       # none | vision_stub | audio_stub
    frontend_dim: int = 0        # raw patch/frame embedding dim
    frontend_tokens: int = 0     # number of prefix tokens supplied by the stub

    # --- CV / NLP paper models ---
    image_size: int = 0
    num_classes: int = 0
    width_mult: float = 1.0

    # --- execution ---
    scan_layers: bool = True     # scan-over-layers (big LMs) vs unrolled (paper models)
    remat: str = "full"          # 'none' | 'full' | 'dots'
    attn_chunk: int = 2048       # blockwise (flash-style) attention above this seq len
    attn_q_block: int = 2048     # blockwise attention q block
    attn_k_block: int = 2048     # blockwise attention kv block
    scan_unroll: bool = False    # unroll the layer scan (roofline dry-runs)
    ssm_chunk: int = 128         # mamba/rwkv chunk length (sequence blocking)
    ssm_dtype: str = "float32"   # mamba state-expansion dtype (bf16 = less HBM traffic)
    moe_local_dispatch: bool = False  # per-data-shard top-k routing (no global
                                      # token gather; capacity split per shard)
    attn_batch_shard: bool = False  # batch-shard attention over (data x model)
                                    # when heads don't divide the model axis
    shard_head_dim: bool = False # fallback to head_dim sharding when heads < tp
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False
    # route attention forwards through the Pallas flash kernel (interpret
    # mode on CPU); consumed by the paper's ViT/BERT models — forward
    # only, the loss path keeps XLA (the kernel has no custom VJP)
    use_pallas: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- derived -----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_lm(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "ssm", "vlm", "audio")

    def layer_kind(self, i: int) -> str:
        """Kind of block at layer index i: 'attn' | 'mamba' | 'rwkv'."""
        if self.family == "ssm":
            return "rwkv"
        if self.attn_period:
            # jamba: one attention layer per attn_period, at position attn_period//2
            return "attn" if (i % self.attn_period) == self.attn_period // 2 else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.num_experts:
            return False
        return (i % self.moe_period) == (self.moe_period - 1)

    def layer_window(self, i: int) -> int:
        """Sliding window size for layer i (0 = global)."""
        if self.local_global_period and self.sliding_window:
            return self.sliding_window if i % self.local_global_period == 0 else 0
        return self.sliding_window

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and memory napkin math)."""
        if self.family == "cnn" or self.family == "vit" or self.family == "encoder":
            return -1  # counted from the actual pytree instead
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
            elif kind == "mamba":
                di = self.mamba_expand * d
                total += d * 2 * di + di * (2 * self.mamba_state + 1) \
                    + self.mamba_conv * di + di * d + di  # in/x/dt/conv/out
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o (wkv6 core)
                total += 2 * d * d // 8     # data-dependent decay low-rank (approx)
            if self.layer_is_moe(i):
                total += self.num_experts * 3 * d * self.expert_ff + d * self.num_experts
            else:
                total += 3 * d * ff if self.act in ("silu", "gelu") else 2 * d * ff
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        for i in range(self.num_layers):
            if self.layer_is_moe(i):
                inactive = (self.num_experts - self.experts_per_token)
                total -= inactive * 3 * d * self.expert_ff
        return total
