"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

48L d_model=2048 32H (GQA kv=4) d_ff=768 (expert hidden) vocab=151936,
MoE 128e top-8 on every layer; head_dim=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    moe_d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_period=1,
    rope_theta=1_000_000.0,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-30b-a3b-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96, moe_d_ff=96,
        vocab_size=256, num_experts=8, experts_per_token=2, remat="none",
    )
