"""rwkv6-3b [ssm] — Finch: data-dependent decay linear attention.
[arXiv:2404.05892; hf]

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536; head size 64
(40 heads). Recurrent state is O(1) in sequence length => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # d_model / rwkv_head_size
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_size=64,
    act="silu",
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-3b-reduced", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, rwkv_head_size=16, d_ff=128,
        vocab_size=256, remat="none",
    )
