"""qwen1.5-32b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]

64L d_model=5120 40H (GQA kv=40, i.e. MHA) d_ff=27392 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen1.5-32b-reduced", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, remat="none",
    )
