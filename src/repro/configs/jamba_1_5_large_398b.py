"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Block structure: one attention layer per 8 (attn_period=8, at offset 4),
MoE every other layer (moe_period=2). SSM layers are Mamba-1 selective SSM
(diagonal A, associative-scan). Sub-quadratic overall => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_period=2,
    moe_d_ff=24576,
    attn_period=8,
    mamba_state=16,
    mamba_conv=4,
    mamba_expand=2,
    act="silu",
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-1.5-large-398b-reduced", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, moe_d_ff=128,
        vocab_size=256, num_experts=4, experts_per_token=2, mamba_state=4,
        remat="none",
    )
