"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; head_dim=256;
sliding window 4096 on even layers; attn softcap 50, final softcap 30;
GeGLU; tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    post_norms=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-2b-reduced", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        sliding_window=32, remat="none",
    )
