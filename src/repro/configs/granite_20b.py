"""granite-20b [dense] — llama-arch code model with MQA (kv=1).
[arXiv:2405.04324; hf]

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
MQA: the single KV head is replicated across tensor-parallel shards
(sharding rule falls back head_dim-sharding for the KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-20b-reduced", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256, remat="none",
    )
