"""gemma2-27b [dense] — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; head_dim=128
(official gemma2 config keeps H*hd independent of d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    post_norms=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-27b-reduced", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        sliding_window=32, remat="none",
    )
