"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
The vision encoder is a STUB: ``input_specs()`` supplies precomputed patch
embeddings (1280-dim, ViT-style) that are projected and prepended to the
token sequence. M-RoPE uses (t, h, w) = (16, 24, 24) sections of head_dim/2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
    frontend_dim=1280,
    frontend_tokens=256,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-vl-72b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mrope_sections=(2, 3, 3), frontend_dim=48, frontend_tokens=8,
        remat="none",
    )
