"""Config registry: ``get_config(name)`` / ``get_reduced(name)`` /
``ARCHS`` (the 10 assigned architectures) / ``LM_SHAPES``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (LM_SHAPES, LONG_500K, DECODE_32K, PREFILL_32K,
                                TRAIN_4K, ModelConfig, ShapeConfig)
from repro.configs import paper_models

# The 10 assigned architectures, in assignment order.
ARCHS = (
    "qwen2-vl-72b",
    "jamba-1.5-large-398b",
    "gemma2-2b",
    "granite-20b",
    "gemma2-27b",
    "qwen1.5-32b",
    "rwkv6-3b",
    "qwen3-moe-30b-a3b",
    "kimi-k2-1t-a32b",
    "musicgen-medium",
)

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma2-2b": "gemma2_2b",
    "granite-20b": "granite_20b",
    "gemma2-27b": "gemma2_27b",
    "qwen1.5-32b": "qwen1_5_32b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "musicgen-medium": "musicgen_medium",
}

PAPER_MODELS: Dict[str, ModelConfig] = {
    "resnet50": paper_models.RESNET50,
    "mobilenetv2": paper_models.MOBILENETV2,
    "deit-tiny": paper_models.DEIT_TINY,
    "bert-base": paper_models.BERT_BASE,
}

_PAPER_REDUCED = {
    "resnet50": paper_models.resnet_reduced,
    "mobilenetv2": paper_models.mobilenet_reduced,
    "deit-tiny": paper_models.deit_reduced,
    "bert-base": paper_models.bert_reduced,
}


def _module(name: str):
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    if name in _MODULES:
        return _module(name).CONFIG
    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES) + sorted(PAPER_MODELS)}")


def get_reduced(name: str) -> ModelConfig:
    if name in _MODULES:
        return _module(name).reduced()
    if name in _PAPER_REDUCED:
        return _PAPER_REDUCED[name]()
    raise KeyError(name)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'' if the (arch, shape) cell runs, else a skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip: full quadratic attention at 524288 ctx (DESIGN.md §4)"
    return ""
