"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048. The EnCodec
audio frontend is a STUB: ``input_specs()`` supplies the token ids of the
flattened codebook stream plus optional conditioning frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    frontend="audio_stub",
    frontend_dim=768,
    frontend_tokens=64,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="musicgen-medium-reduced", num_layers=3, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        frontend_dim=48, frontend_tokens=8, remat="none",
    )
