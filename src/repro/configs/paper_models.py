"""The paper's own evaluation models (ETuner §V-A): ResNet50, MobileNetV2,
DeiT-tiny (CV) and BERT-base (NLP). These are the *paper-faithful* targets —
they run unrolled (per-layer pytrees) so SimFreeze's arbitrary-layer
freezing deletes exactly the weight-gradient work the paper describes.

Full-size and reduced (CPU-runnable continual-learning benchmark) variants.
"""
from repro.configs.base import ModelConfig

RESNET50 = ModelConfig(
    name="resnet50", family="cnn", image_size=128, num_classes=50,
    scan_layers=False, remat="none",
)
MOBILENETV2 = ModelConfig(
    name="mobilenetv2", family="cnn", image_size=128, num_classes=50,
    width_mult=1.0, scan_layers=False, remat="none",
)
DEIT_TINY = ModelConfig(
    name="deit-tiny", family="vit", image_size=224, num_classes=50,
    num_layers=12, d_model=192, num_heads=3, num_kv_heads=3, head_dim=64,
    d_ff=768, act="gelu", scan_layers=False, remat="none",
)
BERT_BASE = ModelConfig(
    name="bert-base", family="encoder", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=30522,
    num_classes=20, act="gelu", scan_layers=False, remat="none",
)


def resnet_reduced() -> ModelConfig:
    # A small ResNet (stem + 4 stages of 1 bottleneck each) on 32x32 inputs.
    return RESNET50.replace(name="resnet-reduced", image_size=32, num_classes=10)


def mobilenet_reduced() -> ModelConfig:
    return MOBILENETV2.replace(name="mobilenetv2-reduced", image_size=32,
                               num_classes=10, width_mult=0.5)


def deit_reduced() -> ModelConfig:
    return DEIT_TINY.replace(name="deit-reduced", image_size=32, num_layers=4,
                             d_model=64, num_heads=4, num_kv_heads=4,
                             head_dim=16, d_ff=128, num_classes=10)


def bert_reduced() -> ModelConfig:
    return BERT_BASE.replace(name="bert-reduced", num_layers=4, d_model=64,
                             num_heads=4, num_kv_heads=4, head_dim=16,
                             d_ff=128, vocab_size=512, num_classes=10)
