"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table config).
[arXiv:2501.kimi2; unverified]

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (expert hidden) vocab=163840,
MoE 384e top-8. Unverified tier: we follow the assigned table verbatim
(GQA attention, no MLA, no shared expert). At ~1T params this config only
fits a 256-chip v5e pod with heavy FSDP + low-precision optimizer state;
the dry-run memory analysis reports the honest per-chip bytes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    moe_d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    moe_period=1,
    act="silu",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-k2-1t-a32b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=96, moe_d_ff=96, vocab_size=256,
        num_experts=8, experts_per_token=2, remat="none",
    )
