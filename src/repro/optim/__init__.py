from repro.optim.optimizer import (AdamWConfig, AdamWState, SGDMConfig,
                                   SGDMState, adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_schedule,
                                   global_norm, sgdm_init, sgdm_update)
from repro.optim import compression

__all__ = [
    "AdamWConfig", "AdamWState", "SGDMConfig", "SGDMState", "adamw_init",
    "adamw_update", "clip_by_global_norm", "cosine_schedule", "global_norm",
    "sgdm_init", "sgdm_update", "compression",
]
