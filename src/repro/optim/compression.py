"""Error-feedback gradient compression for data-parallel sync
(distributed-optimization trick for 1000+-node scale; DESIGN.md §2).

Two codecs:
- int8 per-tensor-scale quantization (8x less all-reduce traffic in the
  `pod` axis where ICI/DCN bandwidth dominates),
- top-k magnitude sparsification (sends k values + indices).

Both keep a local error-feedback residual so compression error accumulates
into later steps instead of being lost (Karimireddy et al., 2019); the
residual pytree lives next to the optimizer state and is checkpointed.

These run *around* the cross-pod collective: compress -> all-reduce (or
psum inside shard_map) -> decompress. Semantics are validated in
tests/test_compression.py including the convergence-preserving property of
error feedback."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int8 with per-tensor scale


def int8_encode(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_compress_tree(grads, residual):
    """Returns (quantized tree, scales tree, new residual)."""
    def enc(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = int8_encode(gf)
        err = gf - int8_decode(q, s)
        return (q, s), err

    flat, treedef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residual)
    qs, errs = zip(*[enc(g, r) for g, r in zip(flat, rflat)])
    q_tree = jax.tree.unflatten(treedef, [q for q, _ in qs])
    s_tree = jax.tree.unflatten(treedef, [s for _, s in qs])
    r_tree = jax.tree.unflatten(treedef, list(errs))
    return q_tree, s_tree, r_tree


def int8_decompress_tree(q_tree, s_tree):
    return jax.tree.map(int8_decode, q_tree, s_tree)


def init_residual(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


# ---------------------------------------------------------------------------
# top-k sparsification


def topk_encode(x: jax.Array, frac: float = 0.01):
    xf = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(xf.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(xf), k)
    sel = xf[idx]
    return sel, idx, x.shape


def topk_decode(vals, idx, shape):
    out = jnp.zeros((int(jnp.prod(jnp.asarray(shape))),), jnp.float32)
    return out.at[idx].set(vals).reshape(shape)


def topk_compress_tree(grads, residual, frac: float = 0.01):
    def enc(g, r):
        gf = g.astype(jnp.float32) + r
        vals, idx, shape = topk_encode(gf, frac)
        err = gf - topk_decode(vals, idx, shape)
        return (vals, idx), err

    flat, treedef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residual)
    enc_out, errs = zip(*[enc(g, r) for g, r in zip(flat, rflat)])
    v_tree = jax.tree.unflatten(treedef, [v for v, _ in enc_out])
    i_tree = jax.tree.unflatten(treedef, [i for _, i in enc_out])
    r_tree = jax.tree.unflatten(treedef, list(errs))
    return v_tree, i_tree, r_tree
