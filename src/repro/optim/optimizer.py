"""Freeze-aware optimizers (AdamW, SGD-momentum) on raw pytrees.

Design points for the continual-learning setting:
- `masks`: a 0/1 multiplier pytree (from core.freeze_plan.grad_multiplier_tree
  or a custom mask). Frozen leaves keep params, m and v bit-identical —
  weight decay and momentum must not move a frozen layer (paper §II) — and
  their optimizer-state update math is skipped by XLA where the mask is a
  traced constant 0.
- `state_dtype`: bf16 moment storage for trillion-parameter configs
  (kimi-k2) where fp32 m/v alone would exceed pod HBM (DESIGN.md §4).
- global-norm clipping and a cosine-with-warmup schedule included.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    state_dtype: Optional[str] = None  # None = same as param


class _Out(tuple):
    """Sentinel so per-leaf result tuples are distinguishable from tuples
    that are part of the params pytree structure (e.g. params['blocks'])."""


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves) + 1e-30)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / norm)
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_init(params, config: AdamWConfig) -> AdamWState:
    def zeros_like(p):
        dt = jnp.dtype(config.state_dtype) if config.state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros_like, params),
                      v=jax.tree.map(zeros_like, params))


def adamw_update(grads, state: AdamWState, params, config: AdamWConfig,
                 lr_scale: jax.Array = 1.0, masks=None):
    """Returns (new_params, new_state). `masks` leaves broadcast against the
    param leaf (scalars or [G]-shaped per-group masks)."""
    if config.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, config.clip_norm)
    step = state.step + 1
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = config.lr * lr_scale

    def leaf_update(p, g, m, v, mask):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        m_new = b1 * mf + (1 - b1) * gf
        v_new = b2 * vf + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        upd = mhat / (jnp.sqrt(vhat) + config.eps)
        upd = upd + config.weight_decay * p.astype(jnp.float32)
        if mask is not None:
            mk = mask.astype(jnp.float32)
            if mk.ndim > 0 and mk.ndim < upd.ndim:
                mk = mk.reshape(mk.shape + (1,) * (upd.ndim - mk.ndim))
            upd = upd * mk
            m_new = jnp.where(mk > 0, m_new, mf)
            v_new = jnp.where(mk > 0, v_new, vf)
        p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return _Out((p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)))

    if masks is None:
        out = jax.tree.map(lambda p, g, m, v: leaf_update(p, g, m, v, None),
                           params, grads, state.m, state.v)
    else:
        out = jax.tree.map(leaf_update, params, grads, state.m, state.v, masks)
    def is_out(x):
        return isinstance(x, _Out)
    p_new = jax.tree.map(lambda o: o[0], out, is_leaf=is_out)
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=is_out)
    v_new = jax.tree.map(lambda o: o[2], out, is_leaf=is_out)
    return p_new, AdamWState(step=step, m=m_new, v=v_new)


# ---------------------------------------------------------------------------
# SGD momentum (lighter state; used for some edge experiments)


class SGDMState(NamedTuple):
    step: jax.Array
    mom: Any


@dataclass(frozen=True)
class SGDMConfig:
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 1e-4
    clip_norm: float = 0.0


def sgdm_init(params, config: SGDMConfig) -> SGDMState:
    return SGDMState(step=jnp.zeros((), jnp.int32),
                     mom=jax.tree.map(jnp.zeros_like, params))


def sgdm_update(grads, state: SGDMState, params, config: SGDMConfig,
                lr_scale: jax.Array = 1.0, masks=None):
    if config.clip_norm:
        grads, _ = clip_by_global_norm(grads, config.clip_norm)
    lr = config.lr * lr_scale

    def leaf(p, g, m, mask):
        gf = g.astype(jnp.float32) + config.weight_decay * p.astype(jnp.float32)
        m_new = config.momentum * m.astype(jnp.float32) + gf
        upd = m_new
        if mask is not None:
            mk = mask.astype(jnp.float32)
            if mk.ndim > 0 and mk.ndim < upd.ndim:
                mk = mk.reshape(mk.shape + (1,) * (upd.ndim - mk.ndim))
            upd = upd * mk
            m_new = jnp.where(mk > 0, m_new, m.astype(jnp.float32))
        return _Out(((p.astype(jnp.float32) - lr * upd).astype(p.dtype),
                      m_new.astype(m.dtype)))

    if masks is None:
        out = jax.tree.map(lambda p, g, m: leaf(p, g, m, None),
                           params, grads, state.mom)
    else:
        out = jax.tree.map(leaf, params, grads, state.mom, masks)
    def is_out(x):
        return isinstance(x, _Out)
    p_new = jax.tree.map(lambda o: o[0], out, is_leaf=is_out)
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=is_out)
    return p_new, SGDMState(step=state.step + 1, mom=m_new)


# ---------------------------------------------------------------------------
# schedule


def cosine_schedule(step, *, base_lr=1.0, warmup: int = 100,
                    total: int = 10_000, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
