"""The three physical sub-models a `DeviceEnv` steps (DESIGN.md §15).

All three are plain mutable state machines on the *modeled* timeline —
no jax, no randomness, a handful of floats each — so a fleet of hundreds
of env-enabled devices costs nothing measurable per dispatch. The exact
discrete RC solution (not an Euler step) keeps `ThermalModel.step`
unconditionally stable for any `dt`, which matters because env steps are
driven by the event scheduler and their spacing is arbitrary.
"""
from __future__ import annotations

import math
from typing import Tuple


class BatteryModel:
    """A charge reservoir in joules. `drain` mirrors ledger energy
    charges one-to-one (the conservation test pins ``drained_j`` against
    per-device ledger energy exactly); `harvest` refills at a constant
    rate over modeled time, clamped to capacity."""

    def __init__(self, capacity_j: float, *, harvest_w: float = 0.0,
                 reserve_frac: float = 0.05):
        self.capacity_j = float(capacity_j)
        self.harvest_w = float(harvest_w)
        self.reserve_frac = float(reserve_frac)
        self.charge_j = float(capacity_j)
        self.drained_j = 0.0
        self.harvested_j = 0.0

    def drain(self, energy_j: float) -> None:
        self.drained_j += energy_j
        self.charge_j -= energy_j

    def harvest(self, dt: float) -> None:
        if self.harvest_w <= 0.0 or dt <= 0.0:
            return
        gain = min(self.harvest_w * dt,
                   max(self.capacity_j - self.charge_j, 0.0))
        self.harvested_j += gain
        self.charge_j += gain

    @property
    def soc(self) -> float:
        """State of charge in [0, 1] (clamped — overdrawn reads as 0)."""
        return min(max(self.charge_j / self.capacity_j, 0.0), 1.0)

    @property
    def dead(self) -> bool:
        return self.charge_j <= self.reserve_frac * self.capacity_j


class ThermalModel:
    """First-order RC node above ambient. Each step applies the exact
    discrete solution for a constant power `P` over `dt` seconds::

        T' = T_amb + P·R + (T − T_amb − P·R) · exp(−dt/τ)

    so the temperature relaxes monotonically toward the steady state
    ``T_amb + P·R`` regardless of step size."""

    def __init__(self, *, ambient_c: float, resistance_c_per_w: float,
                 time_constant_s: float):
        self.ambient_c = float(ambient_c)
        self.resistance_c_per_w = float(resistance_c_per_w)
        self.time_constant_s = float(time_constant_s)
        self.temp_c = float(ambient_c)

    def step(self, power_w: float, dt: float) -> float:
        if dt > 0.0:
            target = self.ambient_c + power_w * self.resistance_c_per_w
            decay = math.exp(-dt / self.time_constant_s)
            self.temp_c = target + (self.temp_c - target) * decay
        return self.temp_c


class DvfsGovernor:
    """Discrete frequency governor: temperature at or above `cap_c`
    steps one level down the (descending) `levels` ladder; cooling to
    ``cap_c − hysteresis_c`` steps back up. `cap_c <= 0` disables the
    governor (always level 1.0)."""

    def __init__(self, levels: Tuple[float, ...], *, cap_c: float,
                 hysteresis_c: float = 5.0):
        self.levels = tuple(levels)
        self.cap_c = float(cap_c)
        self.hysteresis_c = float(hysteresis_c)
        self.index = 0
        self.transitions = 0

    @property
    def level(self) -> float:
        return self.levels[self.index]

    def update(self, temp_c: float) -> float:
        if self.cap_c > 0.0:
            if temp_c >= self.cap_c and self.index < len(self.levels) - 1:
                self.index += 1
                self.transitions += 1
            elif (temp_c <= self.cap_c - self.hysteresis_c
                  and self.index > 0):
                self.index -= 1
                self.transitions += 1
        return self.level
