"""repro.env — per-device physical environment models (DESIGN.md §15).

Makes energy a first-class *constraint* instead of a ledger column:
each fleet device may carry an `EnvSpec` (on its `DeviceConfig`) that
instantiates a `DeviceEnv` — a battery drained by the device's ledger
charges, a first-order thermal RC node driven by its average power, and
a DVFS governor that rescales the device's cost model under a thermal
cap. The `ThrottlePolicy` facet of the PolicyStack reads `EnvState`
snapshots to defer or skip fine-tune rounds; battery-dead devices
degrade into the fleet's straggler evict + reroute path.

Everything is off by default: no env (or an inactive spec) means no
state, no observer, no branches taken — bit-exact with every seed-era
run, which the golden regression pins.
"""
from repro.env.models import BatteryModel, DvfsGovernor, ThermalModel
from repro.env.runtime import DeviceEnv, EnvLedgerObserver, EnvState
from repro.env.spec import EnvSpec

__all__ = ["BatteryModel", "DeviceEnv", "DvfsGovernor", "EnvLedgerObserver",
           "EnvSpec", "EnvState", "ThermalModel"]
