"""DeviceEnv — the live per-device environment one fleet device carries.

The env is fed from two sides of the runtime (DESIGN.md §15):

- **energy in**: `EnvLedgerObserver` sits in the `CostLedger`'s single
  observer slot (wrapping the session `Telemetry`, when one is active)
  and routes every charge's joules to the owning device's env — the
  battery drains at the exact instant the ledger accounts the energy, so
  battery conservation against per-device ledger energy is an identity.
- **time in**: `DeviceFleet._step_envs` advances every env to the
  scheduler's current time at each dispatch. A step converts the energy
  accumulated since the previous step into an average power, drives the
  thermal RC node with it, applies harvest, and lets the DVFS governor
  pick a frequency level. The fleet then rescales throttled devices'
  `EdgeCostModel`s via `scale_cost` and consults the `ThrottlePolicy`
  before triggering fine-tune rounds.

A device with no (or an inactive) `EnvSpec` carries ``env = None`` and
every hot path short-circuits on that — the disabled run allocates
nothing and stays bit-exact, which the golden regression pins.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.env.models import BatteryModel, DvfsGovernor, ThermalModel
from repro.env.spec import EnvSpec
from repro.obs.trace import NULL_TRACER


@dataclass(frozen=True)
class EnvState:
    """The read-only env snapshot a `ThrottlePolicy` sees per decision.
    Battery fields are ``None`` on mains-powered (thermal-only) envs."""
    device: str
    temperature_c: float
    level: float                       # current DVFS speed multiplier
    soc: Optional[float] = None        # state of charge in [0, 1]
    charge_j: Optional[float] = None   # joules remaining (may be < reserve)
    reserve_j: float = 0.0             # dead-threshold joules
    battery_dead: bool = False


class DeviceEnv:
    """Live environment state for one device (module docstring)."""

    def __init__(self, spec: EnvSpec, device: str, *, tracer=NULL_TRACER):
        self.spec = spec
        self.device = device
        self.tracer = tracer
        self.battery: Optional[BatteryModel] = None
        if spec.battery_capacity_j > 0:
            self.battery = BatteryModel(
                spec.battery_capacity_j, harvest_w=spec.harvest_w,
                reserve_frac=spec.battery_reserve_frac)
        self.thermal = ThermalModel(
            ambient_c=spec.ambient_c,
            resistance_c_per_w=spec.thermal_resistance_c_per_w,
            time_constant_s=spec.thermal_time_constant_s)
        self.dvfs = DvfsGovernor(spec.dvfs_levels, cap_c=spec.thermal_cap_c,
                                 hysteresis_c=spec.dvfs_hysteresis_c)
        self.level = 1.0
        self.throttle_s = 0.0          # modeled seconds spent below 1.0x
        self._last_step = 0.0
        self._energy_acc = 0.0         # joules since the previous step
        self._last_gauge = float("-inf")
        self._throttle_start: Optional[float] = None
        self._throttle_min = 1.0

    # ---- energy in (EnvLedgerObserver) -----------------------------------
    def on_energy(self, energy_j: float) -> None:
        if self.battery is not None:
            self.battery.drain(energy_j)
        self._energy_acc += energy_j

    # ---- time in (DeviceFleet._step_envs) --------------------------------
    def step(self, now: float) -> float:
        """Advance the physics to `now`; returns the DVFS level in force
        from `now` on. Idempotent for non-advancing timestamps."""
        dt = now - self._last_step
        if dt <= 0.0:
            return self.level
        if self.level < 1.0:
            self.throttle_s += dt
        power_w = self._energy_acc / dt
        self._energy_acc = 0.0
        self.thermal.step(power_w, dt)
        if self.battery is not None:
            self.battery.harvest(dt)
        level = self.dvfs.update(self.thermal.temp_c)
        if level != self.level:
            self._note_transition(level, now)
        self.level = level
        self._last_step = now
        if self.tracer and now - self._last_gauge >= self.spec.gauge_period_s:
            self._emit_gauges(now)
        return self.level

    def finalize(self, now: float) -> None:
        """Run-end bookkeeping: a last physics step, the closing gauge
        sample and the tail of any open throttle span."""
        self.step(now)
        if self._throttle_start is not None:
            self._close_throttle_span(now)
        if self.tracer and now > self._last_gauge:
            self._emit_gauges(now)

    # ---- state out (ThrottlePolicy / fleet) ------------------------------
    def state(self) -> EnvState:
        b = self.battery
        return EnvState(
            device=self.device, temperature_c=self.thermal.temp_c,
            level=self.level,
            soc=None if b is None else b.soc,
            charge_j=None if b is None else b.charge_j,
            reserve_j=0.0 if b is None else b.reserve_frac * b.capacity_j,
            battery_dead=False if b is None else b.dead)

    @property
    def battery_dead(self) -> bool:
        return self.battery is not None and self.battery.dead

    # ---- trace emission --------------------------------------------------
    def _note_transition(self, level: float, now: float) -> None:
        if level < 1.0 and self._throttle_start is None:
            self._throttle_start = now
            self._throttle_min = level
        elif level < 1.0:
            self._throttle_min = min(self._throttle_min, level)
        elif self._throttle_start is not None:
            self._close_throttle_span(now)

    def _close_throttle_span(self, now: float) -> None:
        if self.tracer:
            self.tracer.span("throttle", f"dvfs x{self._throttle_min:g}",
                             self._throttle_start,
                             now - self._throttle_start, device=self.device,
                             min_level=self._throttle_min)
        self._throttle_start = None
        self._throttle_min = 1.0

    def _emit_gauges(self, now: float) -> None:
        self._last_gauge = now
        t = self.tracer
        t.instant("gauge", f"temperature_c/{self.device}", now,
                  device=self.device, value=self.thermal.temp_c)
        if self.battery is not None:
            t.instant("gauge", f"soc/{self.device}", now, device=self.device,
                      value=self.battery.soc)


class EnvLedgerObserver:
    """The `CostLedger` observer installed when at least one device has
    an active env: routes every charge's energy to the owning device's
    battery/thermal accumulator, then delegates each hook verbatim to the
    session `Telemetry` (or swallows it when telemetry is off). Installed
    only when needed — env-less runs keep the ledger untouched."""

    def __init__(self, envs: Dict[str, DeviceEnv], inner=None):
        self.envs = envs
        self.inner = inner

    def on_charge(self, *, time_s: float, energy_j: float, flops: float,
                  stream: int, model: str, device: str,
                  kind: str = "round") -> None:
        env = self.envs.get(device)
        if env is not None and energy_j:
            env.on_energy(energy_j)
        if self.inner is not None:
            self.inner.on_charge(time_s=time_s, energy_j=energy_j,
                                 flops=flops, stream=stream, model=model,
                                 device=device, kind=kind)

    def on_round(self, *, stream: int, model: str, device: str) -> None:
        if self.inner is not None:
            self.inner.on_round(stream=stream, model=model, device=device)

    def on_preemption(self, *, stream: int) -> None:
        if self.inner is not None:
            self.inner.on_preemption(stream=stream)

    def on_swap(self, *, model: str, device: str) -> None:
        if self.inner is not None:
            self.inner.on_swap(model=model, device=device)

    def on_sync(self, *, device: str) -> None:
        if self.inner is not None:
            self.inner.on_sync(device=device)
