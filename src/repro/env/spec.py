"""EnvSpec — the declarative physical-environment knob on `DeviceConfig`.

Serializable like every other config piece (strict `to_dict`/`from_dict`
round trip, unknown keys raise listing the valid set). The default spec
is inactive: no battery, no thermal cap — the runtime takes the legacy
bit-exact path with zero env allocations. A positive battery capacity or
thermal cap activates it::

    DeviceConfig("dev1", env=EnvSpec(battery_capacity_j=500.0,
                                     thermal_cap_c=70.0))

The three physical sub-models the spec parameterizes (DESIGN.md §15):

- **battery**: a charge reservoir of `battery_capacity_j` joules drained
  by every `CostLedger` energy charge attributed to the device, optionally
  refilled at `harvest_w` watts of modeled time (solar/kinetic harvest).
  The device counts as *dead* — and degrades into the fleet's straggler
  evict + reroute path — once state-of-charge falls to
  `battery_reserve_frac`; the reserve keeps the small un-gateable charges
  (probes, CKA, sync participation) from overdrawing the budget.
- **thermal**: a first-order RC node. Average power over each env step
  drives the exact discrete solution
  ``T' = T_amb + P·R + (T − T_amb − P·R)·exp(−dt/τ)`` with
  `thermal_resistance_c_per_w` (R) and `thermal_time_constant_s` (τ)
  above `ambient_c`.
- **dvfs**: discrete frequency states `dvfs_levels` (descending speed
  multipliers, level 0 = 1.0 nominal). Temperature at or above
  `thermal_cap_c` steps one level down; cooling below
  ``cap − dvfs_hysteresis_c`` steps back up. A level L rescales the
  device's `EdgeCostModel` via `scale_cost(speed=L,
  energy=L**dvfs_power_exponent)` — slower but cooler per unit work
  whenever the exponent exceeds 1 (dynamic power ~ f·V² ≈ f³; the
  default 2.0 is conservative).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

#: Fields with non-trivial defaults that `to_dict` omits when unchanged.
_DEFAULTS = dict(battery_capacity_j=0.0, harvest_w=0.0,
                 battery_reserve_frac=0.05, ambient_c=25.0,
                 thermal_resistance_c_per_w=2.0, thermal_time_constant_s=30.0,
                 thermal_cap_c=0.0, dvfs_levels=(1.0, 0.75, 0.5),
                 dvfs_hysteresis_c=5.0, dvfs_power_exponent=2.0,
                 gauge_period_s=5.0)


@dataclass(frozen=True)
class EnvSpec:
    """Physical-environment configuration (module docstring).

    - `battery_capacity_j`: battery budget in joules (0 = mains-powered,
      no battery model);
    - `harvest_w`: recharge rate in watts of modeled time (0 = none);
    - `battery_reserve_frac`: state-of-charge at which the device counts
      as dead and is evicted from the fleet;
    - `ambient_c`: thermal ambient the device cools toward;
    - `thermal_resistance_c_per_w` / `thermal_time_constant_s`: the RC
      node (steady-state °C per watt, and seconds to ~63% of a step);
    - `thermal_cap_c`: DVFS throttling threshold (0 = no governor);
    - `dvfs_levels`: descending speed multipliers, first must be 1.0;
    - `dvfs_hysteresis_c`: cooling margin below the cap before the
      governor steps frequency back up;
    - `dvfs_power_exponent`: power ~ level**exponent (>1 = throttling
      saves energy per unit work);
    - `gauge_period_s`: minimum modeled seconds between temperature/SoC
      gauge samples in the telemetry trace.
    """
    battery_capacity_j: float = 0.0
    harvest_w: float = 0.0
    battery_reserve_frac: float = 0.05
    ambient_c: float = 25.0
    thermal_resistance_c_per_w: float = 2.0
    thermal_time_constant_s: float = 30.0
    thermal_cap_c: float = 0.0
    dvfs_levels: Tuple[float, ...] = (1.0, 0.75, 0.5)
    dvfs_hysteresis_c: float = 5.0
    dvfs_power_exponent: float = 2.0
    gauge_period_s: float = 5.0

    @property
    def active(self) -> bool:
        """Whether the env constrains anything: a finite battery budget
        or a thermal cap. Inactive specs build no runtime state at all —
        the device behaves exactly as if it had no env."""
        return bool(self.battery_capacity_j > 0 or self.thermal_cap_c > 0)

    def validate(self, context: str = "env") -> "EnvSpec":
        for fname in ("battery_capacity_j", "harvest_w", "ambient_c",
                      "thermal_cap_c", "dvfs_hysteresis_c"):
            v = getattr(self, fname)
            if not isinstance(v, (int, float)) or v < 0:
                raise ValueError(f"{context}: {fname} must be a "
                                 f"non-negative number (got {v!r})")
        for fname in ("thermal_resistance_c_per_w", "thermal_time_constant_s",
                      "dvfs_power_exponent", "gauge_period_s"):
            v = getattr(self, fname)
            if not isinstance(v, (int, float)) or v <= 0:
                raise ValueError(f"{context}: {fname} must be a positive "
                                 f"number (got {v!r})")
        if not 0.0 <= self.battery_reserve_frac < 1.0:
            raise ValueError(f"{context}: battery_reserve_frac must be in "
                             f"[0, 1) (got {self.battery_reserve_frac!r})")
        levels = self.dvfs_levels
        if (not isinstance(levels, tuple) or not levels
                or levels[0] != 1.0
                or any(not isinstance(v, (int, float)) or not 0 < v <= 1.0
                       for v in levels)
                or list(levels) != sorted(levels, reverse=True)):
            raise ValueError(f"{context}: dvfs_levels must be a descending "
                             f"tuple of speed multipliers in (0, 1] starting "
                             f"at 1.0 (got {levels!r})")
        return self

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for fname, default in _DEFAULTS.items():
            v = getattr(self, fname)
            if v != default:
                out[fname] = list(v) if isinstance(v, tuple) else v
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EnvSpec":
        if not isinstance(d, dict):
            raise ValueError(f"an env spec must be a dict (got {d!r})")
        unknown = set(d) - set(_DEFAULTS)
        if unknown:
            raise ValueError(f"env spec: unknown key(s) {sorted(unknown)}; "
                             f"valid: {sorted(_DEFAULTS)}")
        kw = dict(d)
        if "dvfs_levels" in kw:
            levels = kw["dvfs_levels"]
            if not isinstance(levels, (list, tuple)):
                raise ValueError(f"env spec: dvfs_levels must be a list "
                                 f"(got {levels!r})")
            kw["dvfs_levels"] = tuple(float(v) for v in levels)
        return cls(**kw).validate()
