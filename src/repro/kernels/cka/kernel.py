"""Pallas TPU kernel for the CKA Gram terms.

Computes (hsic, kk, ll) for row-centered X, Y [n, d] without ever
materializing the n x n Gram matrices in HBM: the grid tiles the Gram into
(bn x bn) blocks; each block is accumulated over the feature dim in
bk-chunks inside VMEM scratch (MXU-aligned tiles), then squared /
cross-multiplied and reduced into three (1,1) outputs that every grid step
revisits (sequential TPU grid semantics).

VMEM budget per step: 4 x (bn x bk) input tiles + 2 x (bn x bn) f32
accumulators ≈ 1.2 MB at the default bn=128, bk=512 — well inside the
~16 MB/core VMEM envelope, with the contraction dim >= 128 for the MXU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cka_kernel(xi_ref, xj_ref, yi_ref, yj_ref, hsic_ref, kk_ref, ll_ref,
                k_acc, l_acc, *, nk: int):
    i, j, kstep = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        k_acc[...] = jnp.zeros_like(k_acc)
        l_acc[...] = jnp.zeros_like(l_acc)

    @pl.when((i == 0) & (j == 0) & (kstep == 0))
    def _zero_outputs():
        hsic_ref[...] = jnp.zeros_like(hsic_ref)
        kk_ref[...] = jnp.zeros_like(kk_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    xi = xi_ref[...].astype(jnp.float32)
    xj = xj_ref[...].astype(jnp.float32)
    yi = yi_ref[...].astype(jnp.float32)
    yj = yj_ref[...].astype(jnp.float32)
    k_acc[...] += jax.lax.dot_general(xi, xj, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    l_acc[...] += jax.lax.dot_general(yi, yj, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)

    @pl.when(kstep == nk - 1)
    def _reduce():
        kt = k_acc[...]
        lt = l_acc[...]
        hsic_ref[0, 0] += jnp.sum(kt * lt)
        kk_ref[0, 0] += jnp.sum(kt * kt)
        ll_ref[0, 0] += jnp.sum(lt * lt)


def cka_terms_pallas(x: jax.Array, y: jax.Array, *, bn: int = 128,
                     bk: int = 512, interpret: bool = True):
    """x, y: [n, d] row-centered (ops.py pads/centers). -> (hsic, kk, ll)."""
    n, d = x.shape
    assert y.shape == (n, d), (x.shape, y.shape)
    assert n % bn == 0 and d % bk == 0, (n, d, bn, bk)
    ni, nk = n // bn, d // bk
    grid = (ni, ni, nk)

    def row_block(i, j, k):
        return (i, k)

    def col_block(i, j, k):
        return (j, k)

    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))

    hsic, kk, ll = pl.pallas_call(
        functools.partial(_cka_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), row_block),
            pl.BlockSpec((bn, bk), col_block),
            pl.BlockSpec((bn, bk), row_block),
            pl.BlockSpec((bn, bk), col_block),
        ],
        out_specs=[scalar_spec, scalar_spec, scalar_spec],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32),
                        pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(x, x, y, y)
    return hsic[0, 0], kk[0, 0], ll[0, 0]
