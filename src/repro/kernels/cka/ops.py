"""Jitted wrapper for the CKA Gram-term kernel: centering, padding to tile
multiples, and the CKA ratio. `interpret=True` on CPU (kernel-body
semantics validated against ref.py); on TPU pass interpret=False."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.cka.kernel import cka_terms_pallas


def _prepare(x: jax.Array, bn: int, bk: int) -> jax.Array:
    x = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
    x = x.astype(jnp.float32)
    x = x - x.mean(axis=0, keepdims=True)
    n, d = x.shape
    pn = (-n) % bn
    pd = (-d) % bk
    if pn or pd:
        x = jnp.pad(x, ((0, pn), (0, pd)))  # zero rows/cols don't change Grams
    return x


@partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def cka_terms(x: jax.Array, y: jax.Array, bn: int = 128, bk: int = 512,
              interpret: bool = True):
    """Returns (hsic, sqrt(kk), sqrt(ll)) matching core.cka conventions."""
    xp = _prepare(x, bn, bk)
    yp = _prepare(y, bn, bk)
    # pad feature dims to a common width (zero features are Gram-neutral)
    d = max(xp.shape[1], yp.shape[1])
    xp = jnp.pad(xp, ((0, 0), (0, d - xp.shape[1])))
    yp = jnp.pad(yp, ((0, 0), (0, d - yp.shape[1])))
    n = max(xp.shape[0], yp.shape[0])
    xp = jnp.pad(xp, ((0, n - xp.shape[0]), (0, 0)))
    yp = jnp.pad(yp, ((0, n - yp.shape[0]), (0, 0)))
    hsic, kk, ll = cka_terms_pallas(xp, yp, bn=bn, bk=bk, interpret=interpret)
    return hsic, jnp.sqrt(kk), jnp.sqrt(ll)


def cka(x: jax.Array, y: jax.Array, bn: int = 128, bk: int = 512,
        interpret: bool = True) -> jax.Array:
    hsic, nx, ny = cka_terms(x, y, bn=bn, bk=bk, interpret=interpret)
    return hsic / jnp.maximum(nx * ny, 1e-12)
