"""Pure-jnp oracle for the CKA Gram-term kernel.

Given row-centered feature matrices X [n, d], Y [n, d] the kernel returns
(hsic, kk, ll) with
    hsic = <X X^T, Y Y^T>_F   (== ||Y^T X||_F^2)
    kk   = ||X X^T||_F^2      (== ||X^T X||_F^2)
    ll   = ||Y Y^T||_F^2
so CKA = hsic / sqrt(kk * ll)."""
from __future__ import annotations

import jax.numpy as jnp


def cka_terms_ref(x: jnp.ndarray, y: jnp.ndarray):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    k = x @ x.T
    l = y @ y.T
    hsic = jnp.sum(k * l)
    kk = jnp.sum(k * k)
    ll = jnp.sum(l * l)
    return hsic, kk, ll


def cka_ref(x, y):
    hsic, kk, ll = cka_terms_ref(x, y)
    return hsic / jnp.maximum(jnp.sqrt(kk) * jnp.sqrt(ll), 1e-12)
