"""Flash-attention forward Pallas TPU kernel (causal, sliding-window,
logit-softcap, GQA).

Grid: (B * Hq, nQ, nK) with the kv axis innermost ("arbitrary"/sequential
on TPU) so the online-softmax running state (acc, m, l) lives in VMEM
scratch across kv steps. Blocks:
  q:   (1, bq, hd)  indexed (b*Hq + h, iq)      from [B*Hq, Sq, hd]
  k/v: (1, bk, hd)  indexed (b*Hkv + h//g, ik)  from [B*Hkv, Sk, hd]
  o:   (1, bq, hd)  written at ik == nK-1
VMEM per step ≈ bq*hd + 2*bk*hd + bq*hd(acc) + 2*bq  floats — with
bq=bk=512, hd=128 that's ~0.9 MB, MXU-aligned (hd multiple of 128).
Fully-masked kv blocks (beyond the causal diagonal / outside the sliding
window) are skipped with pl.when — same static-band saving the XLA
blockwise path exploits (models/attention.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int,
                  softcap: float, scale: float):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_lo = iq * bq
    k_lo = ik * bk
    # live unless entirely above the diagonal or below the window band
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + bq - 1
    if window:
        live &= (k_lo + bk - 1) >= (q_lo - window + 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.bool_(True)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        denom = jnp.maximum(l_s[...], 1e-30)[:, None]
        o_ref[0] = (acc[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, bq: int = 512, bk: int = 512,
                           interpret: bool = True):
    """q: [B, Sq, Hq, hd]; k/v: [B, Sk, Hkv, hd] -> [B, Sq, Hq, hd]."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / (hd ** 0.5)

    qf = jnp.moveaxis(q, 2, 1).reshape(B * Hq, Sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Sk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Sk, hd)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // g, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window, softcap=softcap, scale=scale),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, Hq, Sq, hd), 1, 2)
