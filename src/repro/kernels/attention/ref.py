"""Pure-jnp oracle for the flash-attention kernel: dense causal attention
with optional sliding window, logit softcap and GQA."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: [B, Sq, Hq, hd]; k, v: [B, Sk, Hkv, hd]; Hq % Hkv == 0.
    Returns [B, Sq, Hq, hd] (fp32)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) / jnp.sqrt(jnp.float32(hd))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return out.reshape(B, Sq, Hq, hd)
