"""Jitted wrapper for the flash-attention kernel with shape padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 512, bk: int = 512,
                    interpret: bool = True):
    """Padding-safe wrapper: pads Sq/Sk up to block multiples (padded kv
    positions are masked out by the causal test since they sit beyond the
    real sequence)."""
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    bq = min(bq, max(Sq, 1))
    bk = min(bk, max(Sk, 1))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, bq=bq, bk=bk,
                                 interpret=interpret)
    return out[:, :Sq]
