"""Pure-jnp oracle for the WKV6 recurrence (exact sequential scan).

    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with per-channel data-dependent decay w_t = exp(logw_t)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, logw, u, s0=None):
    """r, k, v, logw: [B, T, H, n] float32; u: [H, n].
    Returns (o [B, T, H, n], s_final [B, H, n, n])."""
    B, T, H, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, n, n), jnp.float32)

    def step(S, inputs):
        rt, kt, vt, lwt = inputs
        # output: r . (S + u*k v^T)
        o = jnp.einsum("bhn,bhnm->bhm", rt, S) \
            + jnp.einsum("bhn,bhn,bhm->bhm", rt * u, kt, vt)
        S_new = jnp.exp(lwt)[..., None] * S + jnp.einsum("bhn,bhm->bhnm", kt, vt)
        return S_new, o

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, logw))
    s_fin, os_ = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(os_, 0, 1), s_fin
