"""WKV6 Pallas TPU kernel: exact recurrence with the [n, n] state resident
in VMEM.

The CUDA wkv6 kernel keeps the per-head state in registers/shared memory
and streams tokens; the TPU adaptation keeps S in VMEM scratch and streams
the sequence through in (1, bt, n) blocks: grid (B*H, nT) with the time
axis sequential, so S persists across time-blocks without ever touching
HBM — only r/k/v/w blocks stream in and o blocks stream out. Inside a
block a fori_loop applies the exact per-token update (no decay-product
approximation — this kernel is the *exact* path; the XLA chunked closed
form in models/rwkv6.py clamps log-decay products, see its docstring).

VMEM per step: 4 x (bt x n) inputs + (bt x n) output + (n x n) state ≈
5*512*64*4 + 64*64*4 ≈ 0.7 MB at bt=512, n=64."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                bt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)    # [bt, n]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)    # [1, n] -> broadcast

    def step(t, carry):
        S, o_acc = carry                 # S: [n, n]; o_acc: [bt, n]
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)     # [1, n]
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        wt = jnp.exp(jax.lax.dynamic_slice_in_dim(lw, t, 1, 0))  # [1, n]
        kv = kt.T @ vt                                    # [n, n]
        o_t = rt @ (S + u.reshape(1, -1).T * kv)          # [1, n]
        S = wt.T * S + kv
        o_acc = jax.lax.dynamic_update_slice_in_dim(o_acc, o_t, t, 0)
        return S, o_acc

    S, o = jax.lax.fori_loop(0, bt, step,
                             (s_scr[...], jnp.zeros((bt, r.shape[1]),
                                                    jnp.float32)))
    s_scr[...] = S
    o_ref[0] = o.astype(o_ref.dtype)


def wkv_pallas(r, k, v, logw, u, *, bt: int = 512, interpret: bool = True):
    """r/k/v/logw: [B, T, H, n]; u: [H, n]. Returns o [B, T, H, n] fp32."""
    B, T, H, n = r.shape
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    nt = T // bt

    def flat(a):
        return jnp.moveaxis(a, 2, 1).reshape(B * H, T, n)

    rf, kf, vf, lwf = map(flat, (r, k, v, logw))

    def seq_map(bh, it):
        return (bh, it, 0)

    def u_map(bh, it):
        return (bh % H, 0)

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, bt=bt),
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, bt, n), seq_map),
            pl.BlockSpec((1, bt, n), seq_map),
            pl.BlockSpec((1, bt, n), seq_map),
            pl.BlockSpec((1, bt, n), seq_map),
            pl.BlockSpec((1, n), u_map),
        ],
        out_specs=pl.BlockSpec((1, bt, n), seq_map),
        out_shape=jax.ShapeDtypeStruct((B * H, T, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, u)
    return jnp.moveaxis(out.reshape(B, H, T, n), 1, 2)
