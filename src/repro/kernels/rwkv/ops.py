"""Jitted wrapper for the WKV6 kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rwkv.kernel import wkv_pallas


@partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv(r, k, v, logw, u, bt: int = 512, interpret: bool = True):
    """Pads T to a block multiple; padded tokens have w=1 (logw=0), k=0 so
    the state and real outputs are untouched."""
    B, T, H, n = r.shape
    bt = min(bt, max(T, 1))
    pt = (-T) % bt
    if pt:
        pad = ((0, 0), (0, pt), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)
    out = wkv_pallas(r, k, v, logw, u, bt=bt, interpret=interpret)
    return out[:, :T]
