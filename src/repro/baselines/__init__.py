"""SOTA efficient-training baselines the paper compares against (§V-C,
Tables V & VII). All expose the ETunerController event API so they plug
into runtime/continual.py unchanged:

- StaticController     — fixed-interval lazy tuning (Table VII S1..S4)
- EgeriaController     — knowledge-guided *module* freezing, strictly
                         front-to-back (Wang et al., EuroSys'23)
- SlimFitController    — weight-update-magnitude freezing (Ardakani'23)
- RigLController       — sparse training w/ magnitude-drop/gradient-regrow
                         (Evci et al., ICML'20)
- EkyaController       — fixed-window scheduling + trial-and-error config
                         search (Bhardwaj et al., NSDI'22)

Each can be combined with LazyTune (the paper integrates its inter-tuning
optimization into every baseline for Table V) via `with_lazytune=True`.
"""
from repro.baselines.controllers import (EgeriaController, EkyaController,
                                         RigLController, SlimFitController,
                                         StaticController)

__all__ = ["StaticController", "EgeriaController", "SlimFitController",
           "RigLController", "EkyaController"]
