"""Baseline controllers (see package docstring). Simplified but faithful
to each method's *scheduling decision*; simplifications are noted inline
and in DESIGN.md."""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.core.cka import cka as _cka
from repro.core.freeze_plan import LayerFreezePlan
from repro.core.lazytune import LazyTune, LazyTuneConfig


class _Base:
    """Shared plumbing: optional LazyTune integration (paper Table V runs
    every baseline on top of LazyTune). Implements the runtime's
    `repro.core.ControllerProtocol` — baselines differ only in how they
    answer `should_trigger` and evolve `plan` in `round_finished`."""

    def __init__(self, model, with_lazytune: bool = False):
        self.model = model
        self.with_lazytune = with_lazytune
        self.lazytune = LazyTune(LazyTuneConfig())
        self.n_units = model.num_freeze_units
        self._plan = LayerFreezePlan(layers=(False,) * self.n_units)
        self.flops_scale = 1.0

    @property
    def plan(self):
        return self._plan

    def should_trigger(self, batches_available: int,
                       staleness: float = 0.0,
                       priority: int = 0) -> bool:
        # `staleness` / `priority` (see repro.core.ControllerProtocol) are
        # accepted protocol-wide; the paper baselines don't weigh them.
        if self.with_lazytune:
            return self.lazytune.should_trigger(batches_available)
        return batches_available >= 1

    def round_finished(self, iters: int, val_acc: float, params) -> None:
        if self.with_lazytune:
            self.lazytune.round_finished(iters, val_acc)

    def inference_served(self, logits) -> bool:
        if self.with_lazytune:
            self.lazytune.inference_arrived()
        return False

    def scenario_changed(self, params, probe) -> None:
        if self.with_lazytune:
            self.lazytune.scenario_changed()

    def start_scenario(self, reference_params, probe) -> None:
        pass

    def stats(self) -> dict:
        return {"frozen_fraction": sum(self._plan.layers) / self.n_units,
                "rounds_triggered": self.lazytune.state.rounds_triggered,
                "batches_needed": self.lazytune.state.batches_needed}


class StaticController(_Base):
    """Table VII S1..S4: trigger a round every `interval` data batches."""

    def __init__(self, model, interval: int = 5):
        super().__init__(model, with_lazytune=False)
        self.interval = interval

    def should_trigger(self, batches_available: int,
                       staleness: float = 0.0,
                       priority: int = 0) -> bool:
        return batches_available >= self.interval


class EgeriaController(_Base):
    """Egeria: layers grouped into modules; a module freezes only when all
    earlier modules are frozen AND its reference-model similarity has
    stabilized (strict front-to-back — the rigidity ETuner beats)."""

    def __init__(self, model, with_lazytune: bool = True,
                 module_size: int = 2, threshold: float = 0.01,
                 interval: int = 8):
        super().__init__(model, with_lazytune)
        self.module_size = module_size
        self.threshold = threshold
        self.interval = interval
        self._iters = 0
        self.reference_params = None
        self.probe = None
        self._hist: List[List[float]] = []

    def start_scenario(self, reference_params, probe) -> None:
        self.reference_params = reference_params
        self.probe = probe
        self._ref_feats = [np.asarray(f, np.float32)
                           for f in self.model.features(reference_params, probe)]
        self._hist = [[] for _ in range(self.n_units)]

    def round_finished(self, iters, val_acc, params) -> None:
        super().round_finished(iters, val_acc, params)
        if self.probe is None:
            return
        self._iters += iters
        if self._iters < self.interval:
            return
        self._iters = 0
        feats = self.model.features(params, self.probe)
        flags = list(self._plan.layers)
        n_modules = (self.n_units + self.module_size - 1) // self.module_size
        for m in range(n_modules):
            lo, hi = m * self.module_size, min((m + 1) * self.module_size,
                                               self.n_units)
            if all(flags[lo:hi]):
                continue
            # front-to-back: all previous modules must already be frozen
            if m > 0 and not all(flags[:lo]):
                break
            stable = True
            for i in range(lo, hi):
                v = float(_cka(feats[i], self._ref_feats[i]))
                self._hist[i].append(v)
                h = self._hist[i]
                if len(h) < 2 or abs(h[-1] - h[-2]) / max(abs(h[-2]), 1e-8) \
                        > self.threshold:
                    stable = False
            if stable:
                for i in range(lo, hi):
                    flags[i] = True
            break  # only the frontier module is evaluated per pass
        self._plan = LayerFreezePlan(layers=tuple(flags))

    def scenario_changed(self, params, probe) -> None:
        super().scenario_changed(params, probe)
        # Egeria restarts its module frontier on drift
        self._plan = LayerFreezePlan(layers=(False,) * self.n_units)
        self.probe = probe
        if self.reference_params is not None:
            self._ref_feats = [np.asarray(f, np.float32) for f in
                               self.model.features(self.reference_params, probe)]
        self._hist = [[] for _ in range(self.n_units)]


class SlimFitController(_Base):
    """SlimFit: freeze layers whose relative weight-update magnitude
    ||dW||/||W|| falls below a threshold (the *indirect* signal ETuner's
    representational CKA improves upon)."""

    def __init__(self, model, with_lazytune: bool = True,
                 threshold: float = 2e-3, interval: int = 8,
                 max_frozen_frac: float = 0.9):
        super().__init__(model, with_lazytune)
        self.threshold = threshold
        self.interval = interval
        self.max_frozen_frac = max_frozen_frac
        self._prev_params = None
        self._iters = 0

    def _unit_leaves(self, params):
        # mirrors the model's freeze-unit structure: units list + head
        if "units" in params:
            units = list(params["units"]) + [params["head"]]
        elif "blocks" in params and isinstance(params["blocks"], list):
            units = [params.get("embed", params.get("patch"))] + \
                list(params["blocks"]) + [params["head"]]
        else:
            units = [params.get("embed")] + list(params["blocks"]) + \
                [params.get("head", params.get("final_ln"))]
        return units[:self.n_units]

    def round_finished(self, iters, val_acc, params) -> None:
        super().round_finished(iters, val_acc, params)
        self._iters += iters
        if self._prev_params is None:
            self._prev_params = jax.tree.map(np.asarray, params)
            return
        if self._iters < self.interval:
            return
        self._iters = 0
        flags = list(self._plan.layers)
        cur_units = self._unit_leaves(params)
        prev_units = self._unit_leaves(self._prev_params)
        budget = int(self.max_frozen_frac * self.n_units)
        for i, (cu, pu) in enumerate(zip(cur_units, prev_units)):
            if flags[i] or sum(flags) >= budget or cu is None:
                continue
            num = 0.0
            den = 0.0
            for c, p in zip(jax.tree.leaves(cu), jax.tree.leaves(pu)):
                c = np.asarray(c, np.float32)
                p = np.asarray(p, np.float32)
                num += float(np.linalg.norm(c - p))
                den += float(np.linalg.norm(p)) + 1e-8
            if num / den < self.threshold:
                flags[i] = True
        self._plan = LayerFreezePlan(layers=tuple(flags))
        self._prev_params = jax.tree.map(np.asarray, params)

    def scenario_changed(self, params, probe) -> None:
        super().scenario_changed(params, probe)
        self._plan = LayerFreezePlan(layers=(False,) * self.n_units)
        self._prev_params = None


class RigLController(_Base):
    """RigL: sparse training at fixed sparsity with periodic magnitude-drop
    / gradient-regrow. Freezing-free; compute savings come from sparsity —
    we charge FLOPs * (1 - sparsity * realization) where realization < 1
    models the hardware-underutilization the paper criticizes."""

    def __init__(self, model, with_lazytune: bool = True,
                 sparsity: float = 0.5, realization: float = 0.5):
        super().__init__(model, with_lazytune)
        self.sparsity = sparsity
        self.flops_scale = 1.0 - sparsity * realization
        self.masks = None
        self.update_every = 4
        self._rounds = 0

    def wrap_model(self):
        """Model whose loss applies the sparsity masks (straight-through)."""
        import dataclasses

        import jax.numpy as jnp

        base = self.model
        ctrl = self

        def masked(params):
            if ctrl.masks is None:
                return params
            return jax.tree.map(
                lambda p, m: p * m.astype(p.dtype), params, ctrl.masks)

        def loss(params, batch, plan=None):
            return base.loss(masked(params), batch, plan)

        def predict(params, batch):
            return base.predict(masked(params), batch)

        return dataclasses.replace(base, loss=loss, predict=predict)

    def init_masks(self, params, rng: np.random.Generator):
        def mask(p):
            p = np.asarray(p, np.float32)
            if p.ndim < 2:
                return np.ones_like(p, np.float32)
            k = int(p.size * (1 - self.sparsity))
            thr = np.partition(np.abs(p).ravel(), -k)[-k] if k else np.inf
            return (np.abs(p) >= thr).astype(np.float32)

        import jax.numpy as jnp

        self.masks = jax.tree.map(lambda p: jnp.asarray(mask(p)), params)

    def round_finished(self, iters, val_acc, params) -> None:
        super().round_finished(iters, val_acc, params)
        self._rounds += 1
        if self.masks is None:
            self.init_masks(params, np.random.default_rng(0))
        elif self._rounds % self.update_every == 0:
            # drop lowest-|w| 10% of active, regrow same count randomly
            # (gradient-regrow approximated by random-regrow; noted)
            import jax.numpy as jnp

            rng = np.random.default_rng(self._rounds)

            def update(p, m):
                p = np.asarray(p, np.float32)
                m = np.asarray(m, np.float32)
                if p.ndim < 2:
                    return jnp.asarray(m)
                act = np.flatnonzero(m.ravel())
                if act.size < 10:
                    return jnp.asarray(m)
                k = max(1, act.size // 10)
                mag = np.abs(p.ravel()[act])
                drop = act[np.argpartition(mag, k)[:k]]
                inact = np.flatnonzero(m.ravel() == 0)
                grow = rng.choice(inact, min(k, inact.size), replace=False) \
                    if inact.size else np.empty(0, int)
                flat = m.ravel().copy()
                flat[drop] = 0.0
                flat[grow] = 1.0
                return jnp.asarray(flat.reshape(m.shape))

            self.masks = jax.tree.map(update, params, self.masks)


class EkyaController(_Base):
    """Ekya: fixed-length windows; at each window boundary run a
    trial-and-error micro-profiling over candidate configs (here: freeze-
    prefix depths) and adopt the best. The profiling cost is charged via
    `extra_flops_rounds` (the inefficiency ETuner removes)."""

    def __init__(self, model, with_lazytune: bool = True,
                 window_batches: int = 8,
                 candidate_prefixes=(0.0, 0.25, 0.5)):
        super().__init__(model, with_lazytune)
        self.window_batches = window_batches
        self.candidates = candidate_prefixes
        self._since_profile = 0
        self.profile_rounds = 0

    def should_trigger(self, batches_available: int,
                       staleness: float = 0.0,
                       priority: int = 0) -> bool:
        if self.with_lazytune:
            return self.lazytune.should_trigger(batches_available)
        return batches_available >= self.window_batches

    def round_finished(self, iters, val_acc, params) -> None:
        super().round_finished(iters, val_acc, params)
        self._since_profile += iters
        if self._since_profile >= self.window_batches:
            self._since_profile = 0
            self.profile_rounds += 1
            # micro-profiling: pretend to try each candidate (cost charged
            # by the runtime via profile_rounds); adopt the middle one
            # after "trials" — a coarse stand-in for Ekya's thief scheduler.
            frac = self.candidates[self.profile_rounds % len(self.candidates)]
            k = int(self.n_units * frac)
            flags = tuple(i < k for i in range(self.n_units))
            self._plan = LayerFreezePlan(layers=flags)

    def scenario_changed(self, params, probe) -> None:
        super().scenario_changed(params, probe)
        self._plan = LayerFreezePlan(layers=(False,) * self.n_units)
        self._since_profile = 0
